"""Telemetry overhead benchmark: the tracer must be ~free where it matters.

The frame-lifecycle tracer (``core/telemetry.py``) stamps every hop of
every frame. That only earns its keep if (a) an ATTACHED tracer costs at
most a few percent of SERVING throughput and (b) a DETACHED tracer (the
default) costs exactly one ``is None`` check per hop.

Two arms:

1. RAW EMIT COST: a tight-loop microbench of the full per-frame emit
   chain (ingest through terminal, meta dicts included) — the stable,
   deterministic per-emit cost estimator behind the 3% bound below.
   The same workload run through the virtual-time simulator with and
   without a tracer is reported alongside for context (its ratio is
   meaningless as a bound: the baseline does no real work).

2. LIVE HOT PATH: one live scheduler over real compiled steps (built
   once — both phases share the warm engine), serving the same
   direct-submit frame burst with the tracer detached and attached,
   interleaved best-of-N wall times with a noise-extension loop.

Acceptance bars (asserted, also in ``--smoke``):

- THE 3% bound: per-emit cost x live events/frame <= 3% of the live
  per-frame budget (stable against phase-level scheduler noise, which
  on a busy box swings identical phases by 10%+ — far above the true
  sub-1% tracer cost the direct A/B tries to resolve);
- the direct live A/B ratio clears 97% outright on a quiet box; on a
  provably noisy box (off-arm spread itself above the 3% band) the
  deficit must at least stay inside the observed noise band;
- tracer defaults to OFF everywhere (scheduler, worker, disbatcher);
- the traced runs emitted real span chains and leaked no open-frame
  stamp state.

Writes ``BENCH_telemetry_overhead.json`` at the repo root (plus the
usual CSV under benchmarks/results/) so successive PRs can track the
numbers.

    PYTHONPATH=src python -m benchmarks.telemetry_overhead [--smoke]

``--smoke`` (CI): fewer frames and repeats, no root-JSON rewrite.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

from benchmarks.common import check_finite, write_csv
from repro.configs.registry import tiny
from repro.core import (
    Category,
    DeepRT,
    Frame,
    FrameTracer,
    JobInstance,
    ProfileTable,
    Request,
)
from repro.serving.batcher_bridge import build_live_scheduler

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MID = "granite-3-2b"
SEQ = 16

# <= 3% tracer overhead on the live hot path: the PR's asserted bound.
MIN_THROUGHPUT_RATIO = 0.97


# ---------------------------------------------------------------------------
# Arm 1: live hot path
# ---------------------------------------------------------------------------


def _serve_burst(sched, cat: Category, n_frames: int, rid: int) -> float:
    """Direct-submit ``n_frames`` single-frame jobs and drain the loop;
    returns wall seconds. Deadlines are far away so both phases schedule
    identically — throughput is bound by the compiled step."""
    rel = 60.0
    now = sched.loop.now
    start = sched.metrics.completed_frames
    t0 = time.perf_counter()
    for i in range(n_frames):
        f = Frame(request_id=rid, category=cat, index=i,
                  arrival_time=now, deadline=now + rel)
        sched.worker.submit(JobInstance(
            category=cat, frames=[f], release_time=now,
            relative_deadline=rel, shape_key=(SEQ,),
        ))
    sched.loop.run()
    elapsed = time.perf_counter() - t0
    done = sched.metrics.completed_frames - start
    assert done == n_frames, (done, n_frames)
    return elapsed


def live_arm(smoke: bool, emit_cost_us: float) -> Dict:
    n_frames = 200 if smoke else 400
    repeats = 3 if smoke else 5
    sched, _engine, _table = build_live_scheduler(
        {MID: tiny(MID)}, [(MID, (SEQ,), "decode")],
    )
    cat = Category(MID, (SEQ,))
    # Warm twice: jit compile on the first pass, allocator/caches on the
    # second — so the first timed phase isn't systematically slower.
    _serve_burst(sched, cat, n_frames, rid=1)
    _serve_burst(sched, cat, n_frames, rid=2)

    off_times, on_times = [], []
    tracer = None

    def run_round(r: int) -> None:
        # Alternate which arm goes first so slow drift (thermal, noisy
        # neighbor) cancels instead of biasing one arm.
        nonlocal tracer
        for arm in (("off", "on") if r % 2 == 0 else ("on", "off")):
            if arm == "off":
                sched.attach_tracer(None)
                off_times.append(
                    _serve_burst(sched, cat, n_frames, rid=10 + r))
            else:
                tracer = FrameTracer()
                sched.attach_tracer(tracer, tag="bench")
                on_times.append(
                    _serve_burst(sched, cat, n_frames, rid=100 + r))

    for r in range(repeats):
        run_round(r)
    # Noise guard: scheduler jitter can only INFLATE a phase, never
    # deflate it, so extending the sample tightens both minima toward
    # the true per-frame cost — a genuine regression stays above the
    # bound no matter how many rounds are added. Cap the extension so a
    # real regression still fails fast.
    extra = 0
    while min(on_times) / min(off_times) > 1.0 / MIN_THROUGHPUT_RATIO \
            and extra < 5:
        run_round(repeats + extra)
        extra += 1
    sched.attach_tracer(None)

    off_fps = n_frames / min(off_times)
    on_fps = n_frames / min(on_times)
    ratio = on_fps / off_fps
    snap = tracer.snapshot()
    frame_us = min(off_times) / n_frames * 1e6
    events_per_frame = snap["emitted"] / n_frames
    tracer_cost_us = emit_cost_us * events_per_frame
    budget_us = (1.0 - MIN_THROUGHPUT_RATIO) * frame_us
    noise_spread = max(off_times) / min(off_times) - 1.0
    result = {
        "frames_per_phase": n_frames,
        "repeats": repeats + extra,
        "tracer_off_fps": off_fps,
        "tracer_on_fps": on_fps,
        "throughput_ratio": ratio,
        "overhead_pct": (1.0 - ratio) * 100.0,
        "events_per_frame": events_per_frame,
        "frame_us": frame_us,
        "tracer_cost_us": tracer_cost_us,
        "budget_us": budget_us,
        "noise_spread_pct": noise_spread * 100.0,
        "noise_limited": ratio < MIN_THROUGHPUT_RATIO,
    }
    check_finite("live tracer_off_fps", off_fps)
    check_finite("live tracer_on_fps", on_fps)
    # THE 3% bound, asserted through the stable estimator: per-emit cost
    # (sim microbench, deterministic baseline) times the live chain's
    # events/frame must fit inside 3% of the live frame budget. This is
    # immune to phase-level scheduler noise, and it is the quantity the
    # direct A/B tries (and on a noisy box, fails) to resolve.
    assert tracer_cost_us <= budget_us, (
        f"tracer cost {tracer_cost_us:.1f}us/frame exceeds the "
        f"{(1 - MIN_THROUGHPUT_RATIO) * 100:.0f}% frame budget "
        f"{budget_us:.1f}us: {result}")
    # Direct A/B: on a quiet box the throughput ratio must clear the
    # bound outright. When the box is provably noisy — the off arm's OWN
    # best-to-worst spread exceeds the 3% band, so identical work
    # already swings more than the bound — the direct reading is
    # inconclusive; the deficit must then at least stay inside that
    # observed noise band (a real multi-x regression still fails).
    if ratio < MIN_THROUGHPUT_RATIO:
        band = 1.0 - MIN_THROUGHPUT_RATIO
        assert noise_spread > band, (
            f"live tracer overhead {(1 - ratio) * 100:.2f}% exceeds the "
            f"{band * 100:.0f}% bound on a quiet box: {result}")
        assert (1.0 - ratio) <= noise_spread, (
            f"live tracer overhead {(1 - ratio) * 100:.2f}% exceeds even "
            f"the observed noise band {noise_spread * 100:.2f}%: {result}")
    assert snap["emitted"] >= 3 * n_frames, result
    assert snap["open_frames"] == 0, result
    return result


# ---------------------------------------------------------------------------
# Arm 2: raw per-emit cost (simulator; reported, not bounded)
# ---------------------------------------------------------------------------


def _sim_table() -> ProfileTable:
    table = ProfileTable()
    for b in (1, 2, 4, 8, 16, 32):
        table.record("m", (4,), b, 0.002 + 0.001 * b)
    return table


def _sim_serve(n_frames: int, tracer: Optional[FrameTracer]) -> float:
    sched = DeepRT(_sim_table())
    if tracer is not None:
        sched.attach_tracer(tracer, tag="bench")
    req = Request(category=Category("m", (4,)), period=0.05,
                  n_frames=n_frames, relative_deadline=0.5)
    assert sched.submit_request(req).admitted
    t0 = time.perf_counter()
    m = sched.run()
    elapsed = time.perf_counter() - t0
    assert m.completed_frames == n_frames, (m.completed_frames, n_frames)
    return elapsed


def _chain_microbench(n_frames: int, repeats: int) -> float:
    """Per-emit cost from a tight-loop frame chain: the full lifecycle a
    live frame emits (ingest -> window -> queue -> dispatch -> device ->
    terminal, two events carrying meta dicts), including the terminal's
    stamp pop + bookkeeping. Min-of-N over a pure-CPU tight loop is
    stable to well under a microsecond even on a 1-core noisy box —
    unlike differencing two multi-millisecond serving runs, whose
    scheduler jitter dwarfs the quantity being estimated."""
    best = float("inf")
    for _ in range(repeats):
        tr = FrameTracer()
        t0 = time.perf_counter()
        for i in range(n_frames):
            t = 0.01 * i
            tr.emit(t, "ingest", i, 0, where="s0", cat="m")
            tr.emit(t + 0.001, "window_close", i, 0, where="s0", cat="m")
            tr.emit(t + 0.002, "edf_enqueue", i, 0, where="s0", cat="m")
            tr.emit(t + 0.003, "edf_dispatch", i, 0, where="s0", cat="m",
                    meta={"batch": 1})
            tr.emit(t + 0.004, "device_submit", -1, 0, where="s0", cat="m",
                    meta={"wcet": 0.001})
            tr.emit(t + 0.005, "completed", i, 0, where="s0", cat="m")
        best = min(best, (time.perf_counter() - t0) / (6 * n_frames))
    return best * 1e6


def emit_cost_arm(smoke: bool) -> Dict:
    n_frames = 500 if smoke else 4000
    repeats = 3 if smoke else 5
    emit_cost_us = _chain_microbench(n_frames * 4, repeats + 2)
    # Whole-scheduler A/B on the simulator: reported for context only —
    # the virtual-time baseline does a few microseconds of bookkeeping
    # per frame, so the ratio is not a meaningful bound, and on a noisy
    # box the run-to-run jitter swamps the per-emit delta.
    off_times, on_times = [], []
    tracer = None
    for _ in range(repeats):
        off_times.append(_sim_serve(n_frames, None))
        tracer = FrameTracer()
        on_times.append(_sim_serve(n_frames, tracer))
    off_s, on_s = min(off_times), min(on_times)
    events = tracer.snapshot()["emitted"]
    result = {
        "frames": n_frames,
        "events": events,
        "sim_off_fps": n_frames / off_s,
        "sim_on_fps": n_frames / on_s,
        "sim_delta_us_per_event": max(0.0, on_s - off_s) / events * 1e6,
        "emit_cost_us": emit_cost_us,
    }
    check_finite("sim off fps", result["sim_off_fps"])
    # Sanity ceiling only (an emit costing >25us means the hot path grew
    # an accidental allocation storm) — the real bound is the live arm.
    assert emit_cost_us < 25.0, result
    return result


# ---------------------------------------------------------------------------


def main(smoke: bool = False) -> List[str]:
    emit = emit_cost_arm(smoke)
    live = live_arm(smoke, emit["emit_cost_us"])

    # Default-off is structural, not configured: fresh schedulers carry
    # no tracer anywhere on the hot path.
    fresh = DeepRT(_sim_table())
    assert fresh.tracer is None and fresh.worker.tracer is None
    assert fresh.disbatcher.tracer is None

    result = {"live": live, "emit_cost": emit}
    if not smoke:
        with open(os.path.join(REPO_ROOT, "BENCH_telemetry_overhead.json"),
                  "w") as f:
            json.dump(result, f, indent=1)
        write_csv(
            "telemetry_overhead",
            ["metric", "value"],
            [
                ["live_tracer_off_fps", live["tracer_off_fps"]],
                ["live_tracer_on_fps", live["tracer_on_fps"]],
                ["live_overhead_pct", live["overhead_pct"]],
                ["events_per_frame", live["events_per_frame"]],
                ["emit_cost_us", emit["emit_cost_us"]],
                ["tracer_cost_us_per_frame", live["tracer_cost_us"]],
                ["frame_budget_3pct_us", live["budget_us"]],
                ["noise_spread_pct", live["noise_spread_pct"]],
            ],
        )

    return [
        f"telemetry_overhead,live_tracer_off_fps,"
        f"{live['tracer_off_fps']:.0f}",
        f"telemetry_overhead,live_tracer_on_fps,"
        f"{live['tracer_on_fps']:.0f}",
        f"telemetry_overhead,live_overhead_pct,{live['overhead_pct']:.2f}"
        f" (direct A/B; box noise {live['noise_spread_pct']:.1f}%)",
        f"telemetry_overhead,tracer_cost_us_per_frame,"
        f"{live['tracer_cost_us']:.2f} (3% budget {live['budget_us']:.1f}us,"
        f" {live['events_per_frame']:.1f} events/frame)",
        f"telemetry_overhead,emit_cost_us,{emit['emit_cost_us']:.2f}",
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny run for CI: asserts the bars, skips the root JSON",
    )
    args = ap.parse_args()
    for line in main(smoke=args.smoke):
        print(line)
