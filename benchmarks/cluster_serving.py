"""Cluster serving benchmark: live multi-slice DeepRT with per-slice
slot arenas and a slice-failure replay.

Scenario (all on real compiled programs, one shared WallClock):

1. build a live cluster (``build_live_cluster``): N slices, each with
   its OWN InferenceEngine (resident decode arena, per-slice
   ``max_slots``), AsyncDevice, and profiled WCET table;
2. place a mixed RT workload (decode streams + prefill streams) through
   the utilization-ordered placement + admission + arena-lease path;
3. mid-run, FAIL one slice: its device closes, its engine freezes, and
   every in-flight request's remaining tail re-admits onto surviving
   slices' arenas (re-leased rows — arenas are never re-created);
4. drain to completion.

Acceptance bars (asserted, also in ``--smoke``):

- ZERO decode recompiles on steady slices across the whole replay —
  failover traffic lands on the survivors' one resident program;
- every request placed on the dead slice is re-admitted (immediately
  or via the parked-tail retry queue), provably expired, or finished
  (accounting conserved — nothing silently dropped);
- aggregate throughput is finite and positive (NaN guard) and the miss
  rate stays bounded below 1.

Writes ``BENCH_cluster_serving.json`` at the repo root (plus the usual
CSV under benchmarks/results/) so successive PRs can track the numbers.

    PYTHONPATH=src python -m benchmarks.cluster_serving [--smoke]

``--smoke`` (CI): 2 tiny slices, short streams, no root-JSON rewrite —
a bit-rot guard for the live cluster path, not a timing source.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

from benchmarks.common import check_finite, write_csv
from repro.configs.registry import tiny
from repro.core import Category, Request
from repro.serving.batcher_bridge import build_live_cluster

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MID = "granite-3-2b"


def main(smoke: bool = False) -> List[str]:
    if smoke:
        n_slices, seq_pre, seq_dec = 2, 16, 8
        batch_sizes, nonrt_cap = (1, 2), 1
        n_decode, n_prefill, frames = 3, 2, 8
        fail_after = 0.5
    else:
        n_slices, seq_pre, seq_dec = 3, 32, 16
        batch_sizes, nonrt_cap = (1, 2, 4, 8), 8
        n_decode, n_prefill, frames = 6, 3, 20
        fail_after = 1.0

    configs = {MID: tiny(MID)}
    cats = [(MID, (seq_pre,), "prefill"), (MID, (seq_dec,), "decode")]
    t0 = time.perf_counter()
    cluster, slices = build_live_cluster(
        configs,
        cats,
        slice_names=tuple(f"slice{i}" for i in range(n_slices)),
        batch_sizes=batch_sizes,
        profile_runs=3 if smoke else 5,
        nonrt_cap=nonrt_cap,
    )
    build_s = time.perf_counter() - t0

    reqs = [
        Request(category=Category(MID, (seq_dec,)), period=0.2,
                relative_deadline=0.4, n_frames=frames)
        for _ in range(n_decode)
    ] + [
        Request(category=Category(MID, (seq_pre,)), period=0.1,
                relative_deadline=0.3, n_frames=frames)
        for _ in range(n_prefill)
    ]
    placed = sum(cluster.submit_request(r) for r in reqs)

    by_slice: Dict[str, int] = {name: 0 for name in slices}
    for name in cluster.placement.values():
        by_slice[name] += 1

    t_serve = time.perf_counter()
    cluster.run(until=cluster.loop.now + fail_after)
    # Fail the most loaded slice mid-decode (deterministic tie: name;
    # placement only changes at fail_slice, so by_slice is still current).
    dead = max(by_slice, key=lambda n: (by_slice[n], n))
    victims = [rid for rid, n in cluster.placement.items() if n == dead]
    # Guard the replay against becoming vacuous: at failure time at least
    # one victim must still be mid-stream (placement also retains fully
    # arrived requests, so victims alone proves nothing).
    now = cluster.loop.now
    inflight = [rid for rid in victims if cluster.requests[rid].end_time > now]
    assert inflight, (
        "failure replay needs in-flight requests on the dead slice; "
        f"streams ended before fail_after={fail_after}"
    )
    completed_at_failure = cluster.aggregate_metrics()["completed_frames"]
    parked_now = cluster.fail_slice(dead)
    cluster.run()
    serve_s = time.perf_counter() - t_serve

    agg = cluster.aggregate_metrics()
    throughput = agg["completed_frames"] / serve_s if serve_s > 0 else 0.0
    survivors = [n for n in slices if n != dead]
    compiles = {
        name: {
            "decode": slices[name].engine.stats["decode_compiles"],
            "prefill": slices[name].engine.stats["prefill_compiles"],
        }
        for name in slices
    }
    rerouted = sum(1 for t in cluster.failover_map.values() if t is not None)
    expired = sum(1 for t in cluster.failover_map.values() if t is None)

    result = {
        "slices": n_slices,
        "build_seconds": build_s,
        "placed_requests": placed,
        "placement": by_slice,
        "failed_slice": dead,
        "failover": {
            "victims": len(victims),
            "rerouted": rerouted,
            "parked": len(parked_now),
            "parked_admitted": len(cluster.parked_admitted),
            "expired": expired,
            "finished_with_slice": len(cluster.finished_with_slice),
        },
        "completed_frames": agg["completed_frames"],
        "completed_at_failure": completed_at_failure,
        "miss_rate": agg["miss_rate"],
        "throughput_frames_per_sec": throughput,
        "compiles_after_warmup": compiles,
        "survivor_arena_allocs": {
            name: slices[name].engine.arena(MID, seq_dec).allocs
            for name in survivors
        },
    }

    # Bit-rot guards (what --smoke exists for).
    assert placed >= 2, result
    assert rerouted + expired >= 1, result  # failover actually displaced work
    check_finite("cluster throughput", throughput)
    assert agg["miss_rate"] < 1.0, result
    # Accounting conserved: every victim re-admitted (immediately or via
    # the parked retry queue), provably expired while parked, or finished.
    accounted = rerouted + expired + len(cluster.finished_with_slice)
    assert accounted == len(victims), result
    assert cluster.parked == {}, result  # every parked tail resolved
    assert len(cluster.parked_admitted) + len(cluster.parked_expired) == len(
        parked_now
    ), result
    # THE acceptance bar: zero decode recompiles on steady slices across
    # the failure replay — rerouted decode traffic hit the survivors' one
    # resident program, batch size stayed data.
    for name in survivors:
        assert compiles[name]["decode"] == 0, (name, result)
    assert agg["completed_frames"] > completed_at_failure, result

    if not smoke:
        with open(os.path.join(REPO_ROOT, "BENCH_cluster_serving.json"), "w") as f:
            json.dump(result, f, indent=1)
        write_csv(
            "cluster_serving",
            ["metric", "value"],
            [
                ["slices", n_slices],
                ["placed_requests", placed],
                ["victims", len(victims)],
                ["rerouted", rerouted],
                ["expired", expired],
                ["miss_rate", agg["miss_rate"]],
                ["throughput_frames_per_sec", throughput],
                ["survivor_decode_recompiles",
                 sum(compiles[n]["decode"] for n in survivors)],
            ],
        )

    lines = [
        f"cluster_serving,slices,{n_slices}",
        f"cluster_serving,placed_requests,{placed}/{len(reqs)}",
        f"cluster_serving,failed_slice,{dead} ({len(victims)} in-flight)",
        f"cluster_serving,failover,rerouted {rerouted} / expired {expired}",
        f"cluster_serving,completed_frames,{agg['completed_frames']}",
        f"cluster_serving,miss_rate,{agg['miss_rate']:.3f}",
        f"cluster_serving,throughput_fps,{throughput:.1f}",
        f"cluster_serving,survivor_decode_recompiles,"
        f"{sum(compiles[n]['decode'] for n in survivors)}",
    ]
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="2 tiny slices, short streams, no JSON rewrite (CI bit-rot guard)",
    )
    args = ap.parse_args()
    for line in main(smoke=args.smoke):
        print(line)
