"""Multi-step decode chunking benchmark: k-step scanned decode programs
vs single-step dispatch, and a deadline-constrained A/B of the
slack-chosen depth policy.

Headline scenarios:

- decode steps/sec vs chunk depth k in {1, 2, 4, 8}: k=1 is the
  pipelined single-step slot-arena loop (the serving_hotpath baseline);
  k>1 runs the scanned ``decode_chunk`` program, which removes k-1
  host returns + dispatch decisions per k steps. Acceptance: k=8
  sustains >= 1.25x the k=1 step rate, with ZERO decode recompiles
  across the whole sweep after the warm-up (one compiled program per
  (model, seq, k), like every other shape on the arena).
- deadline-constrained A/B: the same bursty backlogged job trace served
  by a live scheduler with chunk_depth=8 (slack-chosen depths) vs
  chunk_depth=1 (every step its own dispatch). The chunked arm must not
  degrade the p99 frame latency — deep chunks are only taken when every
  fused job's slack clears the chunk WCET + margin, so tail latency is
  protected by construction.

Writes ``BENCH_decode_chunking.json`` at the repo root (plus the usual
CSV under benchmarks/results/) so successive PRs can track the numbers.

    PYTHONPATH=src python -m benchmarks.decode_chunking [--smoke]

``--smoke`` (CI): tiny shapes, few steps, no root-JSON rewrite — it
exists to catch bench bit-rot (import errors, NaN/zero throughput)
before a perf PR needs the numbers, not to produce stable timings.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import check_finite, write_csv
from repro.configs.registry import tiny
from repro.core import Category, Frame, JobInstance
from repro.serving.batcher_bridge import build_live_scheduler
from repro.serving.engine import InferenceEngine

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MID = "granite-3-2b"
SEQ = 16
MAX_SLOTS = 8
DEPTHS = (1, 2, 4, 8)


def _chunk_rate_sweep(
    depths=DEPTHS, steps_target: int = 96, seq: int = SEQ,
    max_slots: int = MAX_SLOTS, batch: int = 4,
) -> Dict[int, float]:
    """Steady-state decode steps/sec per chunk depth.

    Every depth executes the SAME number of decode steps (not the same
    number of dispatches), so the rates are directly comparable; k=1 is
    the plain single-step dispatch loop."""
    engine = InferenceEngine(
        {MID: tiny(MID)}, max_slots=max_slots, chunk_depth=max(depths)
    )
    # Warm: compile the single-step program and every chunk program.
    engine.execute(MID, (seq,), batch, kind="decode")
    for k in depths:
        if k > 1:
            engine.execute_chunk(MID, (seq,), batch, k)
            engine.execute_chunk(MID, (seq,), batch, k)
    engine.reset_stats()

    rates: Dict[int, float] = {}
    for k in depths:
        n = max(1, steps_target // k)
        best = 0.0
        for _rep in range(3):  # best-of-3: shrug off scheduler noise
            t0 = time.perf_counter()
            if k == 1:
                for _ in range(n):
                    h = engine.dispatch(MID, (seq,), batch, kind="decode")
            else:
                for _ in range(n):
                    h = engine.decode_chunk(MID, (seq,), batch, k)
            h.wait()  # pipelined: block once at the end
            best = max(best, (n * k) / (time.perf_counter() - t0))
        rates[k] = best
        check_finite(f"decode_steps_per_sec[k={k}]", rates[k])
    # The whole sweep reused warm programs: one per (model, seq, k).
    assert engine.stats["decode_compiles"] == 0, engine.stats
    return rates


def _burst_trace(n_bursts: int, burst: int, rel_deadline: float):
    """Deterministic bursty backlog: per burst, ``burst`` same-category
    decode jobs released back-to-back (the queue the depth policy works
    on). Rebuilt per arm so both arms serve identical traces."""
    cat = Category(MID, (SEQ,))
    return [
        [(b, i, rel_deadline) for i in range(burst)]
        for b in range(n_bursts)
    ], cat


def _deadline_arm(
    chunk_depth: int, n_bursts: int, burst: int, rel_deadline: float,
    drain: float,
) -> Dict[str, float]:
    """Serve the burst trace live; report p99 latency + misses."""
    sched, engine, _table = build_live_scheduler(
        {MID: tiny(MID)}, [(MID, (SEQ,), "decode")],
        chunk_depth=chunk_depth,
    )
    plan, cat = _burst_trace(n_bursts, burst, rel_deadline)
    for burst_jobs in plan:
        now = sched.loop.now
        for (b, i, rel) in burst_jobs:
            f = Frame(
                request_id=b, category=cat, index=i,
                arrival_time=now, deadline=now + rel,
            )
            sched.worker.submit(JobInstance(
                category=cat, frames=[f], release_time=now,
                relative_deadline=rel, shape_key=(SEQ,),
            ))
        sched.loop.run(until=sched.loop.now + drain)
    m = sched.metrics
    lat = sorted(m.frame_latencies)
    total = n_bursts * burst
    assert m.completed_frames == total, (m.completed_frames, total)
    return {
        "p50_latency": float(np.percentile(lat, 50)),
        "p99_latency": float(np.percentile(lat, 99)),
        "missed_frames": m.missed_frames,
        "chunk_submits": m.chunk_submits,
        "chunked_steps": m.chunked_steps,
        "decode_compiles_post_warmup": engine.stats["decode_compiles"],
    }


def main(smoke: bool = False) -> List[str]:
    if smoke:
        rates = _chunk_rate_sweep(depths=(1, 2, 4), steps_target=8,
                                  max_slots=4, batch=2)
        deadline = {
            d: _deadline_arm(d, n_bursts=2, burst=4, rel_deadline=1.0,
                             drain=0.3)
            for d in (1, 4)
        }
        deep, base = 4, 1
    else:
        rates = _chunk_rate_sweep()
        deadline = {
            d: _deadline_arm(d, n_bursts=6, burst=8, rel_deadline=0.5,
                             drain=0.6)
            for d in (1, 8)
        }
        deep, base = 8, 1

    speedup = rates[max(rates)] / rates[1]
    chunked, single = deadline[deep], deadline[base]

    result = {
        "decode_steps_per_sec": {str(k): r for k, r in rates.items()},
        "deepest_vs_single_speedup_x": speedup,
        "deadline_arm": {
            f"chunk_depth_{base}": single,
            f"chunk_depth_{deep}": chunked,
        },
    }

    if not smoke:
        # Acceptance bars (the chunking PR's headline numbers).
        assert speedup >= 1.25, (
            f"k={max(rates)} decode rate only {speedup:.2f}x k=1"
        )
        assert chunked["chunk_submits"] >= 1, chunked
        assert chunked["decode_compiles_post_warmup"] == 0, chunked
        assert single["decode_compiles_post_warmup"] == 0, single
        # Slack-gated depths must not degrade the deadline tail: allow
        # a small wall-clock noise band on top of "no worse".
        assert chunked["p99_latency"] <= single["p99_latency"] * 1.10, (
            chunked["p99_latency"], single["p99_latency"],
        )
        with open(os.path.join(REPO_ROOT, "BENCH_decode_chunking.json"), "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)

    write_csv(
        "decode_chunking",
        ["metric", "value"],
        [[f"decode_steps_per_sec_k{k}", f"{r:.1f}"] for k, r in rates.items()]
        + [["deepest_vs_single_speedup_x", f"{speedup:.3f}"]]
        + [
            [f"depth{d}_{key}", f"{val:.6f}" if isinstance(val, float) else val]
            for d, arm in deadline.items()
            for key, val in arm.items()
        ],
    )
    return [
        f"decode_chunking,steps_per_sec_k1,{rates[1]:.1f}",
        f"decode_chunking,steps_per_sec_k{max(rates)},{rates[max(rates)]:.1f}",
        f"decode_chunking,deepest_vs_single_speedup_x,{speedup:.3f}",
        f"decode_chunking,chunked_p99_latency_s,{chunked['p99_latency']:.6f}",
        f"decode_chunking,single_p99_latency_s,{single['p99_latency']:.6f}",
        f"decode_chunking,chunk_submits,{chunked['chunk_submits']}",
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny fast run for CI bit-rot detection (no JSON rewrite)",
    )
    args = ap.parse_args()
    for line in main(smoke=args.smoke):
        print(line)
