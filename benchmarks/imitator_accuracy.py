"""Paper Fig 8: EDF-imitator latency-prediction accuracy.

Three traces with (period, deadline) = (100,300), (200,200), (300,100)
ms, per the paper. Metric: predicted - actual frame completion
difference; the CDF should be one-sided (conservative) up to the bounded
early-flush perturbation, and differences should stay below the relative
deadline (the paper's acceptance bar).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import paper_table, paper_trace, write_csv
from repro.core import DeepRT, ExecutionModel


def run_trace(mean_p: float, mean_d: float, seed: int):
    table = paper_table()
    reqs = paper_trace(mean_p, mean_d, seed=seed)
    sched = DeepRT(
        table,
        execution=ExecutionModel(actual_fn=lambda j, w: 0.93 * w),
        adaptation_enabled=False,
    )
    predictions = {}
    for r in reqs:
        res = sched.submit_request(r)
        if res.admitted:
            predictions.update(res.predicted_completions)
    m = sched.run()
    diffs = []
    for key, pred in predictions.items():
        rec = m.frame_records.get(key)
        if rec is not None:
            diffs.append(pred - rec[2])  # predicted - actual
    return diffs


def main(seeds=(0, 1)) -> List[str]:
    rows = []
    lines = []
    for mean_p, mean_d in [(0.1, 0.3), (0.2, 0.2), (0.3, 0.1)]:
        alldiffs = []
        for seed in seeds:
            alldiffs += run_trace(mean_p, mean_d, seed)
        alldiffs.sort()
        for d in alldiffs:
            rows.append([f"p{mean_p}_d{mean_d}", d])
        if alldiffs:
            p50 = alldiffs[len(alldiffs) // 2]
            p99 = alldiffs[min(len(alldiffs) - 1, int(0.99 * len(alldiffs)))]
            neg = sum(1 for d in alldiffs if d < -1e-6) / len(alldiffs)
            lines.append(
                f"fig8,p{mean_p}_d{mean_d},pred_minus_actual_p50_p99_negfrac,"
                f"{p50:.4f}|{p99:.4f}|{neg:.4f}"
            )
    write_csv("fig8_imitator_accuracy", ["trace", "pred_minus_actual_s"], rows)
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
