"""Paper Fig 9: Admission Control Module running time vs #frames.

Traces whose requests contain 1e2..1e5 frames; wall-clock of one full
admission decision (Phase 1 + pseudo-job generation + EDF imitator).
The paper reports sub-second up to 1e4 and ~5.9 s at 1e5 on a TX2; the
complexity is linear in the number of frames.
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import paper_table, write_csv
from repro.core import Category, DeepRT, Request


def admission_time(n_frames: int, n_existing: int = 5) -> float:
    table = paper_table()
    sched = DeepRT(table, adaptation_enabled=False)
    cat = Category("resnet50", (3, 224, 224))
    for i in range(n_existing):
        sched.submit_request(
            Request(category=cat, period=0.2, relative_deadline=0.6,
                    n_frames=n_frames)
        )
    pending = Request(
        category=cat, period=0.2, relative_deadline=0.6, n_frames=n_frames
    )
    t0 = time.perf_counter()
    sched.submit_request(pending)
    return time.perf_counter() - t0


def main() -> List[str]:
    rows = []
    lines = []
    for n in [100, 1000, 10000, 100000]:
        ts = [admission_time(n) for _ in range(3)]
        med = sorted(ts)[1]
        rows.append([n, med])
        lines.append(f"fig9,frames_{n},admission_runtime_s,{med:.4f}")
    write_csv("fig9_admission_runtime", ["n_frames", "runtime_s"], rows)
    # Linearity check: runtime(1e5)/runtime(1e3) should be ~1e2, not 1e4.
    r = rows[-1][1] / max(rows[1][1], 1e-9)
    lines.append(f"fig9,linearity,runtime_1e5_over_1e3,{r:.1f}")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
