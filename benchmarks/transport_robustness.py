"""Transport robustness replay: a lossy, reordering, duplicating link in
front of a live cluster, plus one mid-stream slice failure — and the
client-signaled backpressure A/B.

Two arms:

1. CHAOS + FAILOVER (live, WallClock, real compiled programs): build the
   full networked path with ``build_live_transport`` — wire datagrams ->
   reassembly (reorder window, dedup, late rejection) -> gateway ->
   placement/EDF. Every stream rides its own seed-derived ``LinkPlan``
   (the network analogue of ``FaultPlan.from_seed``: per-frame
   DROP/DUPLICATE/REORDER/DELAY, deterministic and prefix-stable). One
   slice is failed mid-stream; the transport server is the cluster's
   rehome owner, so the displaced session re-homes and the client
   retransmits its buffered tail through the SAME chaotic link.

2. FLOW CONTROL A/B (simulated EventLoop, bit-deterministic): a 2.5x
   burst overload (``BurstSource`` duty=0.4) against a single slice,
   once with credit/duty-downshift backpressure and once with the
   server's CREDIT messages ignored. Sim time makes this arm exactly
   reproducible — the strict inequality is a property, not a race.

Acceptance bars (asserted, also in ``--smoke``):

- conservation THROUGH the transport: ``completed + dropped + lost ==
  ingested`` cluster-wide, and the wire-level identity (every datagram
  that reached the server lands in exactly one bucket) per session;
- frames delivered after the failover carry REAL payload: bit-identical
  to the source's bytes for their sequence number, and collectively
  non-zero (a synthetic re-admission would stream zeros);
- the displaced session actually re-homed (>= 1 rehome observed, new
  home differs from the failed slice);
- ZERO decode recompiles on surviving slices across the whole replay;
- the flow-control arm's effective miss rate is STRICTLY lower than the
  no-flow-control arm's.

Writes ``BENCH_transport_robustness.json`` at the repo root (plus the
usual CSV under benchmarks/results/).

    PYTHONPATH=src python -m benchmarks.transport_robustness [--smoke]

``--smoke`` (CI): 2 tiny slices, short streams, no root-JSON rewrite —
a bit-rot guard for the transport path, not a timing source.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

import numpy as np

from benchmarks.common import write_csv
from repro.configs.registry import tiny
from repro.core import Category, EventLoop, ProfileTable
from repro.core.cluster import build_sim_cluster
from repro.ingest import (
    BurstSource,
    IngestGateway,
    LinkPlan,
    PeriodicSource,
    SimLink,
    TransportServer,
    TransportSource,
)
from repro.serving.batcher_bridge import build_live_transport

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MID = "granite-3-2b"
SEQ_PRE = 16
SEQ_DEC = 8

LINK_SEED = 2026
CHAOS = dict(p_drop=0.06, p_dup=0.06, p_reorder=0.08, p_delay=0.06,
             reorder_hold=(0.05, 0.2))


# ---------------------------------------------------------------------------
# Arm 1: chaos link + slice failure over a live cluster
# ---------------------------------------------------------------------------


def run_chaos_failover(n_slices: int, n_streams: int, frames: int,
                       horizon: float, fail_at: float):
    configs = {MID: tiny(MID)}
    cats = [(MID, (SEQ_PRE,), "prefill"), (MID, (SEQ_DEC,), "decode")]
    cluster, slices, gateway, transport, _binding = build_live_transport(
        configs,
        cats,
        slice_names=tuple(f"slice{i}" for i in range(n_slices)),
        # Decode is a flat-cost category: its arena max_slots IS the max
        # profiled batch, and Phase-1 admission sees infinity past it.
        # Profile to 8 so a survivor slice can host the failover tail on
        # top of its own streams (n_g = floor(sum w/p) can reach 5 here).
        batch_sizes=(1, 2, 4, 8),
        profile_runs=2,
        nonrt_cap=1,
        record_payloads=True,
    )
    loop = cluster.loop
    period, deadline = 0.2, 0.7
    clients, links, sources = [], [], []
    for i in range(n_streams):
        plan = LinkPlan.from_seed(LINK_SEED + i, frames * 4, **CHAOS)
        link = SimLink(loop, transport.datagram, plan=plan)
        src = PeriodicSource(
            period=period, n_frames=frames, payload_shape=(), seed=80 + i
        )
        client = TransportSource(src, Category(MID, (SEQ_DEC,)), deadline, link)
        assert client.start(transport), f"stream {i} refused admission"
        clients.append(client)
        links.append(link)
        sources.append(src)

    # Fail the slice that owns session 1: its tail must re-home and its
    # client must retransmit the buffered bytes through the chaos link.
    victim = transport.sessions[1]
    home = victim.session.slice_name
    loop.schedule(fail_at, lambda: cluster.fail_slice(home), priority=0)

    try:
        cluster.run(until=loop.now + horizon)
        transport.finalize_all()
        cluster.run(until=loop.now + 1.0)
    finally:
        for sl in slices.values():
            if sl.alive:
                sl.scheduler.device.close()

    # --- conservation through the transport --------------------------------
    agg = cluster.aggregate_metrics()
    assert (
        agg["completed_frames"] + agg["dropped_frames"] + agg["lost_frames"]
        == agg["ingested_frames"]
    ), agg
    for sid, ts in transport.sessions.items():
        assert ts.wire_conserved(), (sid, transport.status()["sessions"][str(sid)])

    # --- re-homing carried real bytes --------------------------------------
    assert victim.rehomes >= 1, "displaced session never re-homed"
    assert victim.session.slice_name != home
    post = [s for s in victim.delivered_log if s * period >= fail_at]
    assert post, "no post-failover deliveries on the re-homed session"
    src = sources[0]
    for seq in post:
        assert np.array_equal(victim.delivered_payloads[seq], src.payload(seq)), (
            f"post-failover frame {seq} not bit-identical to the source"
        )
    assert any(
        np.asarray(victim.delivered_payloads[s]).any() for s in post
    ), "post-failover frames are all zeros (synthetic tail)"

    # Every delivery on every session is the source's bytes, in order.
    for i, client in enumerate(clients):
        ts = transport.sessions[i + 1]
        assert ts.delivered_log == sorted(set(ts.delivered_log)), i
        for seq, payload in ts.delivered_payloads.items():
            assert np.array_equal(payload, sources[i].payload(seq)), (i, seq)

    # --- survivors: zero decode recompiles ---------------------------------
    survivors = [n for n in slices if slices[n].alive]
    assert survivors, "failover killed every slice"
    for name in survivors:
        assert slices[name].engine.stats["decode_compiles"] == 0, name

    link_totals = {
        "sends": sum(l.sends for l in links),
        "dropped": sum(l.dropped for l in links),
        "duplicated": sum(l.duplicated for l in links),
        "reordered": sum(l.reordered for l in links),
        "delayed": sum(l.delayed for l in links),
    }
    return cluster, slices, transport, victim, home, agg, link_totals


# ---------------------------------------------------------------------------
# Arm 2: flow-control A/B under burst overload (deterministic sim)
# ---------------------------------------------------------------------------


def _sim_table(a: float = 0.01, c: float = 0.04) -> ProfileTable:
    table = ProfileTable()
    for b in (1, 2, 4, 8, 16, 32):
        table.record("m", (4,), b, a + c * b)
    return table


def run_flow_arm(flow: bool):
    loop = EventLoop()
    cluster = build_sim_cluster(_sim_table, ["s0"], loop=loop)
    gateway = IngestGateway(cluster)
    server = TransportServer(gateway, flow_control=flow, record_payloads=False)
    link = SimLink(loop, server.datagram)
    src = BurstSource(
        period=0.12, n_frames=120, payload_shape=(4,), seed=3,
        burst=8, duty=0.4,
    )
    client = TransportSource(src, Category("m", (4,)), 0.36, link,
                             flow_control=flow)
    assert client.start(server)
    loop.run()
    server.finalize_all()
    loop.run()
    m = cluster.slices["s0"].scheduler.metrics
    assert (
        m.completed_frames + m.dropped_frames + m.lost_frames
        == m.ingested_frames
    )
    eff = (m.missed_frames + m.dropped_frames + m.lost_frames) / m.ingested_frames
    return eff, server.sessions[1], client


# ---------------------------------------------------------------------------


def main(smoke: bool = False) -> List[str]:
    if smoke:
        n_slices, n_streams, frames, horizon, fail_at = 2, 3, 10, 6.0, 1.1
    else:
        n_slices, n_streams, frames, horizon, fail_at = 3, 4, 16, 9.0, 1.5

    t0 = time.perf_counter()
    cluster, slices, transport, victim, home, agg, link_totals = (
        run_chaos_failover(n_slices, n_streams, frames, horizon, fail_at)
    )
    chaos_seconds = time.perf_counter() - t0

    t1 = time.perf_counter()
    eff_flow, ts_flow, client_flow = run_flow_arm(flow=True)
    eff_ctrl, _ts_ctrl, client_ctrl = run_flow_arm(flow=False)
    flow_seconds = time.perf_counter() - t1
    assert eff_flow < eff_ctrl, (
        f"flow control must strictly beat the control arm: "
        f"{eff_flow:.3f} vs {eff_ctrl:.3f}"
    )
    assert client_flow.downshifts_applied > 0
    assert client_ctrl.duty == client_ctrl.plan_duty
    assert ts_flow.session.downshifts > 0

    survivors = [n for n in slices if slices[n].alive]
    result = {
        "chaos_failover": {
            "slices": n_slices,
            "streams": n_streams,
            "frames_per_stream": frames,
            "link_seed": LINK_SEED,
            "link": link_totals,
            "failed_slice": home,
            "rehomes": victim.rehomes,
            "rehomed_to": victim.session.slice_name,
            "wire": {
                str(sid): {
                    "received": ts.wire_received,
                    "delivered": ts.delivered,
                    "duplicates": ts.duplicates,
                    "net_lost": ts.net_lost,
                    "late_rejected": ts.late_rejected,
                    "conserved": ts.wire_conserved(),
                }
                for sid, ts in transport.sessions.items()
            },
            "completed_frames": agg["completed_frames"],
            "dropped_frames": agg["dropped_frames"],
            "lost_frames": agg["lost_frames"],
            "ingested_frames": agg["ingested_frames"],
            "reroutes": agg["reroutes"],
            "survivor_decode_recompiles": sum(
                slices[n].engine.stats["decode_compiles"] for n in survivors
            ),
            "seconds": chaos_seconds,
        },
        "flow_control": {
            "effective_miss_rate_flow": eff_flow,
            "effective_miss_rate_control": eff_ctrl,
            "downshifts_applied": client_flow.downshifts_applied,
            "final_duty": client_flow.duty,
            "plan_duty": client_flow.plan_duty,
            "session_credit": ts_flow.session.credit,
            "seconds": flow_seconds,
        },
    }

    if not smoke:
        with open(
            os.path.join(REPO_ROOT, "BENCH_transport_robustness.json"), "w"
        ) as f:
            json.dump(result, f, indent=1)
        write_csv(
            "transport_robustness",
            ["metric", "value"],
            [
                ["slices", n_slices],
                ["streams", n_streams],
                ["link_dropped", link_totals["dropped"]],
                ["link_duplicated", link_totals["duplicated"]],
                ["link_reordered", link_totals["reordered"]],
                ["rehomes", victim.rehomes],
                ["effective_miss_rate_flow", eff_flow],
                ["effective_miss_rate_control", eff_ctrl],
                ["lost_frames", agg["lost_frames"]],
                ["survivor_decode_recompiles",
                 result["chaos_failover"]["survivor_decode_recompiles"]],
            ],
        )

    return [
        f"transport_robustness,link,"
        f"{link_totals['sends']} sends / {link_totals['dropped']} dropped / "
        f"{link_totals['duplicated']} duplicated / "
        f"{link_totals['reordered']} reordered",
        f"transport_robustness,rehome,{home} failed -> "
        f"{victim.session.slice_name} ({victim.rehomes} rehome, "
        f"post-failover bytes bit-checked)",
        f"transport_robustness,conservation,completed {agg['completed_frames']}"
        f" + dropped {agg['dropped_frames']} + lost {agg['lost_frames']} == "
        f"ingested {agg['ingested_frames']}",
        f"transport_robustness,flow_control,"
        f"flow {eff_flow:.3f} vs control {eff_ctrl:.3f} "
        f"({client_flow.downshifts_applied} downshifts)",
        f"transport_robustness,survivor_decode_recompiles,"
        f"{result['chaos_failover']['survivor_decode_recompiles']}",
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="2 tiny slices, short streams, no JSON rewrite (CI bit-rot guard)",
    )
    args = ap.parse_args()
    if args.smoke:
        # The chaos arm rides real wall-clock timing; a loaded CI runner
        # can blur it. One retry forgives transient machine noise — a
        # genuine regression fails both attempts. (The flow-control arm
        # is simulated time and exactly deterministic.)
        try:
            lines = main(smoke=True)
        except AssertionError as e:
            print(f"transport_robustness,smoke_retry,first attempt failed: {e}")
            lines = main(smoke=True)
    else:
        lines = main(smoke=False)
    for line in lines:
        print(line)
