"""Ingest serving benchmark: real payload bytes arrival -> staged device
buffer, plus the load-shedding A/B under overload.

Two arms:

1. LIVE STAGED STEADY STATE (real compiled programs, one WallClock):
   build a live cluster (``build_live_cluster``), register camera
   streams (prefill token rows + decode token streams) through the
   ingest gateway, serve to completion. Reported: steady-state
   host->device staging traffic (bytes/step per slice — real ingestion
   means every step PAYS a payload transfer; the ring makes it the only
   per-step host cost), end-to-end latency (arrival -> completion,
   alongside the scheduler-relative latency), and the hot-loop
   invariants.

2. SHEDDING A/B UNDER 2x OVERLOAD (deterministic simulation): one
   admitted stream whose bursty source delivers its declared frame
   budget at twice the admitted rate (``BurstSource(duty=0.5)``) — the
   overload admission never saw, which is exactly where arrival-side
   degradation must act. Same trace with and without the gateway's
   adaptation-driven shedder.

Acceptance bars (asserted, also in ``--smoke``):

- ZERO decode recompiles across the whole served run (staged payloads
  hit the one resident arena program);
- ZERO fresh host allocations on the staged steady state: every ring's
  ``host_allocs`` still equals its depth after serving;
- shedding yields STRICTLY fewer deadline misses than no-shedding under
  the 2x overload, and every dropped frame is accounted
  (completed + dropped == ingested — nothing silently vanishes);
- throughput finite and positive (NaN guard).

Writes ``BENCH_ingest_serving.json`` at the repo root (plus the usual
CSV under benchmarks/results/) so successive PRs can track the numbers.

    PYTHONPATH=src python -m benchmarks.ingest_serving [--smoke]

``--smoke`` (CI): tiny shapes, short streams, no root-JSON rewrite — a
bit-rot guard for the ingest gateway path, not a timing source.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

from benchmarks.common import check_finite, write_csv
from repro.configs.registry import tiny
from repro.core import Category, DeepRT, FrameTracer, ProfileTable
from repro.ingest import BurstSource, CameraSource, IngestGateway
from repro.serving.batcher_bridge import build_live_cluster

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MID = "granite-3-2b"


# ---------------------------------------------------------------------------
# Arm 1: live staged steady state
# ---------------------------------------------------------------------------


def live_staged_arm(smoke: bool) -> Dict:
    if smoke:
        seq_pre, seq_dec = 16, 8
        batch_sizes, nonrt_cap = (1, 2), 1
        n_decode, n_prefill, frames = 2, 1, 6
        period, deadline = 0.2, 0.4
    else:
        seq_pre, seq_dec = 32, 16
        batch_sizes, nonrt_cap = (1, 2, 4), 2
        n_decode, n_prefill, frames = 4, 2, 20
        period, deadline = 0.2, 0.4

    configs = {MID: tiny(MID)}
    cats = [(MID, (seq_pre,), "prefill"), (MID, (seq_dec,), "decode")]
    t0 = time.perf_counter()
    cluster, slices = build_live_cluster(
        configs, cats, slice_names=("slice0", "slice1"),
        batch_sizes=batch_sizes, profile_runs=3 if smoke else 5,
        nonrt_cap=nonrt_cap,
    )
    build_s = time.perf_counter() - t0

    tracer = FrameTracer()
    cluster.attach_tracer(tracer)
    gw = IngestGateway(cluster)
    gw.tracer = tracer
    sessions = []
    for i in range(n_decode):
        sessions.append(gw.register(
            CameraSource(period=period, n_frames=frames, payload_shape=(),
                         seed=100 + i),
            Category(MID, (seq_dec,)), relative_deadline=deadline,
        ))
    for i in range(n_prefill):
        sessions.append(gw.register(
            CameraSource(period=period, n_frames=frames,
                         payload_shape=(seq_pre,), seed=200 + i),
            Category(MID, (seq_pre,)), relative_deadline=deadline,
        ))
    active = [s for s in sessions if s.state == "active"]

    t_serve = time.perf_counter()
    cluster.run()
    serve_s = time.perf_counter() - t_serve

    agg = cluster.aggregate_metrics()
    throughput = agg["completed_frames"] / serve_s if serve_s > 0 else 0.0
    per_slice = {}
    for name, sl in slices.items():
        eng = sl.engine
        fills = eng.staging_fills
        per_slice[name] = {
            "staged_bytes_total": eng.staging_bytes,
            "staged_steps": fills,
            "bytes_per_step": eng.staging_bytes / fills if fills else 0.0,
            "staging_host_allocs": eng.staging_host_allocs,
            "staging_rings": len(eng._rings),
            "decode_compiles": eng.stats["decode_compiles"],
            "prefill_compiles": eng.stats["prefill_compiles"],
            "mean_e2e_latency": sl.scheduler.metrics.mean_e2e_latency,
            "mean_sched_latency": sl.scheduler.metrics.mean_latency,
        }

    result = {
        "build_seconds": build_s,
        "registered_sessions": len(sessions),
        "active_sessions": len(active),
        "completed_frames": agg["completed_frames"],
        "dropped_frames": agg["dropped_frames"],
        "miss_rate": agg["miss_rate"],
        "mean_e2e_latency": agg["mean_e2e_latency"],
        "e2e_p99": agg["e2e_p99"],
        "throughput_frames_per_sec": throughput,
        "per_slice": per_slice,
        # The unified observability tree: slice health/utilization,
        # latency histograms, arena + staging-ring probes, chunk-depth
        # histogram, watchdog stats, tracer ring + miss attribution.
        "telemetry": cluster.telemetry_snapshot(),
    }

    # Bit-rot guards.
    assert len(active) >= 2, result
    assert all(s.conserved() for s in sessions), result
    check_finite("ingest throughput", throughput)
    ingested = sum(s.frames_ingested for s in active)
    assert agg["completed_frames"] + agg["dropped_frames"] == ingested, result
    for name, sl in slices.items():
        # THE hot-loop bars: zero decode recompiles on staged traffic,
        # zero fresh host allocations (rings reuse their scratch pool).
        assert sl.engine.stats["decode_compiles"] == 0, (name, result)
        for ring in sl.engine._rings.values():
            assert ring.host_allocs == ring.depth, (name, ring.shape, result)
        # Real ingestion: payload bytes actually moved host -> device.
        assert sl.engine.staging_bytes > 0, (name, result)
    return result


# ---------------------------------------------------------------------------
# Arm 2: shedding A/B under 2x overload (deterministic simulation)
# ---------------------------------------------------------------------------


def _sim_table() -> ProfileTable:
    table = ProfileTable()
    for b in (1, 2, 4, 8, 16, 32):
        table.record("m", (4,), b, 0.01 + 0.04 * b)
    return table


def shedding_arm(smoke: bool) -> Dict:
    n_frames = 24 if smoke else 60
    cat = Category("m", (4,))
    arms = {}
    for label, shedding in (("no_shed", False), ("shed", True)):
        sched = DeepRT(_sim_table())
        tracer = FrameTracer()
        sched.attach_tracer(tracer, tag=label)
        gw = IngestGateway(sched, shedding=shedding)
        gw.tracer = tracer
        # Declared: 1 frame / 0.1s (admissible, U ~= 0.9 at the window
        # batch); delivered: the same budget at 2x in bursts of 4.
        src = BurstSource(
            period=0.1, n_frames=n_frames, burst=4, duty=0.5,
            payload_shape=(4,), seed=11,
        )
        session = gw.register(src, cat, relative_deadline=0.2)
        assert session.state == "active", (label, session.state)
        m = sched.run()
        arms[label] = {
            "ingested": session.frames_ingested,
            "delivered": session.frames_delivered,
            "dropped": m.dropped_frames,
            "completed": m.completed_frames,
            "missed": m.missed_frames,
            "miss_rate": m.miss_rate,
            "mean_e2e_latency": m.mean_e2e_latency,
            "e2e_p99": m.e2e_percentile(0.99),
            "telemetry": {
                "terminals": dict(tracer.terminals),
                "attribution": tracer.attribution(),
            },
        }
        # Conservation: nothing silently vanishes.
        assert session.conserved(), (label, arms[label])
        assert m.completed_frames + m.dropped_frames == n_frames, arms[label]
        # Deadline-miss attribution closes: every missed frame's
        # per-stage budget breakdown sums to its observed latency.
        assert len(tracer.miss_log) == m.missed_frames, label
        for entry in tracer.miss_log:
            assert abs(sum(entry["stages"].values()) - entry["total"]) \
                < 1e-9, (label, entry)
        # Trace-level conservation matches the metrics identity.
        assert sum(tracer.terminals.values()) == session.frames_ingested, (
            label, tracer.terminals)

    # THE acceptance bar: adaptation-driven shedding strictly reduces
    # deadline misses under the overload, by actually dropping frames.
    assert arms["no_shed"]["missed"] > 0, arms
    assert arms["shed"]["missed"] < arms["no_shed"]["missed"], arms
    assert arms["shed"]["dropped"] > 0, arms
    assert arms["no_shed"]["dropped"] == 0, arms
    return {"overload_factor": 2.0, "frames": n_frames, "arms": arms}


# ---------------------------------------------------------------------------


def main(smoke: bool = False) -> List[str]:
    live = live_staged_arm(smoke)
    shed = shedding_arm(smoke)
    result = {"live_staged": live, "overload_shedding": shed}

    if not smoke:
        with open(os.path.join(REPO_ROOT, "BENCH_ingest_serving.json"), "w") as f:
            json.dump(result, f, indent=1)
        write_csv(
            "ingest_serving",
            ["metric", "value"],
            [
                ["active_sessions", live["active_sessions"]],
                ["completed_frames", live["completed_frames"]],
                ["miss_rate", live["miss_rate"]],
                ["mean_e2e_latency", live["mean_e2e_latency"]],
                ["throughput_frames_per_sec",
                 live["throughput_frames_per_sec"]],
                ["bytes_per_step_slice0",
                 live["per_slice"]["slice0"]["bytes_per_step"]],
                ["bytes_per_step_slice1",
                 live["per_slice"]["slice1"]["bytes_per_step"]],
                ["overload_miss_rate_no_shed",
                 shed["arms"]["no_shed"]["miss_rate"]],
                ["overload_miss_rate_shed", shed["arms"]["shed"]["miss_rate"]],
                ["overload_dropped_shed", shed["arms"]["shed"]["dropped"]],
            ],
        )

    lines = [
        f"ingest_serving,active_sessions,{live['active_sessions']}"
        f"/{live['registered_sessions']}",
        f"ingest_serving,completed_frames,{live['completed_frames']}",
        f"ingest_serving,miss_rate,{live['miss_rate']:.3f}",
        f"ingest_serving,mean_e2e_latency_ms,"
        f"{live['mean_e2e_latency'] * 1e3:.2f}",
        f"ingest_serving,throughput_fps,"
        f"{live['throughput_frames_per_sec']:.1f}",
    ]
    for name, ps in live["per_slice"].items():
        lines.append(
            f"ingest_serving,{name}_bytes_per_step,{ps['bytes_per_step']:.1f}"
            f" (decode_recompiles {ps['decode_compiles']},"
            f" host_allocs {ps['staging_host_allocs']}"
            f" over {ps['staging_rings']} rings)"
        )
    a = shed["arms"]
    lines.append(
        f"ingest_serving,overload_2x_miss_rate,"
        f"no_shed {a['no_shed']['miss_rate']:.3f} -> "
        f"shed {a['shed']['miss_rate']:.3f} "
        f"(dropped {a['shed']['dropped']}/{shed['frames']}, accounted)"
    )
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny shapes, short streams, no JSON rewrite (CI bit-rot guard)",
    )
    args = ap.parse_args()
    for line in main(smoke=args.smoke):
        print(line)
