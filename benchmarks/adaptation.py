"""Paper Fig 10: recovery from injected overruns.

5 consecutive job instances get an injected extra wait (100/200/500/
1000 ms); count deadline misses with the Adaptation Module enabled vs
disabled. Adaptation shrinks the category's shape until the penalty is
repaid, so misses should be no worse — and typically strictly fewer for
the larger injections.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import paper_table, paper_trace, write_csv
from repro.core import DeepRT, ExecutionModel


def run(inject_s: float, enabled: bool, seed: int = 0) -> int:
    table = paper_table()
    # Paper: periods/deadlines 200 ms (desktop experiment).
    reqs = paper_trace(0.2, 0.2, seed=seed, n_requests=12)
    count = {"n": 0}

    def actual_fn(job, wcet):
        count["n"] += 1
        # Inject into 5 consecutive jobs mid-run (paper protocol).
        if 40 <= count["n"] < 45:
            return wcet + inject_s
        return 0.93 * wcet

    sched = DeepRT(
        table,
        execution=ExecutionModel(actual_fn=actual_fn),
        adaptation_enabled=enabled,
    )
    for r in reqs:
        sched.submit_request(r)
    m = sched.run()
    return m.missed_frames


def main() -> List[str]:
    rows, lines = [], []
    for inject in [0.1, 0.2, 0.5, 1.0]:
        on = sum(run(inject, True, s) for s in range(3))
        off = sum(run(inject, False, s) for s in range(3))
        rows.append([inject, on, off])
        lines.append(f"fig10,inject_{inject}s,misses_adapt_on_vs_off,{on}|{off}")
        assert on <= off + 2, "adaptation made things materially worse"
    write_csv(
        "fig10_adaptation", ["inject_s", "misses_adapt_on", "misses_adapt_off"], rows
    )
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
