"""Serving hot-path A/B: async zero-stall dispatch vs. the legacy
blocking path, donated vs. copying KV caches, masked vs. blind padding.

Establishes the perf trajectory baseline for the live pipeline:

- scheduler overhead per job (µs): host-side loop stall per dispatch
  decision, measured by the EDF worker. Async dispatch submits and
  returns; the blocking path stalls for the whole device execution.
- decode steps/sec at batch {1, 2, 4, 8}: donated in-place caches +
  preallocated staging vs. the old copy-every-step engine.
- padding-waste fraction: measured attended-KV-slot waste with blind
  power-of-two padding vs. the masked validity-bitmap path, over a
  mixed-true-batch workload.

Writes ``BENCH_serving_hotpath.json`` at the repo root (plus the usual
CSV under benchmarks/results/) so successive PRs can track the numbers.

    PYTHONPATH=src python -m benchmarks.serving_hotpath
"""
from __future__ import annotations

import copy
import json
import os
import time
from typing import Dict, List

from benchmarks.common import write_csv
from repro.configs.registry import tiny
from repro.core import Category, Request
from repro.serving.batcher_bridge import build_live_scheduler
from repro.serving.engine import InferenceEngine

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MID = "granite-3-2b"
SEQ = 32
DECODE_BATCHES = (1, 2, 4, 8)
MIXED_TRUE_BATCHES = (1, 3, 5, 6, 7, 8)  # non-pow2-heavy: padding stress


def _scheduler_overhead(dispatch: str, n_frames: int = 12) -> Dict[str, float]:
    """Run the same admitted workload through the live scheduler in the
    given dispatch mode; report host-stall per job."""
    configs = {MID: tiny(MID)}
    sched, engine, table = build_live_scheduler(
        configs, [(MID, (SEQ,), "prefill")], batch_sizes=(1, 2, 4),
        dispatch=dispatch,
    )
    w1 = table.wcet(MID, (SEQ,), 1)
    req = Request(
        category=Category(MID, (SEQ,)),
        period=max(w1 * 4, 0.02),
        relative_deadline=max(w1 * 24, 0.25),
        n_frames=n_frames,
    )
    res = sched.submit_request(req)
    assert res.admitted, f"{dispatch}: probe request rejected"
    m = sched.run()
    assert m.completed_frames == n_frames, (dispatch, m.completed_frames)
    return {
        "overhead_us_per_job": m.mean_dispatch_overhead * 1e6,
        "jobs": m.job_count,
        "miss_rate": m.miss_rate,
    }


def _decode_rate(donate: bool, steps: int = 30) -> Dict[int, float]:
    """Steady-state decode steps/sec per batch bucket."""
    engine = InferenceEngine({MID: tiny(MID)}, donate_cache=donate)
    rates: Dict[int, float] = {}
    for b in DECODE_BATCHES:
        engine.execute(MID, (SEQ,), b, kind="decode")  # compile + warm
        engine.execute(MID, (SEQ,), b, kind="decode")
        t0 = time.perf_counter()
        for _ in range(steps):
            h = engine.dispatch(MID, (SEQ,), b, kind="decode")
        h.wait()  # pipelined: block once at the end
        rates[b] = steps / (time.perf_counter() - t0)
    return rates


def _padding_waste(masked: bool) -> float:
    """Measured attended-slot waste over a mixed true-batch decode mix."""
    engine = InferenceEngine({MID: tiny(MID)}, masked_decode=masked)
    for b in MIXED_TRUE_BATCHES:
        engine.execute(MID, (SEQ,), b, kind="decode")
    return engine.padding_waste


def main() -> List[str]:
    sync = _scheduler_overhead("sync")
    asyn = _scheduler_overhead("async")
    rate_copy = _decode_rate(donate=False)
    rate_donate = _decode_rate(donate=True)
    waste_blind = _padding_waste(masked=False)
    waste_masked = _padding_waste(masked=True)

    result = {
        "scheduler_overhead_per_job_us": {
            "sync_blocking": sync["overhead_us_per_job"],
            "async_dispatch": asyn["overhead_us_per_job"],
            "improvement_x": (
                sync["overhead_us_per_job"] / max(asyn["overhead_us_per_job"], 1e-9)
            ),
        },
        "decode_steps_per_sec": {
            str(b): {"copy": rate_copy[b], "donated": rate_donate[b]}
            for b in DECODE_BATCHES
        },
        "padding_waste_fraction": {
            "blind_pow2": waste_blind,
            "masked_bitmap": waste_masked,
        },
        "miss_rate": {"sync": sync["miss_rate"], "async": asyn["miss_rate"]},
    }
    with open(os.path.join(REPO_ROOT, "BENCH_serving_hotpath.json"), "w") as f:
        json.dump(result, f, indent=1)
    write_csv(
        "serving_hotpath",
        ["metric", "before", "after"],
        [
            ["scheduler_overhead_us", sync["overhead_us_per_job"],
             asyn["overhead_us_per_job"]],
            ["padding_waste", waste_blind, waste_masked],
        ]
        + [
            [f"decode_steps_per_sec_b{b}", rate_copy[b], rate_donate[b]]
            for b in DECODE_BATCHES
        ],
    )

    # The acceptance bar: strictly improved on both headline axes.
    assert asyn["overhead_us_per_job"] < sync["overhead_us_per_job"], result
    assert waste_masked < waste_blind, result

    lines = [
        f"serving_hotpath,scheduler_overhead_us_sync,{sync['overhead_us_per_job']:.1f}",
        f"serving_hotpath,scheduler_overhead_us_async,{asyn['overhead_us_per_job']:.1f}",
        f"serving_hotpath,padding_waste_blind,{waste_blind:.4f}",
        f"serving_hotpath,padding_waste_masked,{waste_masked:.4f}",
    ]
    for b in DECODE_BATCHES:
        lines.append(
            f"serving_hotpath,decode_steps_per_sec_b{b},"
            f"{rate_donate[b]:.1f} (copy {rate_copy[b]:.1f})"
        )
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
