"""Serving hot-path benchmark: async zero-stall dispatch, slot-arena
decode (one program, one resident KV arena per model), donated vs
copying arenas, masked vs blind padding.

Headline scenarios:

- scheduler overhead per job (µs): host-side loop stall per dispatch
  decision, measured by the EDF worker under async dispatch. The legacy
  blocking path is DELETED (ROADMAP note); its recorded numbers from the
  last run that still had it are replayed as the before-arm.
- decode steps/sec at batch {1, 2, 4, 8}: the slot arena under donated
  (in-place) vs copying cache semantics. On CPU jax donation is honored
  (buffers alias) but charges a fixed per-dispatch bookkeeping cost that
  swamps the avoided copy at these model sizes, so the engine gates its
  default by backend — both arms are still measured here.
- padding-waste fraction: measured attended-KV-slot waste with blind
  full-arena work vs the active-bitmap path (dead rows skip all KV
  blocks), over a mixed-true-batch workload.
- bucket transition: a batch-size sweep 1 -> max_slots -> 1 crossing
  every former power-of-two bucket boundary. The arena arm must show
  ZERO decode compiles after warm-up and no step-time spike at former
  boundaries; the per-bucket arm (the pre-arena engine behavior,
  reconstructed locally — the engine itself no longer has it) shows the
  lazy-compile stall + cold cache per new bucket that used to blow
  deadlines.

Writes ``BENCH_serving_hotpath.json`` at the repo root (plus the usual
CSV under benchmarks/results/) so successive PRs can track the numbers.

    PYTHONPATH=src python -m benchmarks.serving_hotpath [--smoke]

``--smoke`` (CI): tiny shapes, few steps, no root-JSON rewrite — it
exists to catch bench bit-rot (import errors, NaN/zero throughput)
before a perf PR needs the numbers, not to produce stable timings.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import check_finite as _check_finite
from benchmarks.common import write_csv
from repro.configs.registry import tiny
from repro.core import Category, Request
from repro.core.bucketing import bucket
from repro.models import model_for
from repro.serving.batcher_bridge import build_live_scheduler
from repro.serving.engine import InferenceEngine

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MID = "granite-3-2b"
SEQ = 32
MAX_SLOTS = 8
DECODE_BATCHES = (1, 2, 4, 8)
MIXED_TRUE_BATCHES = (1, 3, 5, 6, 7, 8)  # non-pow2-heavy: padding stress

# Recorded output of the deleted blocking-dispatch path (last measured in
# the PR-1 BENCH_serving_hotpath.json on this container, commit 7faae7e).
# Replayed as the before-arm per the ROADMAP note — the dead code is not
# kept alive just to re-time it.
RECORDED_SYNC = {"overhead_us_per_job": 1983.1, "miss_rate": 0.0}


def _scheduler_overhead(n_frames: int = 12, seq: int = SEQ) -> Dict[str, float]:
    """Run an admitted workload through the live async scheduler; report
    host-stall per dispatch decision."""
    configs = {MID: tiny(MID)}
    sched, engine, table = build_live_scheduler(
        configs, [(MID, (seq,), "prefill")], batch_sizes=(1, 2, 4),
    )
    w1 = table.wcet(MID, (seq,), 1)
    req = Request(
        category=Category(MID, (seq,)),
        period=max(w1 * 4, 0.02),
        relative_deadline=max(w1 * 24, 0.25),
        n_frames=n_frames,
    )
    res = sched.submit_request(req)
    assert res.admitted, "async: probe request rejected"
    m = sched.run()
    assert m.completed_frames == n_frames, ("async", m.completed_frames)
    return {
        "overhead_us_per_job": m.mean_dispatch_overhead * 1e6,
        "jobs": m.job_count,
        "miss_rate": m.miss_rate,
    }


def _decode_rate(
    donate: bool, steps: int = 30, seq: int = SEQ, max_slots: int = MAX_SLOTS,
    batches=DECODE_BATCHES,
) -> Dict[int, float]:
    """Steady-state decode steps/sec per batch size on the slot arena."""
    engine = InferenceEngine(
        {MID: tiny(MID)}, donate_cache=donate, max_slots=max_slots
    )
    rates: Dict[int, float] = {}
    for b in batches:
        engine.execute(MID, (seq,), b, kind="decode")  # compile + warm
        engine.execute(MID, (seq,), b, kind="decode")
        t0 = time.perf_counter()
        for _ in range(steps):
            h = engine.dispatch(MID, (seq,), b, kind="decode")
        h.wait()  # pipelined: block once at the end
        rates[b] = steps / (time.perf_counter() - t0)
    return rates


def _padding_waste(masked: bool, seq: int = SEQ, max_slots: int = MAX_SLOTS,
                   batches=MIXED_TRUE_BATCHES) -> float:
    """Measured attended-slot waste over a mixed true-batch decode mix."""
    engine = InferenceEngine(
        {MID: tiny(MID)}, masked_decode=masked, max_slots=max_slots
    )
    for b in batches:
        if b <= max_slots:
            engine.execute(MID, (seq,), b, kind="decode")
    return engine.padding_waste


class _LegacyPerBucketDecode:
    """The pre-arena decode path, reconstructed for the A/B only.

    One lazily-compiled program AND one separate KV cache per batch
    bucket — exactly what the engine did before the slot arena (and what
    the arena deleted). A job crossing a bucket boundary hits a cold
    program (compile stall on the serving thread) and a cold cache.
    Token and cursor staging are preallocated per (bucket, true batch),
    matching the pre-arena engine's synthetic staging buffers (the
    ``_stage`` path that PR 4's ingestion rings later deleted), so the
    steady-state comparison is fair — the arms differ only in program/
    cache granularity.
    """

    def __init__(self, cfg, seq: int):
        self.model = model_for(cfg)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.seq = seq
        self._compiled: Dict[int, object] = {}
        self._caches: Dict[int, object] = {}
        self._tok: Dict[int, object] = {}
        self._cur: Dict[tuple, object] = {}
        self.compiles = 0

    def step(self, k: int) -> None:
        b = bucket(k)
        if b not in self._compiled:
            self.compiles += 1
            model = self.model

            def run(params, cache, tok, cur):
                return model.decode_step(params, cache, tok, cur)

            self._compiled[b] = jax.jit(run)
        if b not in self._caches:
            self._caches[b] = self.model.init_cache(b, self.seq)
        if b not in self._tok:
            self._tok[b] = jnp.zeros((b,), jnp.int32)
        if (b, k) not in self._cur:
            self._cur[(b, k)] = jnp.concatenate(
                [
                    jnp.full((k,), self.seq - 1, jnp.int32),
                    jnp.zeros((b - k,), jnp.int32),
                ]
            )
        logits, cache = self._compiled[b](
            self.params, self._caches[b], self._tok[b], self._cur[(b, k)]
        )
        self._caches[b] = cache
        jax.block_until_ready(logits)


def _bucket_transition(
    seq: int = SEQ, max_slots: int = MAX_SLOTS
) -> Dict[str, object]:
    """Batch-size sweep crossing every former bucket boundary, per-step
    latency measured synchronously. Both arms warm up ONCE at batch 1."""
    up = list(range(1, max_slots + 1))
    sweep = up + up[-2::-1] + up[1:]  # 1..max..1..max: re-cross boundaries

    # --- slot arena arm ---------------------------------------------------
    engine = InferenceEngine({MID: tiny(MID)}, max_slots=max_slots)
    engine.execute(MID, (seq,), 1, kind="decode")  # the ONE compile
    engine.reset_stats()  # compiles counted from here = after warm-up
    arena_ms = [
        engine.execute(MID, (seq,), k, kind="decode") * 1e3 for k in sweep
    ]

    # --- legacy per-bucket arm -------------------------------------------
    legacy = _LegacyPerBucketDecode(tiny(MID), seq)
    legacy.step(1)  # warm bucket 1
    warm_compiles = legacy.compiles
    legacy_ms = []
    for k in sweep:
        t0 = time.perf_counter()
        legacy.step(k)
        legacy_ms.append((time.perf_counter() - t0) * 1e3)

    def summarize(ms: List[float]) -> Dict[str, float]:
        med = statistics.median(ms)
        return {
            "median_ms": med,
            "max_ms": max(ms),
            "spike_x": max(ms) / med if med > 0 else float("inf"),
        }

    return {
        "sweep": sweep,
        "arena": dict(
            summarize(arena_ms),
            compiles_after_warmup=engine.stats["decode_compiles"],
        ),
        "per_bucket": dict(
            summarize(legacy_ms),
            compiles_after_warmup=legacy.compiles - warm_compiles,
        ),
    }


def main(smoke: bool = False) -> List[str]:
    if smoke:
        seq, max_slots, steps = 16, 4, 4
        batches = (1, 2, 4)
    else:
        seq, max_slots, steps = SEQ, MAX_SLOTS, 30
        batches = DECODE_BATCHES

    asyn = _scheduler_overhead(n_frames=6 if smoke else 12, seq=seq)
    rate_copy = _decode_rate(False, steps, seq, max_slots, batches)
    rate_donate = _decode_rate(True, steps, seq, max_slots, batches)
    waste_blind = _padding_waste(False, seq, max_slots)
    waste_masked = _padding_waste(True, seq, max_slots)
    transition = _bucket_transition(seq, max_slots)

    result = {
        "scheduler_overhead_per_job_us": {
            "sync_blocking_recorded": RECORDED_SYNC["overhead_us_per_job"],
            "async_dispatch": asyn["overhead_us_per_job"],
            "improvement_x": (
                RECORDED_SYNC["overhead_us_per_job"]
                / max(asyn["overhead_us_per_job"], 1e-9)
            ),
        },
        "decode_steps_per_sec": {
            str(b): {"copy": rate_copy[b], "donated": rate_donate[b]}
            for b in batches
        },
        "donate_cache_default": {
            "backend": jax.default_backend(),
            "donate": jax.default_backend() != "cpu",
            "rationale": (
                "CPU XLA honors donation (buffers alias across steps) but "
                "adds a fixed per-dispatch donation bookkeeping cost that "
                "exceeds the avoided O(cache) copy at these model sizes — "
                "measured ~50us+ per jitted call on this container; on "
                "tpu/gpu the copy dominates and donation is the default."
            ),
        },
        "padding_waste_fraction": {
            "blind_full_arena": waste_blind,
            "masked_bitmap": waste_masked,
        },
        "bucket_transition": transition,
        "miss_rate": {
            "sync_recorded": RECORDED_SYNC["miss_rate"],
            "async": asyn["miss_rate"],
        },
    }

    # Bit-rot guards (what --smoke exists for): every throughput finite
    # and positive, padding accounting sane, arena invariants hold.
    for b in batches:
        _check_finite(f"decode copy b={b}", rate_copy[b])
        _check_finite(f"decode donated b={b}", rate_donate[b])
    _check_finite("async overhead", asyn["overhead_us_per_job"])
    assert waste_masked < waste_blind, result["padding_waste_fraction"]
    # The acceptance bar of the slot arena: zero decode recompiles after
    # warm-up across the full sweep (old path: one per bucket), and no
    # compile-sized step spike at former bucket boundaries.
    arena_t = transition["arena"]
    legacy_t = transition["per_bucket"]
    assert arena_t["compiles_after_warmup"] == 0, transition
    assert legacy_t["compiles_after_warmup"] >= 1, transition
    assert arena_t["spike_x"] < legacy_t["spike_x"], transition
    if not smoke:
        # Wall-clock comparison against the recorded sync numbers is a
        # same-machine claim — skip it in CI smoke, where a slow runner
        # would fail on timing rather than breakage.
        assert (
            asyn["overhead_us_per_job"] < RECORDED_SYNC["overhead_us_per_job"]
        ), result

    if not smoke:
        with open(os.path.join(REPO_ROOT, "BENCH_serving_hotpath.json"), "w") as f:
            json.dump(result, f, indent=1)
        write_csv(
            "serving_hotpath",
            ["metric", "before", "after"],
            [
                ["scheduler_overhead_us", RECORDED_SYNC["overhead_us_per_job"],
                 asyn["overhead_us_per_job"]],
                ["padding_waste", waste_blind, waste_masked],
                ["decode_compiles_after_warmup",
                 legacy_t["compiles_after_warmup"],
                 arena_t["compiles_after_warmup"]],
                ["bucket_transition_spike_x", legacy_t["spike_x"],
                 arena_t["spike_x"]],
            ]
            + [
                [f"decode_steps_per_sec_b{b}", rate_copy[b], rate_donate[b]]
                for b in batches
            ],
        )

    lines = [
        f"serving_hotpath,scheduler_overhead_us_sync_recorded,"
        f"{RECORDED_SYNC['overhead_us_per_job']:.1f}",
        f"serving_hotpath,scheduler_overhead_us_async,{asyn['overhead_us_per_job']:.1f}",
        f"serving_hotpath,padding_waste_blind,{waste_blind:.4f}",
        f"serving_hotpath,padding_waste_masked,{waste_masked:.4f}",
        f"serving_hotpath,decode_compiles_after_warmup_arena,"
        f"{arena_t['compiles_after_warmup']}",
        f"serving_hotpath,decode_compiles_after_warmup_per_bucket,"
        f"{legacy_t['compiles_after_warmup']}",
        f"serving_hotpath,bucket_transition_spike_arena,{arena_t['spike_x']:.2f}x",
        f"serving_hotpath,bucket_transition_spike_per_bucket,"
        f"{legacy_t['spike_x']:.2f}x",
    ]
    for b in batches:
        lines.append(
            f"serving_hotpath,decode_steps_per_sec_b{b},"
            f"copy {rate_copy[b]:.1f} / donated {rate_donate[b]:.1f}"
        )
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny shapes, few steps, no JSON rewrite (CI bit-rot guard)",
    )
    args = ap.parse_args()
    for line in main(smoke=args.smoke):
        print(line)
