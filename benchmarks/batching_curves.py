"""Paper Fig 2c/2d with REAL execution: batch size vs latency/throughput
of jit-compiled models on this host (tiny configs — the identical harness
runs full configs on a TPU). Validates the monotonicity assumptions the
ProfileTable relies on (latency non-decreasing, throughput increasing)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import write_csv


def main() -> List[str]:
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import tiny
    from repro.models import model_for

    rows = []
    lines = []
    for arch in ["granite-3-2b", "rwkv6-1.6b"]:
        cfg = tiny(arch)
        model = model_for(cfg)
        params = model.init(jax.random.PRNGKey(0))
        seq = 64

        def step(tokens):
            logits, _ = model.forward(params, tokens)
            return logits[:, -1].argmax(-1)

        jitted = jax.jit(step)
        prev_lat = 0.0
        series = []
        for b in [1, 2, 4, 8, 16]:
            toks = jnp.zeros((b, seq), jnp.int32)
            jitted(toks).block_until_ready()  # compile+warm
            ts = []
            for _ in range(5):
                t0 = time.perf_counter()
                jitted(toks).block_until_ready()
                ts.append(time.perf_counter() - t0)
            lat = sorted(ts)[len(ts) // 2]
            thpt = b / lat
            rows.append([arch, b, lat, thpt])
            series.append((b, lat, thpt))
        lines.append(
            f"fig2cd_real,{arch},batch16_vs_batch1_thpt_gain,"
            f"{series[-1][2] / series[0][2]:.2f}"
        )
    write_csv(
        "fig2cd_batching_real",
        ["arch", "batch", "median_latency_s", "throughput_seq_per_s"],
        rows,
    )
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
