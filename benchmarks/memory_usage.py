"""Paper Fig 6: peak memory of DeepRT vs the concurrent baselines.

Tracked as live batch-buffer bytes on the device models (sequential
execution holds at most one batch; concurrent baselines stack batches
across categories — the effect the paper measures with nvidia-smi).
"""
from __future__ import annotations

import copy
from typing import List

from benchmarks.common import frame_bytes, paper_table, paper_trace, write_csv
from repro.core import AIMD, BATCH, BATCHDelay, DeepRT, ExecutionModel


def job_bytes(job) -> float:
    shape = getattr(job, "shape_key", None) or job.category.shape_key
    return frame_bytes(shape) * job.batch_size


def run(mean_pd: float, seed: int) -> List[List]:
    table = paper_table()
    reqs = paper_trace(mean_pd, mean_pd, seed=seed)
    deep = DeepRT(
        table, execution=ExecutionModel(actual_fn=lambda j, w: 0.95 * w),
        adaptation_enabled=False,
    )
    deep.worker.job_bytes_fn = job_bytes
    accepted = [copy.deepcopy(r) for r in reqs if deep.submit_request(r).admitted]
    deep.run()
    rows = [["DeepRT", mean_pd, seed, deep.device.peak_bytes / 1e6]]
    for name, mk in [
        ("AIMD", lambda t: AIMD(t, actual_fn=lambda j, w: 0.95 * w)),
        ("BATCH", lambda t: BATCH(t, actual_fn=lambda j, w: 0.95 * w, batch_size=4)),
        ("BATCH-Delay", lambda t: BATCHDelay(
            t, actual_fn=lambda j, w: 0.95 * w, batch_size=4, max_delay=mean_pd / 2
        )),
    ]:
        sched = mk(table)
        sched.job_bytes_fn = job_bytes
        for r in accepted:
            sched.submit_request(copy.deepcopy(r))
        sched.run()
        rows.append([name, mean_pd, seed, sched.device.peak_bytes / 1e6])
    return rows


def main() -> List[str]:
    rows = []
    for mean_pd in [0.05, 0.15, 0.25]:
        for seed in (0, 1):
            rows += run(mean_pd, seed)
    write_csv("fig6_peak_memory", ["scheduler", "trace", "seed", "peak_mb"], rows)
    agg = {}
    for r in rows:
        agg.setdefault(r[0], []).append(r[3])
    return [
        f"fig6,{k},mean_peak_batch_mb,{sum(v)/len(v):.1f}" for k, v in agg.items()
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
