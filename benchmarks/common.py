"""Shared fixtures for the benchmark harness.

WCET tables are calibrated to the paper's own measurements (Table 1
single-model execution times on the RTX 2080, batching slopes from Fig
2c): E(model, resolution, b) = (a + c*b) * pixel_scale. The same tables
drive DeepRT and every baseline, so comparisons isolate SCHEDULING — the
paper's methodology.
"""
from __future__ import annotations

import csv
import math
import os
import random
from typing import Dict, List, Tuple

from repro.core import Category, ProfileTable, Request, TraceSpec, generate_trace

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# Paper Table 1 "-" column: solo execution time at batch 1 (seconds).
PAPER_BATCH1 = {
    "resnet50": 0.0035,
    "resnet101": 0.0064,
    "resnet152": 0.0090,
    "vgg16": 0.0045,
    "vgg19": 0.0053,
    "inception_v3": 0.0093,
    "mobilenet_v2": 0.0020,
}
# Marginal per-image cost as a fraction of the batch-1 cost (Fig 2c shows
# sub-linear batching: batch 8 ≈ 3-4x batch 1).
BATCH_SLOPE = 0.35

RESOLUTIONS = [(3, 224, 224), (3, 240, 352), (3, 480, 854), (3, 1080, 1920)]


def check_finite(tag: str, value: float) -> None:
    """NaN/zero/negative guard for benchmark headline numbers (what the
    CI --smoke arms exist to catch)."""
    if not math.isfinite(value) or value <= 0:
        raise AssertionError(f"{tag} is NaN/zero/negative: {value}")


def pixel_scale(shape: Tuple[int, ...]) -> float:
    return (shape[1] * shape[2]) / (224.0 * 224.0)


def paper_table(models=None, resolutions=None, max_batch: int = 256) -> ProfileTable:
    table = ProfileTable()
    models = models or list(PAPER_BATCH1)
    resolutions = resolutions or RESOLUTIONS
    for m in models:
        a = PAPER_BATCH1[m]
        for shape in resolutions:
            s = pixel_scale(shape)
            # Also profile the adaptation module's reduced shapes.
            for res in [shape, (shape[0], shape[1] // 2, shape[2] // 2)]:
                sc = pixel_scale(res)
                b = 1
                while b <= max_batch:
                    table.record(m, res, b, (a + a * BATCH_SLOPE * (b - 1)) * max(sc, 0.05))
                    b *= 2
    return table


def paper_trace(
    mean_period: float,
    mean_deadline: float,
    seed: int = 0,
    n_requests: int = 25,
    models=("resnet50", "resnet101", "vgg16", "mobilenet_v2"),
    resolutions=((3, 224, 224), (3, 240, 352)),
    frames=(30, 120),
    mean_interarrival: float = 1.0,
) -> List[Request]:
    return generate_trace(
        TraceSpec(
            mean_period=mean_period,
            mean_deadline=mean_deadline,
            n_requests=n_requests,
            frames_per_request=frames,
            models=models,
            shapes=resolutions,
            max_categories=4,
            mean_interarrival=mean_interarrival,
            seed=seed,
        )
    )


def frame_bytes(shape: Tuple[int, ...]) -> float:
    import math

    n = 1
    for d in shape:
        n *= d
    return 4.0 * n  # f32 input tensors


def write_csv(name: str, header: List[str], rows: List[List]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path
