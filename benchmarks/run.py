"""Benchmark harness: one module per paper table/figure (+ roofline).

Each module's main() writes a CSV under benchmarks/results/ and returns
headline ``name,metric,value`` lines, printed here. Run:

    PYTHONPATH=src python -m benchmarks.run [--only fig4,fig7]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("fig2_table1_interference", "benchmarks.interference"),
    ("fig2cd_batching_real", "benchmarks.batching_curves"),
    ("fig4_fig5_miss_rates", "benchmarks.miss_rates"),
    ("fig6_memory", "benchmarks.memory_usage"),
    ("fig7_throughput_vs_sedf", "benchmarks.throughput_vs_sedf"),
    ("fig8_imitator_accuracy", "benchmarks.imitator_accuracy"),
    ("fig9_admission_runtime", "benchmarks.admission_runtime"),
    ("fig10_adaptation", "benchmarks.adaptation"),
    ("roofline_table", "benchmarks.roofline_report"),
    ("serving_hotpath", "benchmarks.serving_hotpath"),
    ("cluster_serving", "benchmarks.cluster_serving"),
    ("ingest_serving", "benchmarks.ingest_serving"),
    ("fault_tolerance", "benchmarks.fault_tolerance"),
    ("transport_robustness", "benchmarks.transport_robustness"),
    ("transport_churn", "benchmarks.transport_churn"),
    ("decode_chunking", "benchmarks.decode_chunking"),
    ("telemetry_overhead", "benchmarks.telemetry_overhead"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite filters")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    import importlib

    failures = 0
    for name, module in SUITES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            lines = mod.main()
            for line in lines:
                print(line)
            print(f"# {name}: done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name}: FAILED\n{traceback.format_exc()}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suite(s) failed")


if __name__ == "__main__":
    main()
