"""Paper Fig 7: admitted requests + throughput, DeepRT vs Sequential EDF.

Saturating traces (high request arrival frequency); each scheduler runs
its OWN admission control over the same pending set (paper §6.3
protocol). DeepRT should admit >= SEDF and win on throughput as the mean
deadline grows (bigger windows -> bigger batches).
"""
from __future__ import annotations

import copy
from typing import List

from benchmarks.common import paper_table, paper_trace, write_csv
from repro.core import SEDF, DeepRT, ExecutionModel


def run_pair(mean_pd: float, seed: int):
    table = paper_table()
    # Saturation per paper §6.3: increase the REQUEST arrival frequency
    # (not the frame rate) so many same-category streams overlap and the
    # DisBatcher can aggregate real batches.
    reqs = paper_trace(
        mean_pd, mean_pd, seed=seed, n_requests=60, mean_interarrival=0.08,
        frames=(60, 180),
    )
    deep = DeepRT(table, execution=ExecutionModel(actual_fn=lambda j, w: 0.95 * w))
    n_deep = sum(
        deep.submit_request(copy.deepcopy(r)).admitted for r in reqs
    )
    m_deep = deep.run()
    sedf = SEDF(table, actual_fn=lambda j, w: 0.95 * w)
    n_sedf = sum(sedf.submit_request(copy.deepcopy(r)) for r in reqs)
    m_sedf = sedf.run()
    return (n_deep, m_deep), (n_sedf, m_sedf)


def main(seeds=(0, 1, 2)) -> List[str]:
    rows = []
    summary = {}
    for mean_pd in [0.05, 0.15, 0.25]:
        acc = {"DeepRT": [0, 0.0], "SEDF": [0, 0.0]}
        for seed in seeds:
            (nd, md), (ns, ms) = run_pair(mean_pd, seed)
            rows.append(["DeepRT", mean_pd, seed, nd, md.completed_frames,
                         md.throughput, md.mean_batch, md.miss_rate])
            rows.append(["SEDF", mean_pd, seed, ns, ms.completed_frames,
                         ms.throughput, ms.mean_batch, ms.miss_rate])
            acc["DeepRT"][0] += nd
            acc["DeepRT"][1] += md.throughput
            acc["SEDF"][0] += ns
            acc["SEDF"][1] += ms.throughput
        summary[mean_pd] = {
            k: (v[0] / len(seeds), v[1] / len(seeds)) for k, v in acc.items()
        }
    write_csv(
        "fig7_throughput_vs_sedf",
        ["scheduler", "mean_pd", "seed", "admitted", "completed",
         "throughput_fps", "mean_batch", "miss_rate"],
        rows,
    )
    lines = []
    for mean_pd, s in summary.items():
        ratio = s["DeepRT"][1] / max(s["SEDF"][1], 1e-9)
        lines.append(
            f"fig7,trace_{mean_pd},deepRT_vs_sedf_throughput_ratio,{ratio:.2f}"
        )
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
