"""Paper Fig 2a/2b + Table 1: concurrency & interference characterization.

TPUs have no CUDA-style context multiplexing (DESIGN.md §2), so this is
the one benchmark that runs entirely on the time-sliced concurrency
MODEL (ProcessorSharingDevice), reproducing the paper's measured shape:
execution time grows ~linearly with concurrency while throughput
saturates — the analysis that motivates sequential execution + batching.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import PAPER_BATCH1, paper_table, write_csv
from repro.core import Category, EventLoop, ProcessorSharingDevice


def run_concurrency(model: str, concurrency: int, horizon: float = 20.0):
    """Closed-loop clients: each of ``concurrency`` streams keeps one
    request in flight (the paper's perf_analyzer protocol)."""
    exec_time = PAPER_BATCH1[model]
    loop = EventLoop()
    device = ProcessorSharingDevice(loop)
    completed = []
    latencies = []

    def submit(stream_id):
        start = loop.now

        def done(job, now):
            completed.append(now)
            latencies.append(now - start)
            if now < horizon:
                submit(stream_id)

        device.submit(stream_id, exec_time, done)

    for s in range(concurrency):
        submit(s)
    loop.run(until=horizon)
    n = len(completed)
    med = sorted(latencies)[len(latencies) // 2] if latencies else 0.0
    return med, n / horizon


def run_pair(model_a: str, model_b: str, horizon: float = 20.0):
    """Table 1: model A and model B concurrently, one in flight each."""
    loop = EventLoop()
    device = ProcessorSharingDevice(loop)
    stats = {model_a: [], model_b: []}

    def submit(model):
        start = loop.now

        def done(job, now):
            stats[model].append(now - start)
            if now < horizon:
                submit(model)

        device.submit(model, PAPER_BATCH1[model], done)

    submit(model_a)
    if model_b is not None:
        submit(model_b)
    loop.run(until=horizon)
    lat = stats[model_a]
    med = sorted(lat)[len(lat) // 2]
    return med, len(lat) / horizon


def run_batching(model: str, batch: int, horizon: float = 20.0):
    """Fig 2c/2d on the calibrated table: batched execution, one in flight."""
    table = paper_table()
    e = table.wcet(model, (3, 224, 224), batch)
    return e, batch / e  # latency, imgs/s


def main() -> List[str]:
    rows = []
    for model in ["resnet50", "vgg16", "inception_v3"]:
        base_med, _ = run_concurrency(model, 1)
        for c in [1, 2, 3, 4, 6]:
            med, thpt = run_concurrency(model, c)
            rows.append(["concurrency", model, c, med, thpt, med / base_med])
        for b in [1, 2, 4, 8, 16]:
            lat, thpt = run_batching(model, b)
            rows.append(["batching", model, b, lat, thpt, 0.0])
    pair_rows = []
    models = list(PAPER_BATCH1)[:6]
    for a in models:
        solo_med, solo_thpt = run_pair(a, None)
        pair_rows.append([a, "-", solo_med, solo_thpt])
        for b in models:
            med, thpt = run_pair(a, b)
            pair_rows.append([a, b, med, thpt])
    write_csv(
        "fig2_concurrency_batching",
        ["mode", "model", "level", "median_latency_s", "throughput_ips", "slowdown"],
        rows,
    )
    write_csv(
        "table1_interference",
        ["model", "concurrent_with", "median_exec_s", "throughput_ips"],
        pair_rows,
    )
    # Headline checks reproducing the paper's two observations.
    rn_lat_c4 = next(r for r in rows if r[0] == "concurrency" and r[1] == "resnet50" and r[2] == 4)
    rn_b4 = next(r for r in rows if r[0] == "batching" and r[1] == "resnet50" and r[2] == 4)
    rn_b1 = next(r for r in rows if r[0] == "batching" and r[1] == "resnet50" and r[2] == 1)
    return [
        f"fig2a,resnet50,concurrency4_slowdown,{rn_lat_c4[5]:.2f}",
        f"fig2cd,resnet50,batch4_latency_vs_batch1,{rn_b4[3]/rn_b1[3]:.2f}",
        f"fig2f,resnet50,batch4_thpt_gain,{rn_b4[4]/rn_b1[4]:.2f}",
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
