"""Fault-tolerance chaos replay: stall one slice, throttle another, and
prove the health watchdog survives it with zero operator intervention.

Scenario (real compiled programs, one shared WallClock, streamed through
the ingest gateway):

1. build a live cluster (``build_live_cluster``) with the watchdog armed
   and deterministic fault plans injected at the dispatch-handle layer
   (``core/faults.FaultyDevice``):
   - one slice's decode step WEDGES mid-run (a hung ``block_until_ready``
     — the waiter thread genuinely blocks);
   - a second slice is THROTTLED: several completions land late by an
     absolute margin that crosses the watchdog's ``min_deadline`` floor;
2. register camera streams through the gateway and run — NOTHING else.
   No operator ``fail_slice``, no manual ``mark_slow``;
3. the watchdog must detect the hang, quarantine the slice (auto
   ``fail_slice``), abort its gateway sessions, and re-admit its tails
   on survivors; the throttled slice must go suspect (shed earlier, WCET
   table re-profiled from measured drift) without being killed.

Acceptance bars (asserted, also in ``--smoke``):

- the stalled slice is QUARANTINED automatically within the watchdog
  window of the injected stall (hang threshold + heartbeat slack);
- ZERO decode recompiles on surviving slices across the whole replay;
- every displaced request accounted: rerouted, parked-then-admitted,
  parked-then-expired, or finished-with-slice — and the parked queue is
  empty after the drain;
- conservation: ``completed + dropped + lost == ingested`` across the
  quarantine;
- a NO-WATCHDOG control arm replaying the same faults ends strictly
  worse: its effective miss rate (frames that never completed, counted
  as missed) exceeds the watchdog arm's.

Writes ``BENCH_fault_tolerance.json`` at the repo root (plus the usual
CSV under benchmarks/results/) so successive PRs can track the numbers.

    PYTHONPATH=src python -m benchmarks.fault_tolerance [--smoke]

``--smoke`` (CI): 2 tiny slices, short streams, no root-JSON rewrite —
a bit-rot guard for the fault-tolerance path, not a timing source.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

from benchmarks.common import write_csv
from repro.configs.registry import tiny
from repro.core import (
    Category,
    DELAY,
    FaultPlan,
    FaultSpec,
    QUARANTINED,
    STALL,
    WatchdogConfig,
)
from repro.ingest.session import IngestGateway
from repro.ingest.sources import CameraSource
from repro.serving.batcher_bridge import build_live_cluster

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MID = "granite-3-2b"
SEQ_PRE = 16
SEQ_DEC = 8

WD = WatchdogConfig(
    slack=3.0,
    hang_slack=9.0,
    min_deadline=0.05,
    suspect_after=2,
    quarantine_after=6,
)


def fault_plans(n_slices: int) -> Dict[str, FaultPlan]:
    """slice0 wedges on its third served submit; slice1 is throttled on a
    few SPACED-OUT submits by an absolute +0.08 s (decode WCETs here are
    sub-millisecond, so a relative factor alone could never cross the
    watchdog's 0.05 s ``min_deadline`` floor; 0.08 s stays safely below
    the 0.15 s hang threshold). Spacing matters: each throttled submit
    yields ~2 late signals (overdue beat + late completion), and clean
    completions in between reset the streak — the slice must cycle
    suspect -> recovered, not die. Only the wedge kills."""
    plans = {
        "slice0": FaultPlan((FaultSpec(STALL, 2),)),
        "slice1": FaultPlan(
            tuple(FaultSpec(DELAY, i, factor=1.0, extra=0.08) for i in (2, 6, 10))
        ),
    }
    return {k: v for k, v in plans.items() if int(k[len("slice"):]) < n_slices}


def run_arm(watchdog, n_slices, n_streams, frames, horizon):
    """One chaos replay; returns (cluster, slices, gateway, sessions)."""
    configs = {MID: tiny(MID)}
    cats = [(MID, (SEQ_PRE,), "prefill"), (MID, (SEQ_DEC,), "decode")]
    cluster, slices = build_live_cluster(
        configs,
        cats,
        slice_names=tuple(f"slice{i}" for i in range(n_slices)),
        batch_sizes=(1, 2),
        profile_runs=2,
        nonrt_cap=1,
        watchdog=watchdog,
        fault_plans=fault_plans(n_slices),
    )
    gw = IngestGateway(cluster)
    sessions = [
        gw.register(
            CameraSource(period=0.2, n_frames=frames, payload_shape=(), seed=60 + i),
            Category(MID, (SEQ_DEC,)),
            # Roomy relative to the 0.08s throttle and host jitter: the
            # watchdog arm's misses/sheds are deadline-relative, while the
            # control arm's penalty (wedged frames never complete) is not —
            # headroom here stabilizes the A/B without softening it.
            relative_deadline=0.7,
        )
        for i in range(n_streams)
    ]
    try:
        # With the watchdog the loop drains naturally (quarantine closes
        # the wedged device and releases its hold); without it the wedged
        # slice holds the loop forever, so the horizon is the only exit.
        cluster.run(until=cluster.loop.now + horizon)
    finally:
        for sl in slices.values():
            if sl.alive:
                sl.scheduler.device.close()
    return cluster, slices, gw, sessions


def effective_miss_rate(cluster) -> float:
    """Deadline misses plus frames that never completed at all (stuck in
    a wedged pipeline, shed, or lost with a slice), over everything the
    gateway presented. The metric a client actually experiences."""
    agg = cluster.aggregate_metrics()
    ingested = agg["ingested_frames"]
    if ingested == 0:
        return 0.0
    served_on_time = agg["completed_frames"] - agg["missed_frames"]
    return 1.0 - served_on_time / ingested


def main(smoke: bool = False) -> List[str]:
    if smoke:
        n_slices, n_streams, frames, horizon = 2, 3, 8, 6.0
    else:
        n_slices, n_streams, frames, horizon = 3, 5, 12, 8.0

    t0 = time.perf_counter()
    cluster, slices, gw, sessions = run_arm(WD, n_slices, n_streams, frames, horizon)
    wd_seconds = time.perf_counter() - t0

    # --- watchdog-arm invariants -----------------------------------------
    agg = cluster.aggregate_metrics()
    dead = "slice0"
    assert slices[dead].health == QUARANTINED, cluster.health.transitions
    assert not slices[dead].alive
    quarantines = [
        (t, name, reason)
        for t, name, _old, new, reason in cluster.health.transitions
        if new == QUARANTINED
    ]
    hang = [(t, r) for t, name, r in quarantines if name == dead]
    assert hang and "hung" in hang[0][1], quarantines
    # Auto-detection latency: quarantine must land within the watchdog
    # window of the injected stall (hang threshold + one heartbeat + a
    # generous CI-host margin) — not "eventually".
    stall_t = next(
        t for _i, kind, t in slices[dead].device.injected if kind == STALL
    )
    wcet_dec = slices[dead].spec.table.wcet(MID, (SEQ_DEC,), 1)
    window = WD.hang_after(wcet_dec) + WD.deadline_for(wcet_dec) + 1.0
    detect_latency = hang[0][0] - stall_t
    assert 0 < detect_latency <= window, (detect_latency, window)

    # The throttled slice was noticed (suspect at least once) but only a
    # wedge kills a slice — throttling alone must not.
    throttled_transitions = [
        (old, new) for _t, name, old, new, _r in cluster.health.transitions
        if name == "slice1"
    ]
    assert throttled_transitions, "throttled slice never flagged"
    assert slices["slice1"].alive, "throttling must degrade, not kill"

    # Conservation + displaced-tail accounting.
    assert (
        agg["completed_frames"] + agg["dropped_frames"] + agg["lost_frames"]
        == agg["ingested_frames"]
    ), agg
    assert cluster.parked == {}, "unresolved parked tails after drain"
    assert all(name != dead for name in cluster.placement.values())
    for rid, tail in cluster.failover_map.items():
        if tail is None:
            assert rid in cluster.parked_expired
    assert all(s.conserved() for s in sessions)
    dead_sessions = [s for s in sessions if s.slice_name == dead]
    assert all(s.state == "failover" for s in dead_sessions)

    # Survivors: zero decode recompiles, all arena rows recycled.
    survivors = [n for n in slices if slices[n].alive]
    assert survivors, "chaos killed every slice"
    for name in survivors:
        assert slices[name].engine.stats["decode_compiles"] == 0, name
        arena = slices[name].engine.arena(MID, SEQ_DEC)
        assert len(arena.free) == arena.max_slots, name

    # --- no-watchdog control arm ------------------------------------------
    t1 = time.perf_counter()
    ctrl, ctrl_slices, _gw2, _s2 = run_arm(None, n_slices, n_streams, frames, horizon)
    ctrl_seconds = time.perf_counter() - t1
    # Nothing ever detected the wedge: the slice is still nominally alive.
    assert ctrl_slices["slice0"].health != QUARANTINED
    assert not ctrl.health.transitions

    wd_miss = effective_miss_rate(cluster)
    ctrl_miss = effective_miss_rate(ctrl)
    assert ctrl_miss > wd_miss, (
        f"watchdog arm must beat the control: {wd_miss:.3f} vs {ctrl_miss:.3f}"
    )

    result = {
        "slices": n_slices,
        "streams": n_streams,
        "watchdog": {
            "quarantined": [name for _t, name, _r in quarantines],
            "detect_latency_s": detect_latency,
            "detect_window_s": window,
            "transitions": [
                [round(t, 4), name, old, new, reason]
                for t, name, old, new, reason in cluster.health.transitions
            ],
            "reprofiles": dict(cluster.health.reprofiles),
            "effective_miss_rate": wd_miss,
            "completed_frames": agg["completed_frames"],
            "lost_frames": agg["lost_frames"],
            "dropped_frames": agg["dropped_frames"],
            "ingested_frames": agg["ingested_frames"],
            "reroutes": agg["reroutes"],
            "parked_admitted": agg["parked_admitted"],
            "parked_expired": agg["parked_expired"],
            "survivor_decode_recompiles": sum(
                slices[n].engine.stats["decode_compiles"] for n in survivors
            ),
            "seconds": wd_seconds,
        },
        "no_watchdog": {
            "effective_miss_rate": ctrl_miss,
            "completed_frames": ctrl.aggregate_metrics()["completed_frames"],
            "ingested_frames": ctrl.aggregate_metrics()["ingested_frames"],
            "seconds": ctrl_seconds,
        },
    }

    if not smoke:
        with open(os.path.join(REPO_ROOT, "BENCH_fault_tolerance.json"), "w") as f:
            json.dump(result, f, indent=1)
        write_csv(
            "fault_tolerance",
            ["metric", "value"],
            [
                ["slices", n_slices],
                ["streams", n_streams],
                ["detect_latency_s", detect_latency],
                ["watchdog_effective_miss_rate", wd_miss],
                ["no_watchdog_effective_miss_rate", ctrl_miss],
                ["reroutes", agg["reroutes"]],
                ["parked_admitted", agg["parked_admitted"]],
                ["parked_expired", agg["parked_expired"]],
                ["lost_frames", agg["lost_frames"]],
                ["survivor_decode_recompiles",
                 result["watchdog"]["survivor_decode_recompiles"]],
            ],
        )

    return [
        f"fault_tolerance,quarantined,{'+'.join(result['watchdog']['quarantined'])}"
        f" (auto, {detect_latency * 1000:.0f} ms after stall)",
        f"fault_tolerance,effective_miss_rate,"
        f"watchdog {wd_miss:.3f} vs no-watchdog {ctrl_miss:.3f}",
        f"fault_tolerance,failover,rerouted {agg['reroutes']} / "
        f"parked_admitted {agg['parked_admitted']} / "
        f"parked_expired {agg['parked_expired']}",
        f"fault_tolerance,conservation,completed {agg['completed_frames']} + "
        f"dropped {agg['dropped_frames']} + lost {agg['lost_frames']} == "
        f"ingested {agg['ingested_frames']}",
        f"fault_tolerance,survivor_decode_recompiles,"
        f"{result['watchdog']['survivor_decode_recompiles']}",
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="2 tiny slices, short streams, no JSON rewrite (CI bit-rot guard)",
    )
    args = ap.parse_args()
    if args.smoke:
        # The watchdog-vs-control comparison rides real wall-clock timing;
        # a loaded CI runner can blur it. One retry forgives transient
        # machine noise — a genuine regression fails both attempts.
        try:
            lines = main(smoke=True)
        except AssertionError as e:
            print(f"fault_tolerance,smoke_retry,first attempt failed: {e}")
            lines = main(smoke=True)
    else:
        lines = main(smoke=False)
    for line in lines:
        print(line)
