"""Beyond-paper: assemble the §Roofline table from dry-run JSON outputs.

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun)
and emits the per-(arch x shape x mesh) roofline table used verbatim in
EXPERIMENTS.md: the three terms, dominant bottleneck, model-FLOPs ratio,
and per-device memory footprint.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import RESULTS_DIR, write_csv

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def load_cells(pattern: str = "*.json") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main() -> List[str]:
    cells = load_cells()
    rows = []
    for c in cells:
        if not c.get("ok"):
            rows.append(
                [c["arch"], c["shape"], c["mesh"], c.get("opt", "baseline"),
                 "FAIL", "", "", "", "", "", "", c.get("error", "")[:80]]
            )
            continue
        r = c["roofline"]
        mem = c.get("memory_analysis", {})
        hbm_gb = (
            mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        ) / 1e9
        rows.append(
            [
                c["arch"], c["shape"], c["mesh"], c.get("opt", "baseline"), "ok",
                f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
                f"{r['collective_s']:.3e}", r["dominant"],
                f"{r['useful_flops_ratio']:.3f}" if r.get("useful_flops_ratio") else "",
                f"{hbm_gb:.2f}", "",
            ]
        )
    write_csv(
        "roofline_table",
        ["arch", "shape", "mesh", "opt", "status", "compute_s", "memory_s",
         "collective_s", "dominant", "useful_flops_ratio",
         "per_device_arg+temp_GB", "note"],
        rows,
    )
    # Best-variant-per-cell summary: baseline vs the best measured opt.
    best = {}
    for c in cells:
        if not c.get("ok"):
            continue
        r = c["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        key = (c["arch"], c["shape"], c["mesh"])
        entry = best.setdefault(key, {})
        if c.get("opt", "baseline") == "baseline":
            entry["baseline"] = bound
        if "best" not in entry or bound < entry["best"][0]:
            entry["best"] = (bound, c.get("opt", "baseline"), r["dominant"])
    summary_rows = []
    for (a, s, m), e in sorted(best.items()):
        base = e.get("baseline")
        b, opt, dom = e["best"]
        speedup = (base / b) if base and b > 0 else 1.0
        summary_rows.append(
            [a, s, m, f"{base:.3e}" if base else "", f"{b:.3e}", opt, dom,
             f"{speedup:.1f}"]
        )
    write_csv(
        "roofline_best_per_cell",
        ["arch", "shape", "mesh", "baseline_bound_s", "best_bound_s",
         "best_variant", "dominant_after", "speedup_x"],
        summary_rows,
    )
    n_ok = sum(1 for r in rows if r[4] == "ok")
    n_fail = len(rows) - n_ok
    doms = {}
    for r in rows:
        if r[4] == "ok":
            doms[r[8]] = doms.get(r[8], 0) + 1
    single = [r for r in summary_rows if r[2] == "16x16" and r[3]]
    if single:
        import statistics

        speedups = [float(r[7]) for r in single]
        geo = (
            statistics.geometric_mean([max(s, 1e-9) for s in speedups])
            if speedups
            else 1.0
        )
        extra = [f"roofline,geomean_speedup_single_pod,{geo:.2f}"]
    else:
        extra = []
    return [
        f"roofline,cells_ok,{n_ok}",
        f"roofline,cells_fail,{n_fail}",
        f"roofline,dominant_breakdown,{doms}",
    ] + extra


if __name__ == "__main__":
    for line in main():
        print(line)
