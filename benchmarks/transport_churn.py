"""Churn-storm transport benchmark: 2000+ short-lived sessions slam a
small live cohort, with and without the fleet-hardening gates.

Three arms, all deterministic (simulated EventLoop; only the dispatch
arm reads a wall clock, for its own timing):

1. GATED churn storm: 12 long-lived tight-deadline streams (the "live
   cohort") share 3 slices while ~2000 storm sessions — synchronized
   waves of short bursty streams, zombies (vanish mid-stream, no FIN)
   and slowloris (10s+ inter-frame gaps) — arrive on top, plus garbage
   datagrams on the shared wire. The server runs every hardening knob:
   HELLO token bucket (+ HELLO_RETRY backoff), ``max_sessions``,
   idle-timeout eviction, per-session + global reassembly budgets, and
   ``retain_finalized=False`` (finished sessions fold into
   ``retired_totals`` and leave the table).

2. UNGATED control: the identical storm against a server with every
   bound switched off (the pre-hardening default). Zombies pile up,
   the session table grows without limit, and whole waves of bursty
   streams are admitted at the same instant.

3. DISPATCH SCALING: out-of-order DATA datagrams (pure reassembly-
   buffer work, no delivery) timed against a table of 100 vs 2000+
   open sessions on a dedicated cluster — per-datagram dispatch must
   stay O(1)-ish (sharded hash lookup), not O(table).

Acceptance bars (asserted, also in ``--smoke``):

- ZERO uncaught exceptions end-to-end: garbage datagrams are counted
  ``malformed``, never thrown;
- bounded memory under gating: ``reassembly_peak_bytes`` never exceeds
  the global budget (sampled every 0.25s of sim time AND checked at
  the peak counter), and the gated session-table high-water mark stays
  O(max_sessions) while the ungated table ends >= storm size;
- conservation everywhere: every session (live or retired) satisfies
  the wire identity, and ``assert_conserved()`` proves the folded
  retired totals plus the scheduler identity at quiescence;
- the gated arm's live-cohort effective miss rate is STRICTLY lower
  than the ungated arm's (admission pacing decorrelates the storm's
  synchronized bursts; eviction keeps zombie utilization from pinning
  the admission state);
- graceful drain: post-drain HELLO refused with ``reason: draining``;
- dispatch stays flat: per-datagram time at 2000+ sessions is < 3x the
  100-session time.

Writes ``BENCH_transport_churn.json`` at the repo root (plus the usual
CSV under benchmarks/results/).

    PYTHONPATH=src python -m benchmarks.transport_churn [--smoke]

``--smoke`` (CI): same >= 2000-session storm (the scale IS the test),
fewer dispatch-timing reps, no root-JSON rewrite.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import random
import time
from typing import Dict, List

from benchmarks.common import write_csv
from repro.core import Category, EventLoop, ProfileTable
from repro.core.cluster import build_sim_cluster
from repro.ingest import (
    BurstSource,
    IngestGateway,
    LinkPlan,
    PeriodicSource,
    SimLink,
    TransportServer,
    TransportSource,
)
from repro.ingest.transport import decode, encode_data

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SEED = 41
CAT = Category("m", (4,))
LIVE_STREAMS = 12
LIVE_PERIOD = 0.05
LIVE_DEADLINE = 0.15
LIVE_FRAMES = 350
N_WAVES = 25
WAVE_SIZE = 80          # 25 * 80 = 2000 storm sessions
WAVE_INTERVAL = 0.6
N_GARBAGE = 40

GATES = dict(
    hello_rate=40.0,
    hello_burst=20.0,
    max_sessions=64,
    idle_timeout=0.5,
    session_buffer_bytes=256,
    reassembly_budget_bytes=64 * 1024,
    retain_finalized=False,
    shards=32,
)


def _table(a: float = 0.001, c: float = 0.002) -> ProfileTable:
    table = ProfileTable()
    for b in (1, 2, 4, 8, 16, 32):
        table.record("m", (4,), b, a + c * b)
    return table


# ---------------------------------------------------------------------------
# Arms 1 + 2: churn storm, gated vs ungated
# ---------------------------------------------------------------------------


def run_storm(gated: bool) -> Dict:
    rng = random.Random(SEED)
    loop = EventLoop()
    cluster = build_sim_cluster(_table, ["s0", "s1", "s2"], loop=loop)
    gateway = IngestGateway(cluster)
    server = TransportServer(gateway, record_payloads=False,
                             **(GATES if gated else {}))

    # Live cohort: admitted before the storm, no link chaos — every
    # miss/drop they take is the storm's doing, not the wire's.
    live_clients: List[TransportSource] = []
    for i in range(LIVE_STREAMS):
        link = SimLink(loop, server.datagram)
        src = PeriodicSource(period=LIVE_PERIOD, n_frames=LIVE_FRAMES,
                             payload_shape=(4,), seed=100 + i)
        c = TransportSource(src, CAT, LIVE_DEADLINE, link)
        assert c.start(server, start_in=0.01 * i), f"live stream {i} refused"
        live_clients.append(c)
    live_rids = [
        server.sessions[c.sid].session.request_id for c in live_clients
    ]

    # Storm: synchronized waves. Every wave lands WAVE_SIZE HELLOs at
    # the same instant; the admitted bursty streams then fire aligned
    # bursts straight into the live cohort's EDF queues.
    storm_clients: List[TransportSource] = []
    for w in range(N_WAVES):
        t_wave = 0.4 + w * WAVE_INTERVAL
        for j in range(WAVE_SIZE):
            kind = rng.choice(
                ("burst", "burst", "burst", "zombie", "slowloris")
            )
            chaos = (len(storm_clients) % 7 == 0)
            plan = (
                LinkPlan.from_seed(
                    SEED * 131 + len(storm_clients), 32,
                    p_drop=0.05, p_dup=0.05, p_reorder=0.25, p_delay=0.05,
                    reorder_hold=(0.05, 0.4),
                )
                if chaos else None
            )
            link = SimLink(loop, server.datagram, plan=plan)
            if kind == "burst":
                src = BurstSource(period=LIVE_PERIOD, n_frames=4,
                                  payload_shape=(4,), seed=1000 + w * 97 + j,
                                  burst=2, duty=0.5)
                c = TransportSource(src, CAT, LIVE_DEADLINE, link,
                                    hello_max_retries=6)
            elif kind == "zombie":
                src = PeriodicSource(period=LIVE_PERIOD, n_frames=4,
                                     payload_shape=(4,), seed=2000 + j)
                c = TransportSource(src, CAT, LIVE_DEADLINE, link,
                                    hello_max_retries=6, abort_after=1)
            else:  # slowloris: one frame, then a 10s gap it never fills
                src = PeriodicSource(period=10.0, n_frames=3,
                                     payload_shape=(4,), seed=3000 + j)
                c = TransportSource(src, CAT, LIVE_DEADLINE, link,
                                    hello_max_retries=6, abort_after=1)
            storm_clients.append(c)
            loop.schedule(t_wave, lambda c=c: c.start(server), priority=0)

    # Adversarial wire: garbage datagrams sprayed across the storm.
    for g in range(N_GARBAGE):
        blob = bytes(rng.randrange(256) for _ in range(rng.randrange(64)))
        loop.schedule(rng.uniform(0.2, N_WAVES * WAVE_INTERVAL),
                      lambda b=blob: server.datagram(b), priority=0)

    # Bounded-memory sampler: table size + reassembly bytes every 0.25s.
    samples: List[Dict[str, int]] = []
    t_end = LIVE_FRAMES * LIVE_PERIOD + 2.0

    def _sample() -> None:
        samples.append({
            "t": loop.now,
            "sessions": len(server.sessions),
            "open": server.open_count,
            "reassembly_bytes": server.reassembly_bytes,
        })
        if gated and server.reassembly_budget_bytes is not None:
            assert server.reassembly_bytes <= server.reassembly_budget_bytes
        if loop.now < t_end:
            loop.schedule(loop.now + 0.25, _sample, priority=0)

    loop.schedule(0.25, _sample, priority=0)
    loop.schedule(t_end, lambda: server.drain(), priority=0)

    t0 = time.perf_counter()
    loop.run()
    seconds = time.perf_counter() - t0
    assert server.drained

    # Graceful refusal after drain.
    mtype, body = decode(server.hello({
        "model_id": "m", "shape_key": [4], "realtime": True,
        "period": 0.1, "n_frames": 4, "relative_deadline": 0.3,
    }))
    assert not body.get("accepted") and body.get("reason") == "draining", body

    # Conservation: per-session wire identity, retired fold, scheduler
    # identity — any datagram outside its one leg raises here.
    for ts in server.sessions.values():
        assert ts.wire_conserved(), ts.sid
    server.assert_conserved()
    assert server.malformed >= N_GARBAGE, server.malformed_by_reason

    # Live-cohort effective miss: misses + sheds over the known frame
    # budget (chaos-free links -> every planned frame reached the wire).
    hurt = 0
    for rid in live_rids:
        for sl in cluster.slices.values():
            m = sl.scheduler.metrics
            hurt += m.missed_by_request.get(rid, 0)
            hurt += m.drops_by_request.get(rid, 0)
    eff_live = hurt / float(LIVE_STREAMS * LIVE_FRAMES)

    peak_table = max(s["sessions"] for s in samples)
    peak_bytes = max(s["reassembly_bytes"] for s in samples)
    storm_admitted = sum(1 for c in storm_clients if c.frames_sent > 0)
    return {
        "gated": gated,
        "storm_sessions": len(storm_clients),
        "storm_admitted": storm_admitted,
        "storm_rejected": sum(
            1 for c in storm_clients if c.state == "rejected"
        ),
        "eff_live_miss": eff_live,
        "live_hurt_frames": hurt,
        "peak_table": peak_table,
        "final_table": len(server.sessions),
        "peak_reassembly_bytes_sampled": peak_bytes,
        "reassembly_peak_bytes": server.reassembly_peak_bytes,
        "budget_refusals": server.budget_refusals,
        "evictions": server.evictions,
        "retired_sessions": server.retired_sessions,
        "hello_retries_sent": server.hello_retries_sent,
        "malformed": server.malformed,
        "seconds": seconds,
        "telemetry": server.telemetry(),
    }


# ---------------------------------------------------------------------------
# Arm 3: dispatch scaling (O(1)-ish datagram routing vs table size)
# ---------------------------------------------------------------------------


def _open_table(n_sessions: int):
    """A dedicated cluster with ``n_sessions`` open non-RT sessions
    (admission bypassed -> registration is cheap), period 100s so no
    frame is ever due: the table is pure lookup load."""
    loop = EventLoop()
    cluster = build_sim_cluster(_table, ["d0", "d1", "d2"], loop=loop)
    gateway = IngestGateway(cluster)
    server = TransportServer(gateway, record_payloads=False, shards=32)
    nrt = Category("m", (4,), realtime=False)
    sids = []
    for _ in range(n_sessions):
        sid, ok = server.open_session(
            category=nrt, period=100.0, n_frames=8, relative_deadline=50.0,
        )
        assert ok
        sids.append(sid)
    return server, sids


def _dispatch_rig(n_sessions: int):
    server, sids = _open_table(n_sessions)
    probes = sids[:: max(1, len(sids) // 64)][:64]
    blobs = [
        [encode_data(sid, seq, 0.0, [1, 2, 3, 4]) for seq in (2, 3, 4)]
        for sid in probes
    ]
    return server, probes, blobs


def _dispatch_round(server, blobs, reps: int) -> float:
    t0 = time.perf_counter()
    n = 0
    for _r in range(reps):
        for frames in blobs:
            for blob in frames:
                server.datagram(blob)
                n += 1
    return (time.perf_counter() - t0) / n


def time_dispatch(sizes, reps: int, rounds: int = 24) -> Dict[int, float]:
    """Per-datagram time for OUT-OF-ORDER data frames (seqs 2..4 with
    next_seq=0, reorder window 8): the datagram lands in the reassembly
    buffer — session lookup + bookkeeping only, no delivery cascade, so
    the measurement isolates dispatch. Shared machines flip between
    fast/slow CPU regimes that persist for whole seconds, so the sizes
    are measured in INTERLEAVED rounds spread over a few seconds (short
    sleep between rounds) and the per-size MINIMUM is kept — every size
    gets a shot at the fast regime, and the min is the dispatch cost
    with the machine noise stripped."""
    rigs = {n: _dispatch_rig(n) for n in sizes}
    for server, _probes, blobs in rigs.values():
        _dispatch_round(server, blobs, 1)  # warm-up, discarded
    best = {n: float("inf") for n in sizes}
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _round in range(rounds):
            for n, (server, _probes, blobs) in rigs.items():
                best[n] = min(best[n], _dispatch_round(server, blobs, reps))
            time.sleep(0.1)
    finally:
        if gc_was_enabled:
            gc.enable()
    for n, (server, probes, _blobs) in rigs.items():
        for sid in probes:
            assert server.sessions[sid].wire_conserved()
    return best


# ---------------------------------------------------------------------------


def main(smoke: bool = False) -> List[str]:
    reps = 4 if smoke else 12

    gated = run_storm(gated=True)
    ungated = run_storm(gated=False)

    n_storm = gated["storm_sessions"]
    assert n_storm >= 2000, n_storm
    assert ungated["eff_live_miss"] > 0.0, (
        "the storm never hurt the ungated live cohort - the A/B is vacuous"
    )
    assert gated["eff_live_miss"] < ungated["eff_live_miss"], (
        f"gating must strictly beat the ungated control on live misses: "
        f"{gated['eff_live_miss']:.4f} vs {ungated['eff_live_miss']:.4f}"
    )
    # The gates actually engaged.
    assert gated["hello_retries_sent"] > 0
    assert gated["evictions"] > 0
    assert gated["reassembly_peak_bytes"] <= GATES["reassembly_budget_bytes"]
    # Bounded vs unbounded table growth.
    assert gated["peak_table"] <= GATES["max_sessions"] + LIVE_STREAMS + 8, (
        gated["peak_table"]
    )
    assert ungated["final_table"] >= n_storm, ungated["final_table"]

    timings = time_dispatch((100, 2000), reps)
    t100, t2k = timings[100], timings[2000]
    ratio = t2k / t100
    assert ratio < 3.0, (
        f"dispatch must stay O(1)-ish from 100 to 2000 sessions: "
        f"{t100 * 1e6:.2f}us -> {t2k * 1e6:.2f}us (x{ratio:.2f})"
    )

    result = {
        "storm": {"gated": gated, "ungated": ungated},
        "dispatch": {
            "per_datagram_us_100": t100 * 1e6,
            "per_datagram_us_2000": t2k * 1e6,
            "ratio": ratio,
        },
    }

    if not smoke:
        with open(
            os.path.join(REPO_ROOT, "BENCH_transport_churn.json"), "w"
        ) as f:
            json.dump(result, f, indent=1)
        write_csv(
            "transport_churn",
            ["metric", "gated", "ungated"],
            [
                ["storm_sessions", gated["storm_sessions"],
                 ungated["storm_sessions"]],
                ["storm_admitted", gated["storm_admitted"],
                 ungated["storm_admitted"]],
                ["eff_live_miss", gated["eff_live_miss"],
                 ungated["eff_live_miss"]],
                ["peak_table", gated["peak_table"], ungated["peak_table"]],
                ["final_table", gated["final_table"],
                 ungated["final_table"]],
                ["reassembly_peak_bytes", gated["reassembly_peak_bytes"],
                 ungated["reassembly_peak_bytes"]],
                ["evictions", gated["evictions"], ungated["evictions"]],
                ["hello_retries_sent", gated["hello_retries_sent"],
                 ungated["hello_retries_sent"]],
                ["malformed", gated["malformed"], ungated["malformed"]],
                ["dispatch_us_100", t100 * 1e6, ""],
                ["dispatch_us_2000", t2k * 1e6, ""],
            ],
        )

    return [
        f"transport_churn,storm,{n_storm} sessions in {N_WAVES} waves "
        f"({gated['storm_admitted']} admitted gated / "
        f"{ungated['storm_admitted']} ungated)",
        f"transport_churn,live_miss,gated {gated['eff_live_miss']:.4f} vs "
        f"ungated {ungated['eff_live_miss']:.4f}",
        f"transport_churn,memory,gated table peak {gated['peak_table']} "
        f"(final {gated['final_table']}) vs ungated final "
        f"{ungated['final_table']}; reassembly peak "
        f"{gated['reassembly_peak_bytes']}B <= "
        f"{GATES['reassembly_budget_bytes']}B",
        f"transport_churn,lifecycle,{gated['evictions']} evictions / "
        f"{gated['retired_sessions']} retired / "
        f"{gated['hello_retries_sent']} HELLO_RETRY / "
        f"{gated['malformed']} malformed (zero exceptions)",
        f"transport_churn,dispatch,{t100 * 1e6:.2f}us @100 -> "
        f"{t2k * 1e6:.2f}us @2000 (x{ratio:.2f})",
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="same 2000-session storm, fewer timing reps, no JSON rewrite",
    )
    args = ap.parse_args()
    if args.smoke:
        # The dispatch arm reads a wall clock; a loaded CI runner can
        # blur the ratio. One retry forgives transient machine noise —
        # a genuine regression fails both attempts. (Both storm arms
        # are simulated time and exactly deterministic.)
        try:
            lines = main(smoke=True)
        except AssertionError as e:
            print(f"transport_churn,smoke_retry,first attempt failed: {e}")
            lines = main(smoke=True)
    else:
        lines = main(smoke=False)
    for line in lines:
        print(line)
