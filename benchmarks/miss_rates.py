"""Paper Fig 4 + Fig 5: deadline miss rates and overdue-time CDFs.

DeepRT vs AIMD (Clipper), BATCH, BATCH-Delay (Triton) on six synthesized
traces (desktop 50/150/250 ms, jetson 300/450/600 ms — paper Table 2).
Per the paper's fairness protocol (§6.2): DeepRT's admission decides the
request set, the SAME accepted requests are fed to every baseline, and
DeepRT's Adaptation Module is disabled.
"""
from __future__ import annotations

import copy
import random
from typing import Dict, List

from benchmarks.common import paper_table, paper_trace, write_csv
from repro.core import AIMD, BATCH, BATCHDelay, DeepRT, ExecutionModel

JETSON_SCALE = 6.0  # paper: TX2 is ~an order slower than the RTX 2080


def actual_sampler(seed: int):
    rng = random.Random(seed)

    # Real executions sit just under the p99 profile, with jitter, and
    # occasionally overrun it (the profile is a p99, not a hard bound) —
    # the paper's DeepRT shows nonzero miss rates for exactly this reason.
    def fn(job, wcet):
        if rng.random() < 0.02:
            return wcet * rng.uniform(1.0, 1.5)
        return wcet * rng.uniform(0.85, 1.0)

    return fn


def run_trace(mean_pd: float, device: str, seed: int) -> List[List]:
    table = paper_table()
    if device == "jetson":
        table = table.scaled(JETSON_SCALE)
    reqs = paper_trace(mean_pd, mean_pd, seed=seed)

    deep = DeepRT(
        table,
        execution=ExecutionModel(actual_fn=actual_sampler(seed)),
        adaptation_enabled=False,  # paper §6.2 protocol
    )
    accepted = [copy.deepcopy(r) for r in reqs if deep.submit_request(r).admitted]
    m_deep = deep.run()

    rows = []
    overdue: Dict[str, List[float]] = {"DeepRT": m_deep.overdue_times}
    rows.append(
        ["DeepRT", device, mean_pd, len(accepted), m_deep.completed_frames,
         m_deep.miss_rate, m_deep.throughput, m_deep.mean_batch]
    )
    for name, mk in [
        ("AIMD", lambda t: AIMD(t, actual_fn=actual_sampler(seed))),
        ("BATCH", lambda t: BATCH(t, actual_fn=actual_sampler(seed), batch_size=4)),
        (
            "BATCH-Delay",
            lambda t: BATCHDelay(
                t, actual_fn=actual_sampler(seed), batch_size=4,
                max_delay=mean_pd / 2,
            ),
        ),
    ]:
        sched = mk(table)
        for r in accepted:
            sched.submit_request(copy.deepcopy(r))
        m = sched.run()
        overdue[name] = m.overdue_times
        rows.append(
            [name, device, mean_pd, len(accepted), m.completed_frames,
             m.miss_rate, m.throughput, m.mean_batch]
        )
    # Fig 5 CDF points.
    cdf_rows = []
    for name, times in overdue.items():
        for t in sorted(times):
            cdf_rows.append([name, device, mean_pd, t])
    return rows, cdf_rows


def main(seeds=(0, 1, 2)) -> List[str]:
    rows, cdf_rows = [], []
    for device, means in [("desktop", [0.05, 0.15, 0.25]),
                          ("jetson", [0.3, 0.45, 0.6])]:
        for mp in means:
            for seed in seeds:
                r, c = run_trace(mp, device, seed)
                rows += r
                cdf_rows += c
    p1 = write_csv(
        "fig4_miss_rates",
        ["scheduler", "device", "mean_period_deadline", "n_admitted",
         "completed", "miss_rate", "throughput_fps", "mean_batch"],
        rows,
    )
    p2 = write_csv(
        "fig5_overdue_cdf", ["scheduler", "device", "trace", "overdue_s"], cdf_rows
    )
    # Headline: average miss rate per scheduler.
    agg: Dict[str, List[float]] = {}
    for r in rows:
        agg.setdefault(r[0], []).append(r[5])
    lines = []
    for name, xs in agg.items():
        lines.append(f"fig4,{name},mean_miss_rate,{sum(xs)/len(xs):.4f}")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
