"""Tests for the Adaptation Module (paper §4.4) and the cluster layer."""
import pytest

from repro.core import (
    AIMD,
    BATCH,
    BATCHDelay,
    Category,
    ClusterScheduler,
    DeepRT,
    EventLoop,
    ExecutionModel,
    ProfileTable,
    Request,
    SliceSpec,
)


def make_table(model="m", a=0.004, c=0.0015):
    t = ProfileTable()
    for shape in [(3, 224, 224), (3, 112, 112), (3, 56, 56)]:
        scale = shape[1] / 224.0
        b = 1
        while b <= 128:
            t.record(model, shape, b, (a + c * b) * max(scale, 0.25))
            b *= 2
    return t


CAT = Category("m", (3, 224, 224))


class TestAdaptation:
    def _overrun_then_normal(self, n_overruns):
        """actual = 3x WCET for the first n_overruns jobs, then 0.9x."""
        count = {"n": 0}

        def actual_fn(job, wcet):
            count["n"] += 1
            return 3.0 * wcet if count["n"] <= n_overruns else 0.9 * wcet

        return actual_fn

    def test_overrun_triggers_shape_reduction(self):
        table = make_table()
        sched = DeepRT(
            table, execution=ExecutionModel(actual_fn=self._overrun_then_normal(1))
        )
        r = Request(category=CAT, period=0.1, relative_deadline=0.4, n_frames=20)
        assert sched.submit_request(r).admitted
        sched.run()
        assert sched.adaptation.shape_changes >= 1
        # Some jobs must have executed at the reduced shape.
        reduced = [
            j for j in sched.worker.completed_jobs if j.shape_key == (3, 112, 112)
        ]
        assert reduced

    def test_penalty_repaid_and_shape_restored(self):
        table = make_table()
        sched = DeepRT(
            table, execution=ExecutionModel(actual_fn=self._overrun_then_normal(1))
        )
        r = Request(category=CAT, period=0.1, relative_deadline=0.4, n_frames=30)
        assert sched.submit_request(r).admitted
        sched.run()
        assert sched.adaptation.restores >= 1
        assert sched.adaptation.penalty(CAT) == 0.0
        # After restoration, later jobs run at the original shape again.
        assert sched.worker.completed_jobs[-1].shape_key == (3, 224, 224)

    def test_disabled_adaptation_never_changes_shape(self):
        table = make_table()
        sched = DeepRT(
            table,
            execution=ExecutionModel(actual_fn=self._overrun_then_normal(5)),
            adaptation_enabled=False,
        )
        r = Request(category=CAT, period=0.1, relative_deadline=0.4, n_frames=20)
        assert sched.submit_request(r).admitted
        sched.run()
        assert all(
            j.shape_key == (3, 224, 224) for j in sched.worker.completed_jobs
        )

    def test_overruns_counted(self):
        table = make_table()
        sched = DeepRT(
            table, execution=ExecutionModel(actual_fn=self._overrun_then_normal(3))
        )
        r = Request(category=CAT, period=0.1, relative_deadline=0.4, n_frames=20)
        assert sched.submit_request(r).admitted
        m = sched.run()
        assert m.overruns >= 1

    def test_adaptation_reduces_misses_under_injected_overruns(self):
        """The paper's Fig 10 claim, as a test: with heavy injected
        overruns, enabling adaptation yields no more misses than without."""

        def run(enabled):
            table = make_table()
            count = {"n": 0}

            def actual_fn(job, wcet):
                count["n"] += 1
                return 4.0 * wcet if count["n"] % 7 == 3 else 0.95 * wcet

            sched = DeepRT(
                table,
                execution=ExecutionModel(actual_fn=actual_fn),
                adaptation_enabled=enabled,
            )
            for i in range(3):
                r = Request(
                    category=CAT, period=0.05, relative_deadline=0.2, n_frames=60
                )
                sched.submit_request(r)
            return sched.run()

        with_adapt = run(True)
        without = run(False)
        assert with_adapt.missed_frames <= without.missed_frames


class TestClusterScheduler:
    def _mk(self, n_slices=2):
        cluster = ClusterScheduler()
        for i in range(n_slices):
            cluster.add_slice(SliceSpec(name=f"slice{i}", table=make_table()))
        return cluster

    def test_placement_spreads_load(self):
        cluster = self._mk(2)
        reqs = [
            Request(category=CAT, period=0.05, relative_deadline=0.3, n_frames=40)
            for _ in range(6)
        ]
        placed = [cluster.submit_request(r) for r in reqs]
        assert all(placed)
        names = set(cluster.placement.values())
        assert len(names) == 2  # both slices used

    def test_failure_reroutes_requests(self):
        cluster = self._mk(2)
        reqs = [
            Request(category=CAT, period=0.05, relative_deadline=0.3, n_frames=200)
            for _ in range(4)
        ]
        for r in reqs:
            assert cluster.submit_request(r)
        cluster.run(until=1.0)
        victims = [
            rid for rid, s in cluster.placement.items() if s == "slice0"
        ]
        lost = cluster.fail_slice("slice0")
        cluster.run()
        agg = cluster.aggregate_metrics()
        if victims:
            assert cluster.reroutes + len(lost) > 0
        assert agg["completed_frames"] > 0

    def test_overloaded_cluster_sheds(self):
        cluster = self._mk(1)
        results = [
            cluster.submit_request(
                Request(category=CAT, period=0.004, relative_deadline=0.05, n_frames=100)
            )
            for _ in range(30)
        ]
        assert not all(results)
        assert cluster.dropped

    def test_slow_slice_degrades_admission(self):
        cluster = self._mk(1)
        cluster.mark_slow("slice0", 4.0)
        # WCETs now 4x: a workload that would fit at full speed is rejected.
        r = Request(category=CAT, period=0.006, relative_deadline=0.03, n_frames=50)
        assert not cluster.submit_request(r)

    def test_zero_misses_survive_failover(self):
        cluster = ClusterScheduler(
            execution=ExecutionModel(actual_fn=lambda j, w: w)
        )
        for i in range(2):
            cluster.add_slice(SliceSpec(name=f"s{i}", table=make_table()))
        for _ in range(4):
            cluster.submit_request(
                Request(category=CAT, period=0.1, relative_deadline=0.4, n_frames=100)
            )
        cluster.run(until=2.0)
        cluster.fail_slice("s0")
        cluster.run()
        agg = cluster.aggregate_metrics()
        # Frames on surviving slices never miss (re-admitted tails are
        # admission-tested before acceptance).
        assert agg["miss_rate"] == 0.0


class TestBaselines:
    def test_batch_respects_fixed_size_under_saturation(self):
        table = make_table()
        loop = EventLoop()
        b = BATCH(table, loop=loop, batch_size=4)
        for _ in range(4):
            b.submit_request(
                Request(category=CAT, period=0.01, relative_deadline=0.5, n_frames=50)
            )
        m = b.run()
        assert m.completed_frames == 200
        assert max(m.batch_sizes) <= 4

    def test_aimd_grows_batch_when_slo_met(self):
        table = make_table()
        b = AIMD(table)
        b.submit_request(
            Request(category=CAT, period=0.004, relative_deadline=1.0, n_frames=100)
        )
        m = b.run()
        assert m.completed_frames == 100
        assert max(m.batch_sizes) > 1  # additive growth happened

    def test_batch_delay_flushes_on_timeout(self):
        table = make_table()
        b = BATCHDelay(table, batch_size=64, max_delay=0.02)
        b.submit_request(
            Request(category=CAT, period=0.05, relative_deadline=0.5, n_frames=10)
        )
        m = b.run()
        assert m.completed_frames == 10
        # Batches must have been released by the timeout, far below 64.
        assert max(m.batch_sizes) < 64

    def test_concurrent_baselines_slow_down_under_multitenancy(self):
        """Processor sharing: two concurrent categories -> higher latency
        than the same load run alone (paper Fig 2a)."""
        table = make_table()
        cat2 = Category("m", (3, 112, 112))

        def run(cats):
            b = BATCH(make_table(), batch_size=1)
            for c in cats:
                b.submit_request(
                    Request(category=c, period=0.02, relative_deadline=10.0, n_frames=50)
                )
            m = b.run()
            return sum(m.frame_latencies) / len(m.frame_latencies)

        solo = run([CAT])
        multi = run([CAT, cat2])
        assert multi > solo
