"""Streaming ingestion gateway: sources, sessions, staging rings,
payload fidelity, and adaptation-driven load shedding.

Covers the acceptance bars of the ingest PR:

- FrameSource plans are deterministic (bit-identical payloads and
  offsets across re-materializations and processes) and respect their
  shape contracts (camera jitter bounded and order-preserving; burst
  duty compresses the same frame budget into 1/duty of the time; trace
  replay is strict-periodic at the trace's sampled period);
- StagingRing cycles a FIXED host scratch pool (zero fresh host
  allocations after construction) and never lets job N's staged bytes
  be observed by job N+1's fill (double-buffer isolation — including a
  hypothesis interleaving property);
- end-to-end payload fidelity: engine outputs are bit-identical to a
  dense reference consuming the same ingested bytes, and DIFFER when
  the bytes differ — the synthetic-zeros path is gone;
- zero decode recompiles across a staged 1 -> max_slots -> 1 sweep with
  real payloads;
- the gateway's lifecycle (register -> admit/place -> stream -> close)
  runs identically over a simulated DeepRT and the live cluster path,
  deadline-stamping at arrival;
- under a 2x bursty overload, adaptation-driven shedding yields strictly
  fewer deadline misses than no shedding, and every dropped frame is
  accounted (ingested == delivered + dropped, completed + dropped ==
  ingested in Metrics).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import tiny
from repro.core import Category, DeepRT, ProfileTable, Request
from repro.ingest import (
    BurstSource,
    CameraSource,
    IngestGateway,
    ShedPolicy,
    StagingRing,
    TraceSource,
)
from repro.core.traces import TraceSpec
from repro.models import model_for
from repro.serving.engine import InferenceEngine

MID = "granite-3-2b"
SEQ = 16
SEQ_D = 8


# ---------------------------------------------------------------------------
# Sources
# ---------------------------------------------------------------------------


class TestSources:
    def test_plan_is_deterministic_and_rematerializable(self):
        a = CameraSource(period=0.1, n_frames=12, payload_shape=(SEQ,), seed=7)
        b = CameraSource(period=0.1, n_frames=12, payload_shape=(SEQ,), seed=7)
        pa, pb = a.plan(), b.plan()
        assert [f.offset for f in pa] == [f.offset for f in pb]
        for fa, fb in zip(pa, pb):
            assert np.array_equal(fa.payload, fb.payload)
        # Re-materializing the SAME source yields the same plan (no
        # hidden iteration state).
        assert [f.offset for f in a.plan()] == [f.offset for f in pa]

    def test_different_seeds_differ(self):
        a = CameraSource(period=0.1, n_frames=8, payload_shape=(SEQ,), seed=1)
        b = CameraSource(period=0.1, n_frames=8, payload_shape=(SEQ,), seed=2)
        assert any(
            not np.array_equal(x.payload, y.payload)
            for x, y in zip(a.plan(), b.plan())
        )

    def test_camera_jitter_bounded_and_ordered(self):
        src = CameraSource(
            period=0.1, n_frames=50, jitter_frac=0.5, payload_shape=(), seed=3
        )
        offs = [f.offset for f in src.plan()]
        assert offs == sorted(offs)
        assert all(o >= 0 for o in offs)
        half = 0.5 * 0.1 / 2
        assert all(abs(o - i * 0.1) <= half + 1e-12 for i, o in enumerate(offs))
        # Jitter actually present (not silently periodic).
        assert any(abs(o - i * 0.1) > 1e-6 for i, o in enumerate(offs))

    def test_burst_duty_compresses_arrivals(self):
        declared = BurstSource(
            period=0.1, n_frames=20, burst=4, duty=1.0, payload_shape=(), seed=0
        )
        overload = BurstSource(
            period=0.1, n_frames=20, burst=4, duty=0.5, payload_shape=(), seed=0
        )
        span_full = declared.plan()[-1].offset
        span_half = overload.plan()[-1].offset
        # Same frame budget in ~half the time: 2x instantaneous rate.
        assert span_half == pytest.approx(span_full * 0.5, rel=0.1)
        # The declared (admission-visible) rate is unchanged.
        assert overload.period == declared.period

    def test_trace_source_replays_trace_request(self):
        spec = TraceSpec(
            mean_period=0.2, mean_deadline=0.4, n_requests=3,
            models=(MID,), shapes=((SEQ,),), seed=5,
        )
        pairs = TraceSource.from_trace(spec, payload_shape=(SEQ,))
        assert len(pairs) == 3
        for req, src in pairs:
            assert src.period == req.period
            assert src.n_frames == req.n_frames
            offs = [f.offset for f in src.plan()]
            assert offs == pytest.approx(
                [i * req.period for i in range(req.n_frames)]
            )

    def test_validation(self):
        with pytest.raises(ValueError, match="period"):
            CameraSource(period=0.0, n_frames=5)
        with pytest.raises(ValueError, match="jitter"):
            CameraSource(period=0.1, n_frames=5, jitter_frac=1.5)
        with pytest.raises(ValueError, match="duty"):
            BurstSource(period=0.1, n_frames=5, duty=0.0)


# ---------------------------------------------------------------------------
# Staging ring
# ---------------------------------------------------------------------------


class TestStagingRing:
    def test_depth_validated(self):
        with pytest.raises(ValueError, match="depth"):
            StagingRing((4,), depth=1)

    def test_fixed_scratch_pool_cycles(self):
        ring = StagingRing((2, 4), depth=3)
        seen = []
        for _ in range(7):
            ring.stage(lambda buf: seen.append(id(buf)))
        # Round-robin over exactly ``depth`` buffers, allocated once.
        assert len(set(seen)) == 3
        assert seen[:3] == seen[3:6]
        assert ring.host_allocs == 3
        assert ring.fills == 7
        assert ring.bytes_staged == 7 * ring.frame_nbytes

    def test_consecutive_fills_use_distinct_buffers(self):
        """Fill buffer B while the in-flight job reads A: jobs N and N+1
        never share a scratch buffer."""
        ring = StagingRing((4,), depth=2)
        ids = []
        for _ in range(4):
            ring.stage(lambda buf: ids.append(id(buf)))
        assert all(a != b for a, b in zip(ids, ids[1:]))

    def test_stage_rows_pads_and_validates(self):
        ring = StagingRing((4, 3), depth=2)
        out = ring.stage_rows(np.ones((2, 3), np.int32), 2)
        arr = np.asarray(out)
        assert arr[:2].tolist() == np.ones((2, 3)).tolist()
        assert (arr[2:] == 0).all()
        with pytest.raises(ValueError, match="payload shape"):
            ring.stage_rows(np.ones((2, 5), np.int32), 2)
        with pytest.raises(ValueError, match="n_rows"):
            ring.stage_rows(None, 9)

    def test_wrong_dtype_payload_rejected(self):
        """Float bytes handed to an int token ring must fail at the
        boundary, not stage truncated garbage."""
        ring = StagingRing((4, 3), depth=2)
        with pytest.raises(ValueError, match="dtype"):
            ring.stage_rows(np.ones((2, 3), np.float32), 2)
        # Same-kind integer casts are fine.
        ring.stage_rows(np.ones((2, 3), np.int64), 2)

    def test_staged_bytes_correct_within_ring_window(self):
        """A staged array read before its scratch is refilled carries
        exactly the ingested bytes (uploads may alias host memory, so
        this holds only within the depth-1 window — the consumer guard
        enforces the window)."""
        ring = StagingRing((4,), depth=2)
        a = ring.stage_rows(np.full((4,), 1, np.int32), 4)
        b = ring.stage_rows(np.full((4,), 2, np.int32), 4)
        assert np.asarray(a).tolist() == [1, 1, 1, 1]
        assert np.asarray(b).tolist() == [2, 2, 2, 2]

    def test_consumer_guard_runs_before_scratch_reuse(self):
        """Refilling a scratch waits for the job that consumed it: the
        double-buffer correctness mechanism on zero-copy backends."""
        ring = StagingRing((4,), depth=2)
        order = []
        ring.stage(lambda buf: order.append("fill0"))  # scratch 0
        ring.attach_consumer(lambda: order.append("wait0"))
        ring.stage(lambda buf: order.append("fill1"))  # scratch 1
        ring.attach_consumer(lambda: order.append("wait1"))
        ring.stage(lambda buf: order.append("fill2"))  # scratch 0 again
        assert order == ["fill0", "fill1", "wait0", "fill2"]
        assert ring.consumer_waits == 1
        # Guards fire at most once each.
        ring.stage(lambda buf: None)  # scratch 1: wait1 fires
        ring.stage(lambda buf: None)  # scratch 0: no guard left
        assert order[-1] == "wait1"
        assert ring.consumer_waits == 2

    def test_attach_consumer_requires_a_stage(self):
        ring = StagingRing((4,), depth=2)
        with pytest.raises(RuntimeError, match="attach_consumer"):
            ring.attach_consumer(lambda: None)


# ---------------------------------------------------------------------------
# Engine payload fidelity (the no-more-synthetic-zeros bars)
# ---------------------------------------------------------------------------


def _engine(**kw):
    kw.setdefault("max_slots", 4)
    return InferenceEngine({MID: tiny(MID)}, **kw)


class TestPayloadFidelity:
    def test_prefill_bit_identical_to_dense_reference(self):
        e = _engine()
        model = model_for(tiny(MID))
        pay = np.random.default_rng(0).integers(
            0, 64, size=(3, SEQ), dtype=np.int32
        )
        out = e.dispatch(MID, (SEQ,), 3, "prefill", payload=pay).wait()
        logits, _ = jax.jit(model.forward)(e.params[MID], jnp.asarray(pay))
        ref = logits[:, -1].argmax(-1)
        assert bool(jnp.all(out[:3] == ref))

    def test_prefill_output_depends_on_payload(self):
        e = _engine()
        model = model_for(tiny(MID))
        rng = np.random.default_rng(1)
        p1 = rng.integers(0, 64, size=(2, SEQ), dtype=np.int32)
        p2 = p1.copy()
        p2[0, :] = (p2[0, :] + 17) % 64
        # Compare full last-token logits (argmax could coincide).
        l1, _ = jax.jit(model.forward)(e.params[MID], jnp.asarray(p1))
        l2, _ = jax.jit(model.forward)(e.params[MID], jnp.asarray(p2))
        assert not bool(jnp.all(l1[:, -1] == l2[:, -1]))
        o1 = e.dispatch(MID, (SEQ,), 2, "prefill", payload=p1).wait()
        o2 = e.dispatch(MID, (SEQ,), 2, "prefill", payload=p2).wait()
        assert bool(jnp.all(o1[:2] == l1[:, -1].argmax(-1)))
        assert bool(jnp.all(o2[:2] == l2[:, -1].argmax(-1)))

    def test_decode_prefix_payload_bit_identical(self):
        e = _engine()
        model = model_for(tiny(MID))
        toks = np.array([5, 42], np.int32)
        out = e.dispatch(MID, (SEQ_D,), 2, "decode", payload=toks).wait()
        ref, _ = jax.jit(model.decode_step)(
            e.params[MID],
            model.init_cache(2, SEQ_D),
            jnp.asarray(toks),
            jnp.full((2,), SEQ_D - 1, jnp.int32),
        )
        assert bool(jnp.all(out[:2] == ref))

    def test_decode_payload_differs_when_bytes_differ(self):
        outs = []
        for tok in (7, 9):
            e = _engine()
            outs.append(
                np.asarray(
                    e.dispatch(
                        MID, (SEQ_D,), 1, "decode",
                        payload=np.array([tok], np.int32),
                    ).wait()
                )[0]
            )
        assert not np.array_equal(outs[0], outs[1])

    def test_decode_slot_mode_dict_payload_bit_identical(self):
        e = _engine()
        model = model_for(tiny(MID))
        e.alloc_slots(MID, (SEQ_D,)[0], 3, start_pos=SEQ_D - 1)
        e.free_slots(MID, SEQ_D, [1])  # live rows 0, 2 (scattered)
        out = e.dispatch(
            MID, (SEQ_D,), 2, "decode", slots=(0, 2),
            payload={0: 11, 2: 29},
        ).wait()
        ref, _ = jax.jit(model.decode_step)(
            e.params[MID],
            model.init_cache(2, SEQ_D),
            jnp.array([11, 29], jnp.int32),
            jnp.full((2,), SEQ_D - 1, jnp.int32),
        )
        assert bool(jnp.all(out[jnp.array([0, 2])] == ref))

    def test_per_frame_row_list_cropped_to_shrunk_shape(self):
        """Adaptation's shape shrink applied to real bytes: a (SEQ,) row
        dispatched at seq SEQ//2 is cropped, matching the dense ref on
        the cropped tokens."""
        e = _engine()
        model = model_for(tiny(MID))
        row = np.arange(SEQ, dtype=np.int32) % 64
        half = SEQ // 2
        out = e.dispatch(MID, (half,), 1, "prefill", payload=[row]).wait()
        logits, _ = jax.jit(model.forward)(
            e.params[MID], jnp.asarray(row[:half][None, :])
        )
        assert bool(jnp.all(out[:1] == logits[:, -1].argmax(-1)))

    def test_payload_shape_mismatch_raises(self):
        e = _engine()
        with pytest.raises(ValueError, match="payload"):
            e.dispatch(
                MID, (SEQ,), 2, "prefill",
                payload=np.zeros((2, SEQ + 1), np.int32),
            )
        with pytest.raises(ValueError, match="slot ids"):
            e.dispatch(
                MID, (SEQ_D,), 1, "decode",
                slots=e.alloc_slots(MID, SEQ_D, 1),
                payload={99: 1},
            )

    def test_idle_leased_rows_do_not_consume_phantom_tokens(self):
        """A leased stream with no frame in a window stays INACTIVE for
        that step (step_rows): its cursor is frozen and its KV history
        never contains a phantom zero token — every stream's row stays
        bit-identical to a dense reference replaying only ITS OWN
        ingested tokens, at every step, not just the first."""
        e = _engine(max_slots=4)
        model = model_for(tiny(MID))
        step = jax.jit(model.decode_step)
        e.alloc_slots(MID, SEQ_D, 1)  # row 0: stream A
        e.alloc_slots(MID, SEQ_D, 1)  # row 1: stream B
        live = (0, 1)
        # Window 1: only A has a frame (token 3). B idles.
        e.dispatch(
            MID, (SEQ_D,), 2, "decode", slots=live,
            payload={0: 3}, step_rows=[0],
        ).wait()
        # Window 2: both have frames (A: 5, B: 7).
        out = e.dispatch(
            MID, (SEQ_D,), 2, "decode", slots=live,
            payload={0: 5, 1: 7}, step_rows=[0, 1],
        ).wait()
        # A == dense ref replaying [3, 5].
        cache = model.init_cache(1, SEQ_D)
        _, cache = step(
            e.params[MID], cache, jnp.array([3], jnp.int32),
            jnp.zeros((1,), jnp.int32),
        )
        ref_a, _ = step(
            e.params[MID], cache, jnp.array([5], jnp.int32),
            jnp.ones((1,), jnp.int32),
        )
        assert bool(jnp.all(out[0] == ref_a[0]))
        # B == dense ref of its FIRST token at cursor 0: the idle
        # window left no trace.
        ref_b, _ = step(
            e.params[MID], model.init_cache(1, SEQ_D),
            jnp.array([7], jnp.int32), jnp.zeros((1,), jnp.int32),
        )
        assert bool(jnp.all(out[1] == ref_b[0]))

    def test_step_rows_must_be_live(self):
        e = _engine(max_slots=4)
        slots = e.alloc_slots(MID, SEQ_D, 2)
        with pytest.raises(ValueError, match="step_rows"):
            e.dispatch(
                MID, (SEQ_D,), 2, "decode", slots=slots, step_rows=[3]
            )

    def test_staged_sweep_zero_recompiles(self):
        """1 -> max_slots -> 1 with REAL payloads: still one program."""
        e = _engine()
        e.execute(MID, (SEQ_D,), 1, kind="decode")  # warm-up compile
        e.reset_stats()
        rng = np.random.default_rng(2)
        m = e.max_slots
        for b in list(range(1, m + 1)) + list(range(m - 1, 0, -1)):
            pay = rng.integers(0, 64, size=(b,), dtype=np.int32)
            e.dispatch(MID, (SEQ_D,), b, "decode", payload=pay)
        e.dispatch(MID, (SEQ_D,), 1, "decode").wait()
        assert e.stats["decode_compiles"] == 0
        # The staged loop allocated no fresh host buffers either.
        ring = e.staging_ring("decode", MID, SEQ_D, m)
        assert ring.host_allocs == ring.depth


class TestDoubleBufferInterleaving:
    def test_inflight_job_never_observes_next_payload(self):
        """Dispatch N, then fill+dispatch N+1 BEFORE waiting on N: both
        outputs must match their own payload's dense reference."""
        e = _engine()
        model = model_for(tiny(MID))
        fwd = jax.jit(model.forward)
        rng = np.random.default_rng(3)
        pays = [
            rng.integers(0, 64, size=(2, SEQ), dtype=np.int32)
            for _ in range(6)
        ]
        handles = []
        for i, pay in enumerate(pays):
            handles.append(e.dispatch(MID, (SEQ,), 2, "prefill", payload=pay))
            if i % 2:  # drain in pairs: two staged jobs in flight at once
                for h, p in zip(handles, pays[i - 1 : i + 1]):
                    ref = fwd(e.params[MID], jnp.asarray(p))[0][:, -1].argmax(-1)
                    assert bool(jnp.all(h.wait()[:2] == ref))
                handles = []

    @pytest.mark.slow
    def test_hypothesis_interleaved_payload_isolation(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (installed in CI); a bare "
            "env skips instead of erroring at collection",
        )
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        e = _engine()
        model = model_for(tiny(MID))
        fwd = jax.jit(model.forward)

        @settings(
            max_examples=10, deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )
        @given(
            st.lists(
                st.lists(
                    st.integers(min_value=0, max_value=63),
                    min_size=SEQ, max_size=SEQ,
                ),
                min_size=2, max_size=4,
            )
        )
        def prop(rows):
            pays = [np.asarray([r], np.int32) for r in rows]
            # Pipeline every job before waiting on any earlier one.
            handles = [
                e.dispatch(MID, (SEQ,), 1, "prefill", payload=p) for p in pays
            ]
            for h, p in zip(handles, pays):
                ref = fwd(e.params[MID], jnp.asarray(p))[0][:, -1].argmax(-1)
                assert bool(jnp.all(h.wait()[:1] == ref))

        prop()


# ---------------------------------------------------------------------------
# Gateway over a simulated DeepRT
# ---------------------------------------------------------------------------


def _sim_table(a: float = 0.01, c: float = 0.04) -> ProfileTable:
    table = ProfileTable()
    for b in (1, 2, 4, 8, 16, 32):
        table.record("m", (4,), b, a + c * b)
    return table


CAT = Category("m", (4,))


class TestGatewaySimulation:
    def test_lifecycle_and_arrival_stamped_deadlines(self):
        sched = DeepRT(_sim_table())
        gw = IngestGateway(sched)
        src = CameraSource(
            period=0.2, n_frames=10, jitter_frac=0.4, payload_shape=(4,), seed=4
        )
        session = gw.register(src, CAT, relative_deadline=0.5)
        assert session.state == "active"
        m = sched.run()
        assert m.completed_frames == 10
        assert session.conserved()
        # Frames arrived at the SOURCE's jittered offsets (not the
        # declared period), deadline-stamped at arrival.
        offs = [f.offset for f in src.plan()]
        for i, off in enumerate(offs):
            arrival, deadline, _ = m.frame_records[(session.request_id, i)]
            assert arrival == pytest.approx(off)
            assert deadline == pytest.approx(off + 0.5)

    def test_rejected_session_delivers_nothing(self):
        # Saturate: a stream whose own declared load breaks the bound.
        sched = DeepRT(_sim_table(a=0.5, c=0.5))
        gw = IngestGateway(sched)
        src = CameraSource(period=0.1, n_frames=5, payload_shape=(4,), seed=0)
        session = gw.register(src, CAT, relative_deadline=0.2)
        assert session.state == "rejected"
        sched.run()
        assert sched.metrics.completed_frames == 0
        assert session.frames_ingested == 0

    def test_close_cancels_remaining_arrivals(self):
        sched = DeepRT(_sim_table())
        gw = IngestGateway(sched)
        src = CameraSource(period=0.2, n_frames=10, payload_shape=(4,), seed=1)
        session = gw.register(src, CAT, relative_deadline=0.5)
        sched.run(until=0.7)  # frames 0..3 arrived
        # Fired deliveries pruned themselves: only the pending tail is
        # left to cancel (cancelling fired ids would leak them into the
        # loop's cancelled-set).
        assert len(session._events) == 10 - session.frames_ingested
        gw.close(session)
        assert session._events == set()
        sched.run()
        assert session.state == "closed"
        assert sched.metrics.completed_frames < 10
        assert session.conserved()

    def test_e2e_latency_recorded(self):
        sched = DeepRT(_sim_table())
        gw = IngestGateway(sched)
        src = CameraSource(period=0.2, n_frames=6, payload_shape=(4,), seed=2)
        gw.register(src, CAT, relative_deadline=0.5)
        m = sched.run()
        assert len(m.e2e_latencies) == m.completed_frames == 6
        assert m.mean_e2e_latency > 0
        # No upstream queueing here: e2e == scheduler-arrival latency.
        assert m.e2e_latencies == pytest.approx(m.frame_latencies)


class TestLoadShedding:
    def _overloaded(self, shedding: bool, mode: str = "drop"):
        sched = DeepRT(_sim_table())
        gw = IngestGateway(
            sched,
            shedding=shedding,
            default_policy=ShedPolicy(mode=mode),
        )
        # Declared: 1 frame / 0.1s (admissible); delivered: 2.5x that in
        # bursts — the overload admission never saw.
        src = BurstSource(
            period=0.1, n_frames=50, burst=5, duty=0.4,
            payload_shape=(4,), seed=6,
        )
        session = gw.register(src, CAT, relative_deadline=0.2)
        assert session.state == "active"
        m = sched.run()
        return session, m

    def test_shedding_strictly_reduces_misses_under_overload(self):
        _, m_off = self._overloaded(shedding=False)
        s_on, m_on = self._overloaded(shedding=True)
        assert m_off.missed_frames > 0  # overload really overloads
        assert m_on.missed_frames < m_off.missed_frames
        assert m_on.dropped_frames > 0

    def test_every_dropped_frame_accounted(self):
        session, m = self._overloaded(shedding=True)
        assert session.conserved()
        assert session.frames_ingested == 50
        # delivered_frames is counted independently (at ingest_frame),
        # so this conservation check is falsifiable, not definitional.
        assert m.delivered_frames == session.frames_delivered
        assert m.completed_frames + m.dropped_frames == m.ingested_frames
        assert m.completed_frames + m.dropped_frames == 50
        assert m.drops_by_request.get(session.request_id) == m.dropped_frames

    def test_subsample_keeps_some_frames_while_over_budget(self):
        s_drop, _ = self._overloaded(shedding=True, mode="drop")
        s_sub, _ = self._overloaded(shedding=True, mode="subsample")
        assert 0 < s_sub.frames_dropped < s_drop.frames_dropped

    def test_sheds_reported_to_adaptation(self):
        sched = DeepRT(_sim_table())
        gw = IngestGateway(sched, shedding=True)
        src = BurstSource(
            period=0.1, n_frames=50, burst=5, duty=0.4,
            payload_shape=(4,), seed=6,
        )
        s = gw.register(src, CAT, relative_deadline=0.2)
        sched.run()
        assert sched.adaptation.sheds.get(CAT, 0) == s.frames_dropped > 0

    def test_penalized_category_sheds_earlier(self):
        """AdaptationModule.shed_scale tightens the budget while the
        category carries overrun penalty (the arrival-side coupling)."""
        sched = DeepRT(_sim_table())
        assert sched.adaptation.shed_scale(CAT) == 1.0
        sched.adaptation.penalties[CAT] = 0.05
        assert (
            sched.adaptation.shed_scale(CAT)
            == sched.adaptation.PENALIZED_BUDGET_TIGHTEN
            > 1.0
        )
        sched.adaptation.enabled = False
        assert sched.adaptation.shed_scale(CAT) == 1.0


class TestDisBatcherLateFrames:
    def test_frame_after_timer_retirement_still_flushes(self):
        """A jittered frame landing after the declared last arrival must
        re-arm the window timer, not strand in the queue."""
        table = _sim_table()
        sched = DeepRT(table)
        req = Request(category=CAT, period=0.1, relative_deadline=0.4, n_frames=2)
        assert sched.submit_request(req, external_arrivals=True).admitted
        sched.ingest_frame(req, 0, payload=np.zeros(4, np.int32))
        sched.run()  # drains; timer retires (requests look exhausted)
        # Late frame, well past request.end_time:
        sched.loop.schedule(
            sched.loop.now + 1.0,
            lambda: sched.ingest_frame(req, 1, payload=np.zeros(4, np.int32)),
        )
        m = sched.run()
        assert m.completed_frames == 2


# ---------------------------------------------------------------------------
# Gateway over the live cluster path (real compiled programs)
# ---------------------------------------------------------------------------


class TestGatewayLiveCluster:
    @pytest.fixture(scope="class")
    def served(self):
        from repro.serving.batcher_bridge import build_live_cluster

        configs = {MID: tiny(MID)}
        cats = [(MID, (SEQ,), "prefill"), (MID, (SEQ_D,), "decode")]
        cluster, slices = build_live_cluster(
            configs, cats, slice_names=("s0", "s1"), batch_sizes=(1, 2),
            profile_runs=2, nonrt_cap=1,
        )
        # Record every dispatched decode handle + its job so payload
        # routing can be checked against the model reference.
        captured = []
        for sl in slices.values():
            inner = sl.device.dispatch_fn

            def spy(job, _inner=inner, _sl=sl):
                handle = _inner(job)
                captured.append((_sl, job, handle))
                return handle

            sl.device.dispatch_fn = spy
        gw = IngestGateway(cluster)
        sessions = [
            gw.register(
                CameraSource(period=0.2, n_frames=4, payload_shape=(), seed=20 + i),
                Category(MID, (SEQ_D,)),
                relative_deadline=0.4,
            )
            for i in range(3)
        ]
        cluster.run()
        return cluster, slices, gw, sessions, captured

    def test_streams_admitted_and_served(self, served):
        cluster, _, _, sessions, _ = served
        assert [s.state for s in sessions] == ["active"] * 3
        agg = cluster.aggregate_metrics()
        assert agg["completed_frames"] + agg["dropped_frames"] == 12
        assert all(s.conserved() for s in sessions)

    def test_placement_spreads_streams(self, served):
        _, _, _, sessions, _ = served
        assert len({s.slice_name for s in sessions}) == 2

    def test_zero_decode_recompiles_and_ring_reuse(self, served):
        _, slices, _, _, _ = served
        for sl in slices.values():
            assert sl.engine.stats["decode_compiles"] == 0
            for ring in sl.engine._rings.values():
                assert ring.host_allocs == ring.depth

    def test_leases_released_when_streams_drain(self, served):
        _, slices, _, _, _ = served
        for sl in slices.values():
            assert sl.leases == {}
            for (mid, seq), arena in sl.engine._arenas.items():
                assert len(arena.free) == arena.max_slots

    def test_slot_payloads_route_to_leased_rows(self, served):
        """The FIRST decode job on each slice: every index-0 frame's
        ingested token must produce, at some arena row, logits
        bit-identical to a fresh single-row reference fed that token at
        cursor 0 — payloads reached their streams' resident rows.
        (Later jobs depend on each row's KV history: continuous
        batching steps ALL leased rows every window, so only the first
        job has a clean-slate reference.)"""
        _, slices, _, sessions, captured = served
        model = model_for(tiny(MID))
        step = jax.jit(model.decode_step)
        by_rid = {s.request_id: s for s in sessions}
        first_seen = set()
        checked = 0
        for sl, job, handle in captured:
            if job.category.shape_key != (SEQ_D,):
                continue
            if sl.spec.name in first_seen:
                continue
            first_seen.add(sl.spec.name)
            out = np.asarray(handle.wait())
            for frame in job.frames:
                if frame.payload is None or frame.request_id not in by_rid:
                    continue
                if frame.index != 0:
                    continue
                tok = int(np.asarray(frame.payload))
                ref, _ = step(
                    sl.engine.params[MID],
                    model.init_cache(1, SEQ_D),
                    jnp.array([tok], jnp.int32),
                    jnp.zeros((1,), jnp.int32),
                )
                matches = [
                    r for r in range(out.shape[0])
                    if np.array_equal(out[r], np.asarray(ref)[0])
                ]
                assert matches, (sl.spec.name, frame.request_id, tok)
                checked += 1
        assert checked >= 1


class TestSlotPayloadCollision:
    def test_same_stream_two_frames_one_window_counted_earliest_wins(self):
        """One decode step consumes one token per leased row: when a
        window batches two frames of the same stream, the earliest
        token stages (in order) and the collision is COUNTED — visible
        degradation, never a silent overwrite."""
        from repro.serving.batcher_bridge import build_live_cluster

        configs = {MID: tiny(MID)}
        cats = [(MID, (SEQ_D,), "decode")]
        cluster, slices = build_live_cluster(
            configs, cats, slice_names=("s0",), batch_sizes=(1, 2),
            profile_runs=2, nonrt_cap=1,
        )
        sl = slices["s0"]
        sched = sl.scheduler
        req = Request(
            category=Category(MID, (SEQ_D,)), period=0.2,
            relative_deadline=0.4, n_frames=2,
        )
        assert cluster.submit_request(req, external_arrivals=True)
        # Both frames delivered back-to-back, well inside one window.
        sched.ingest_frame(req, 0, payload=np.int32(7))
        sched.ingest_frame(req, 1, payload=np.int32(9))
        cluster.run()
        m = sched.metrics
        assert m.completed_frames == 2
        assert m.payload_collisions == 1
        assert m.delivered_frames == 2
        assert sl.leases == {}  # both frames counted: lease released


class TestLeaselessDecodeFrames:
    def test_closed_stream_frame_does_not_phantom_step_survivors(self):
        """A frame whose stream lost its lease (closed with the frame
        still queued in the window) must step NO arena row active —
        surviving streams' cursors stay frozen, no phantom zero token."""
        from repro.serving.batcher_bridge import build_live_cluster

        configs = {MID: tiny(MID)}
        cats = [(MID, (SEQ_D,), "decode")]
        cluster, slices = build_live_cluster(
            configs, cats, slice_names=("s0",), batch_sizes=(1, 2),
            profile_runs=2, nonrt_cap=1,
        )
        sl = slices["s0"]
        sched = sl.scheduler
        req_a = Request(category=Category(MID, (SEQ_D,)), period=0.2,
                        relative_deadline=0.4, n_frames=1)
        req_b = Request(category=Category(MID, (SEQ_D,)), period=0.2,
                        relative_deadline=0.4, n_frames=1)
        assert cluster.submit_request(req_a, external_arrivals=True)
        assert cluster.submit_request(req_b, external_arrivals=True)
        sched.ingest_frame(req_a, 0, payload=np.int32(5))
        # A closes before the window joint: its lease is gone but its
        # frame is already queued.
        sl.release(req_a.request_id)
        row_b = sl.leases[req_b.request_id][2][0]
        cluster.run()
        arena = sl.engine.arena(MID, SEQ_D)
        # B's cursor never advanced: no phantom zero token consumed.
        assert int(np.asarray(arena.cur)[row_b]) == 0
        assert sched.metrics.completed_frames == 1  # A's frame drained

    def test_payload_decode_without_leases_fails_loudly(self):
        """The single-device (prefix-mode) serving path must refuse
        payload-carrying decode jobs instead of assigning rows
        positionally per window (silent cross-stream corruption)."""
        from repro.serving.batcher_bridge import build_live_scheduler

        sched, engine, table = build_live_scheduler(
            {MID: tiny(MID)}, [(MID, (SEQ_D,), "decode")],
            batch_sizes=(1, 2),
        )
        gw = IngestGateway(sched)
        with pytest.raises(ValueError, match="cluster path"):
            gw.register(
                CameraSource(period=0.2, n_frames=2, payload_shape=(), seed=0),
                Category(MID, (SEQ_D,)), relative_deadline=0.4,
            )


class TestGatewayShedReleasesLease:
    def test_dropped_frames_still_release_lease(self):
        """A truncated (shed) stream must not pin its arena row forever:
        note_dropped advances the lease countdown."""
        from repro.serving.batcher_bridge import build_live_cluster

        configs = {MID: tiny(MID)}
        cats = [(MID, (SEQ_D,), "decode")]
        cluster, slices = build_live_cluster(
            configs, cats, slice_names=("s0",), batch_sizes=(1, 2),
            profile_runs=2, nonrt_cap=1,
        )
        gw = IngestGateway(cluster)
        session = gw.register(
            CameraSource(period=0.2, n_frames=4, payload_shape=(), seed=9),
            Category(MID, (SEQ_D,)),
            relative_deadline=0.4,
        )
        assert session.state == "active"
        sl = slices["s0"]
        # Force-shed half the stream by hand-invoking the drop path.
        sched = sl.scheduler
        gw._shed(session, sched, Category(MID, (SEQ_D,)))
        gw._shed(session, sched, Category(MID, (SEQ_D,)))
        session.frames_ingested += 2
        # Deliver only the remaining two frames (event ids are issued in
        # schedule order, so the two lowest are frames 0 and 1).
        for ev in sorted(session._events)[:2]:
            cluster.loop.cancel(ev)
            session._events.discard(ev)
        cluster.run()
        assert sl.leases == {}  # released despite only 2 completions
        assert sched.metrics.dropped_frames == 2
