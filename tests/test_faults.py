"""Fault injection + device health watchdog (core/faults.py and the
cluster health machinery in core/cluster.py).

Covers, in virtual time unless stated otherwise:

- FaultPlan determinism (same seed -> identical plan) and validation;
- CompletionWatchdog deadlines/heartbeats, loop-generically (EventLoop);
- FaultyDevice behaviors per fault kind over SequentialDevice;
- the healthy -> suspect -> quarantined state machine, including hang
  quarantine, sustained-drift quarantine, recovery with live WCET
  re-profiling, suspect slices receiving no placements, and the
  adaptation-module degraded coupling;
- fail_slice error regressions (unknown slice / double failure);
- the deadline-aware parked-tail retry queue (admitted later vs provably
  expired) and its accounting;
- EDF transient-submit-error retry;
- the conservation identity ``completed + dropped + lost == ingested``
  under seed-driven fault plans (deterministic sweep + hypothesis);
- WallClock hold/release concurrency and AsyncDevice close-with-timeout
  on a wedged waiter (wall clock, no compiled programs).
"""
import threading
import time

import pytest

from repro.core import (
    Category,
    ClusterScheduler,
    CompletionWatchdog,
    DELAY,
    DEATH,
    DeviceDeadError,
    EventLoop,
    FaultPlan,
    FaultSpec,
    FaultyDevice,
    HEALTHY,
    ProfileTable,
    QUARANTINED,
    Request,
    SliceSpec,
    STALL,
    SUBMIT_ERROR,
    SUSPECT,
    TransientSubmitError,
    WatchdogConfig,
    build_sim_cluster,
)
from repro.core.simulator import SequentialDevice, WallClock
from repro.serving.async_device import AsyncDevice

MID = "m"
CAT = Category(MID, (3, 224, 224))


def make_table() -> ProfileTable:
    t = ProfileTable()
    b = 1
    while b <= 16:
        t.record(MID, (3, 224, 224), b, 0.004 + 0.0015 * b)
        b *= 2
    return t


def req(period=0.05, deadline=0.5, n_frames=20, start=None):
    kw = {} if start is None else {"start_time": start}
    return Request(
        category=CAT, period=period, relative_deadline=deadline,
        n_frames=n_frames, **kw,
    )


# ---------------------------------------------------------------------------
# FaultPlan: determinism + validation
# ---------------------------------------------------------------------------
class TestFaultPlan:
    def test_from_seed_deterministic(self):
        kw = dict(n_submits=200, p_delay=0.1, p_stall=0.05, p_error=0.05,
                  p_death=0.02, delay_extra=(0.0, 0.1))
        a = FaultPlan.from_seed(7, **kw)
        b = FaultPlan.from_seed(7, **kw)
        assert len(a) == len(b) > 0
        assert [(s.kind, s.at_submit, s.factor, s.extra) for s in a.specs] == [
            (s.kind, s.at_submit, s.factor, s.extra) for s in b.specs
        ]

    def test_different_seeds_differ(self):
        kw = dict(n_submits=400, p_delay=0.2, p_stall=0.1)
        a = FaultPlan.from_seed(1, **kw)
        b = FaultPlan.from_seed(2, **kw)
        assert [(s.kind, s.at_submit) for s in a.specs] != [
            (s.kind, s.at_submit) for s in b.specs
        ]

    def test_probabilities_must_sum_to_one(self):
        with pytest.raises(ValueError, match="sum"):
            FaultPlan.from_seed(0, 10, p_delay=0.6, p_stall=0.6)

    def test_duplicate_submit_index_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            FaultPlan((FaultSpec(DELAY, 3), FaultSpec(STALL, 3)))

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("melt", 0)
        with pytest.raises(ValueError, match="at_submit"):
            FaultSpec(STALL, -1)
        with pytest.raises(ValueError, match="actually delay"):
            FaultSpec(DELAY, 0, factor=0.5)
        # factor < 1 is fine when extra provides the lateness:
        FaultSpec(DELAY, 0, factor=0.5, extra=0.2)

    def test_empty_plan(self):
        plan = FaultPlan()
        assert len(plan) == 0
        assert plan.for_submit(0) is None


# ---------------------------------------------------------------------------
# WatchdogConfig: knobs + derived deadlines
# ---------------------------------------------------------------------------
class TestWatchdogConfig:
    def test_validation(self):
        with pytest.raises(ValueError, match="slack"):
            WatchdogConfig(slack=1.0)
        with pytest.raises(ValueError, match="hang_slack"):
            WatchdogConfig(slack=4.0, hang_slack=3.0)
        with pytest.raises(ValueError, match="suspect_after"):
            WatchdogConfig(suspect_after=0)
        with pytest.raises(ValueError, match="reprofile_quantile"):
            WatchdogConfig(reprofile_quantile=1.5)

    def test_deadline_floor_and_hang(self):
        cfg = WatchdogConfig(slack=4.0, hang_slack=12.0, min_deadline=0.05)
        assert cfg.deadline_for(0.001) == 0.05  # floored
        assert cfg.deadline_for(0.1) == pytest.approx(0.4)
        # hang threshold scales off the (possibly floored) deadline:
        assert cfg.hang_after(0.001) == pytest.approx(0.05 * 3)
        assert cfg.hang_after(0.1) == pytest.approx(0.4 * 3)


# ---------------------------------------------------------------------------
# CompletionWatchdog under the virtual EventLoop
# ---------------------------------------------------------------------------
class TestCompletionWatchdog:
    def make(self, **cfg_kw):
        loop = EventLoop()
        cfg = WatchdogConfig(**{"slack": 2.0, "hang_slack": 10.0, **cfg_kw})
        fired = []
        wd = CompletionWatchdog(
            loop, cfg, on_overdue=lambda job, exp, el: fired.append((job, exp, el))
        )
        return loop, wd, fired

    def test_completion_before_deadline_never_fires(self):
        loop, wd, fired = self.make()
        wd.started("j", 0.1)
        loop.schedule(0.15, wd.completed)  # deadline is 0.2
        loop.run()
        assert fired == []
        assert wd.overdue_events == 0

    def test_overdue_fires_at_deadline_then_heartbeats(self):
        loop, wd, fired = self.make()
        wd.started("j", 0.1)  # deadline 0.2, heartbeat defaults to 0.2
        loop.run(until=0.65)
        assert [round(e, 3) for _, _, e in fired] == [0.2, 0.4, 0.6]
        assert all(j == "j" and exp == 0.1 for j, exp, _ in fired)
        wd.close()  # stop the heartbeat so the heap can drain

    def test_completed_stops_heartbeat(self):
        loop, wd, fired = self.make()
        wd.started("j", 0.1)
        loop.schedule(0.25, wd.completed)  # one overdue beat, then done
        loop.run()
        assert len(fired) == 1

    def test_overlapping_submits_raise(self):
        _, wd, _ = self.make()
        wd.started("a", 0.1)
        with pytest.raises(RuntimeError, match="overlapping"):
            wd.started("b", 0.1)

    def test_close_silences_pending_check(self):
        loop, wd, fired = self.make()
        wd.started("j", 0.1)
        loop.schedule(0.05, wd.close)
        loop.run()
        assert fired == []

    def test_stale_token_from_previous_submit_ignored(self):
        loop, wd, fired = self.make(min_deadline=0.3)
        wd.started("old", 0.1)
        loop.schedule(0.1, wd.completed)
        # A fresh submit before the old deadline would have fired: its
        # check must key on the NEW token, not trip on the old schedule.
        loop.schedule(0.15, lambda: wd.started("new", 0.1))
        loop.schedule(0.2, wd.completed)
        loop.run()
        assert fired == []


# ---------------------------------------------------------------------------
# FaultyDevice over SequentialDevice (virtual time)
# ---------------------------------------------------------------------------
class TestFaultyDeviceSim:
    def make(self, specs, **kw):
        loop = EventLoop()
        dev = FaultyDevice(SequentialDevice(loop), FaultPlan(tuple(specs)), **kw)
        done = []
        return loop, dev, done

    def test_clean_submit_passes_through(self):
        loop, dev, done = self.make([])
        dev.submit("j", 0.1, lambda j, t: done.append((j, t)))
        assert not dev.idle and dev.busy_until == pytest.approx(0.1)
        loop.run()
        assert done == [("j", pytest.approx(0.1))]
        assert dev.idle
        assert dev.injected == []

    def test_delay_lands_at_max_of_factor_and_extra(self):
        loop, dev, done = self.make(
            [FaultSpec(DELAY, 0, factor=3.0), FaultSpec(DELAY, 1, factor=1.0, extra=0.5)]
        )
        dev.submit("a", 0.1, lambda j, t: done.append((j, t)))
        loop.run()
        dev.submit("b", 0.1, lambda j, t: done.append((j, t)))
        loop.run()
        assert done[0] == ("a", pytest.approx(0.3))  # 0.1 * 3
        assert done[1] == ("b", pytest.approx(0.3 + 0.6))  # + (0.1 + 0.5)
        assert [(i, k) for i, k, _ in dev.injected] == [(0, DELAY), (1, DELAY)]

    def test_stall_never_completes(self):
        loop, dev, done = self.make([FaultSpec(STALL, 0)])
        dev.submit("j", 0.1, lambda j, t: done.append(j))
        loop.run()
        assert done == []
        assert not dev.idle
        assert dev.busy_until == float("inf")

    def test_submit_error_is_transient(self):
        errors = []
        loop, dev, done = self.make(
            [FaultSpec(SUBMIT_ERROR, 0)], on_submit_error=lambda: errors.append(1)
        )
        with pytest.raises(TransientSubmitError):
            dev.submit("j", 0.1, lambda j, t: done.append(j))
        assert errors == [1]
        assert dev.idle  # the device itself is unharmed
        dev.submit("j", 0.1, lambda j, t: done.append(j))  # retry succeeds
        loop.run()
        assert done == ["j"]

    def test_death_stalls_then_refuses(self):
        loop, dev, done = self.make([FaultSpec(DEATH, 0)])
        dev.submit("a", 0.1, lambda j, t: done.append(j))
        loop.run()
        assert done == [] and not dev.idle
        with pytest.raises(DeviceDeadError, match="died at submit 0"):
            dev.submit("b", 0.1, lambda j, t: done.append(j))

    def test_on_idle_forwards_to_inner(self):
        loop, dev, _ = self.make([])
        calls = []
        dev.on_idle = lambda: calls.append(1)
        assert dev.inner.on_idle is dev.on_idle
        dev.submit("j", 0.1, lambda j, t: None)
        loop.run()
        assert calls == [1]

    def test_watchdog_and_measured_wiring(self):
        loop = EventLoop()
        overdue, measured = [], []
        wd = CompletionWatchdog(
            loop, WatchdogConfig(slack=2.0, hang_slack=10.0),
            on_overdue=lambda j, e, el: overdue.append(el),
        )
        dev = FaultyDevice(
            SequentialDevice(loop),
            FaultPlan((FaultSpec(DELAY, 1, factor=5.0),)),
            watchdog=wd,
            on_measured=lambda exp, act: measured.append((exp, act)),
        )
        dev.submit("a", 0.1, lambda j, t: None)
        loop.run()
        dev.submit("b", 0.1, lambda j, t: None)
        loop.run()
        assert measured[0] == (0.1, pytest.approx(0.1))
        assert measured[1] == (0.1, pytest.approx(0.5))  # the injected delay
        assert overdue  # the delayed submit crossed its 0.2s deadline

    def test_close_swallows_inflight_completion(self):
        loop, dev, done = self.make([])
        dev.submit("j", 0.1, lambda j, t: done.append(j))
        loop.schedule(0.05, dev.close)
        loop.run()
        assert done == []
        assert dev.closed and not dev.idle


# ---------------------------------------------------------------------------
# Health state machine over the simulated cluster
# ---------------------------------------------------------------------------
WD = dict(slack=2.0, hang_slack=8.0, min_deadline=0.0)


class TestHealthStateMachine:
    def test_stall_quarantines_via_hang(self):
        cfg = WatchdogConfig(suspect_after=2, quarantine_after=50, **WD)
        plans = {"s0": FaultPlan((FaultSpec(STALL, 3),))}
        cluster = build_sim_cluster(make_table, ("s0",), fault_plans=plans,
                                    watchdog=cfg)
        assert cluster.submit_request(req(n_frames=30))
        cluster.run()
        assert cluster.slices["s0"].health == QUARANTINED
        assert not cluster.slices["s0"].alive  # auto fail_slice, no operator
        reasons = [r for _, _, _, new, r in cluster.health.transitions
                   if new == QUARANTINED]
        assert reasons and "hung" in reasons[0]

    def test_sustained_drift_suspect_then_quarantine(self):
        cfg = WatchdogConfig(suspect_after=2, quarantine_after=4, **WD)
        plans = {"s0": FaultPlan(tuple(FaultSpec(DELAY, i, factor=3.0)
                                       for i in range(2, 12)))}
        cluster = build_sim_cluster(make_table, ("s0",), fault_plans=plans,
                                    watchdog=cfg)
        assert cluster.submit_request(req(n_frames=40))
        cluster.run()
        states = [(old, new) for _, _, old, new, _ in cluster.health.transitions]
        assert (HEALTHY, SUSPECT) in states
        assert (SUSPECT, QUARANTINED) in states
        agg = cluster.aggregate_metrics()
        assert (agg["completed_frames"] + agg["dropped_frames"]
                + agg["lost_frames"]) == agg["ingested_frames"]

    def test_suspect_entry_reprofiles_from_measured_drift(self):
        cfg = WatchdogConfig(suspect_after=2, quarantine_after=50,
                             reprofile_samples=4, **WD)
        plans = {"s0": FaultPlan(tuple(FaultSpec(DELAY, i, factor=3.0)
                                       for i in range(2, 6)))}
        cluster = build_sim_cluster(make_table, ("s0",), fault_plans=plans,
                                    watchdog=cfg)
        assert cluster.submit_request(req(n_frames=30))
        base = cluster.slices["s0"].spec.table.wcet(MID, (3, 224, 224), 1)
        cluster.run()
        assert cluster.health.reprofiles.get("s0", 0) >= 1
        # The live table is the base table rescaled by the measured drift:
        assert cluster.slices["s0"].scheduler.table.wcet(
            MID, (3, 224, 224), 1
        ) == pytest.approx(base * cluster.slices["s0"].slow_factor)

    def test_recovery_restores_health_and_table(self):
        cfg = WatchdogConfig(suspect_after=2, quarantine_after=50,
                             recover_after=3, **WD)
        plans = {"s0": FaultPlan(tuple(FaultSpec(DELAY, i, factor=3.0)
                                       for i in range(2, 8)))}
        cluster = build_sim_cluster(make_table, ("s0",), fault_plans=plans,
                                    watchdog=cfg)
        assert cluster.submit_request(req(n_frames=40))
        cluster.run()
        sl = cluster.slices["s0"]
        assert sl.health == HEALTHY and sl.alive
        states = [(old, new) for _, _, old, new, _ in cluster.health.transitions]
        assert states == [(HEALTHY, SUSPECT), (SUSPECT, HEALTHY)]
        # Recovery re-profiled from the clean completions: back near base.
        assert sl.slow_factor == pytest.approx(1.0, abs=0.05)
        assert cluster.health.reprofiles["s0"] == 2  # entry + recovery

    def test_suspect_slice_gets_no_placements(self):
        cluster = build_sim_cluster(make_table, ("s0", "s1"))
        cluster.health._set_state("s0", SUSPECT, "test")
        r = req(n_frames=5)
        assert cluster.submit_request(r)
        assert cluster.placement[r.request_id] == "s1"
        # Back to healthy: eligible again.
        cluster.health._set_state("s0", HEALTHY, "test")
        r2 = req(n_frames=5)
        assert cluster.submit_request(r2)
        assert cluster.placement[r2.request_id] == "s0"  # lower utilization

    def test_adaptation_degraded_coupling(self):
        cluster = build_sim_cluster(make_table, ("s0",))
        adaptation = cluster.slices["s0"].scheduler.adaptation
        assert adaptation.shed_scale(CAT) == 1.0
        cluster.health._set_state("s0", SUSPECT, "test")
        assert adaptation.device_degraded
        assert adaptation.shed_scale(CAT) == adaptation.DEGRADED_BUDGET_TIGHTEN
        cluster.health._set_state("s0", HEALTHY, "test")
        assert not adaptation.device_degraded
        assert adaptation.shed_scale(CAT) == 1.0

    def test_operator_fail_slice_takes_health_path(self):
        cluster = build_sim_cluster(make_table, ("s0", "s1"))
        seen = []
        cluster.health.subscribe(lambda name, old, new: seen.append((name, old, new)))
        cluster.fail_slice("s0")
        assert cluster.slices["s0"].health == QUARANTINED
        assert seen == [("s0", HEALTHY, QUARANTINED)]
        assert any("operator" in r for _, n, _, _, r in cluster.health.transitions
                   if n == "s0")

    def test_mark_slow_none_uses_measured_drift(self):
        cluster = build_sim_cluster(make_table, ("s0",),
                                    watchdog=WatchdogConfig(**WD))
        for _ in range(8):
            cluster.health.note_complete("s0", 0.1, 0.25)
        factor = cluster.mark_slow("s0")
        assert factor == pytest.approx(2.5)
        assert cluster.slices["s0"].slow_factor == pytest.approx(2.5)
        # Explicit factor still honored (tests / forced degradation):
        assert cluster.mark_slow("s0", 4.0) == 4.0
        assert cluster.slices["s0"].slow_factor == 4.0

    def test_mark_slow_none_without_samples_raises(self):
        cluster = build_sim_cluster(make_table, ("s0",))
        with pytest.raises(RuntimeError, match="no measured completions"):
            cluster.mark_slow("s0")

    def test_edf_retries_transient_submit_error(self):
        plans = {"s0": FaultPlan((FaultSpec(SUBMIT_ERROR, 2),))}
        cluster = build_sim_cluster(make_table, ("s0",), fault_plans=plans)
        assert cluster.submit_request(req(n_frames=10))
        cluster.run()
        agg = cluster.aggregate_metrics()
        assert agg["submit_retries"] == 1
        assert agg["completed_frames"] == 10  # nothing lost to the blip
        assert agg["lost_frames"] == 0


# ---------------------------------------------------------------------------
# fail_slice error regressions
# ---------------------------------------------------------------------------
class TestFailSliceErrors:
    def test_unknown_slice_raises_keyerror(self):
        cluster = build_sim_cluster(make_table, ("s0",))
        with pytest.raises(KeyError, match="unknown slice 'nope'"):
            cluster.fail_slice("nope")

    def test_double_failure_raises(self):
        cluster = build_sim_cluster(make_table, ("s0", "s1"))
        r = req(n_frames=50)
        assert cluster.submit_request(r)
        cluster.run(until=0.2)
        cluster.fail_slice(cluster.placement[r.request_id])
        dead = [n for n, sl in cluster.slices.items() if not sl.alive][0]
        with pytest.raises(RuntimeError, match="already failed"):
            cluster.fail_slice(dead)


# ---------------------------------------------------------------------------
# Parked-tail retry queue
# ---------------------------------------------------------------------------
def two_slice_cluster(bound_s1: float) -> ClusterScheduler:
    """s0 full-size, s1 with its own Phase-1 ceiling."""
    cluster = ClusterScheduler()
    cluster.add_slice(SliceSpec(name="s0", table=make_table()))
    cluster.add_slice(
        SliceSpec(name="s1", table=make_table(), utilization_bound=bound_s1)
    )
    return cluster


class TestParkedTails:
    def test_unplaceable_tail_parks_then_expires(self):
        # s1 too small to ever host the displaced tail: the parked entry
        # must terminate as provably expired, never retry forever.
        cluster = two_slice_cluster(bound_s1=0.0001)
        r = req(period=0.05, n_frames=40)
        assert cluster.submit_request(r)
        assert cluster.placement[r.request_id] == "s0"
        cluster.loop.schedule(0.3, lambda: cluster.fail_slice("s0"))
        cluster.run()
        assert cluster.parked == {}
        assert cluster.parked_expired == [r.request_id]
        assert cluster.parked_admitted == []
        assert cluster.failover_map[r.request_id] is None
        agg = cluster.aggregate_metrics()
        assert (agg["completed_frames"] + agg["dropped_frames"]
                + agg["lost_frames"]) == agg["ingested_frames"]

    def test_parked_tail_admitted_when_capacity_frees(self):
        # s1 is blocked by its own short stream at failover time; once
        # that stream ends, the backoff retry must admit the parked tail.
        # Each active stream snapshots at ~0.046 Phase-1 utilization:
        # s1's 0.06 bound holds one of them, never both at once.
        cluster = two_slice_cluster(bound_s1=0.06)
        victim = req(period=0.05, n_frames=60)  # runs past 2.9s
        blocker = req(period=0.05, n_frames=12)  # ends at ~0.55s
        assert cluster.submit_request(victim)  # empty cluster: s0 by name
        assert cluster.submit_request(blocker)  # s1 now the least utilized
        assert cluster.placement[victim.request_id] == "s0"
        assert cluster.placement[blocker.request_id] == "s1"
        dead = "s0"
        cluster.loop.schedule(0.3, lambda: cluster.fail_slice(dead))
        cluster.run()
        assert cluster.parked == {}
        assert cluster.parked_admitted == [victim.request_id]
        fresh_rid = cluster.failover_map[victim.request_id]
        assert fresh_rid is not None
        assert cluster.placement[fresh_rid] == "s1"
        entry = cluster.requests[fresh_rid]
        assert entry.n_frames < victim.n_frames  # only the live tail moved
        agg = cluster.aggregate_metrics()
        assert (agg["completed_frames"] + agg["dropped_frames"]
                + agg["lost_frames"]) == agg["ingested_frames"]

    def test_aggregate_metrics_expose_parked_counts(self):
        cluster = two_slice_cluster(bound_s1=0.0001)
        agg = cluster.aggregate_metrics()
        for key in ("parked", "parked_admitted", "parked_expired",
                    "lost_frames", "submit_retries", "ingested_frames"):
            assert key in agg


# ---------------------------------------------------------------------------
# Conservation under arbitrary deterministic fault plans
# ---------------------------------------------------------------------------
def run_chaos(seed: int, n_slices: int = 2) -> dict:
    cfg = WatchdogConfig(suspect_after=2, quarantine_after=4, **WD)
    names = tuple(f"s{i}" for i in range(n_slices))
    plans = {
        name: FaultPlan.from_seed(
            seed * 101 + i, n_submits=60,
            p_delay=0.1, p_stall=0.02, p_error=0.05, p_death=0.01,
        )
        for i, name in enumerate(names)
    }
    cluster = build_sim_cluster(make_table, names, fault_plans=plans,
                                watchdog=cfg)
    rng_frames = 20 + (seed % 3) * 10
    submitted = [req(period=0.04, n_frames=rng_frames) for _ in range(n_slices + 1)]
    for r in submitted:
        cluster.submit_request(r)
    cluster.run()
    return {"cluster": cluster, "agg": cluster.aggregate_metrics()}


def assert_chaos_invariants(out: dict) -> None:
    cluster, agg = out["cluster"], out["agg"]
    # THE conservation identity: every frame presented to a scheduler is
    # completed, shed, or reconciled as lost — none silently vanish.
    assert (agg["completed_frames"] + agg["dropped_frames"]
            + agg["lost_frames"]) == agg["ingested_frames"], agg
    # Every parked tail resolved (admitted or provably expired).
    assert cluster.parked == {}, agg
    assert len(cluster.parked_admitted) + len(cluster.parked_expired) \
        == len(set(cluster.parked_admitted) | set(cluster.parked_expired))
    # Every displaced request is accounted in exactly one ledger.
    for name, sl in cluster.slices.items():
        if sl.alive:
            continue
        for rid, placed_on in cluster.placement.items():
            assert placed_on != name or rid in cluster.failover_map


class TestChaosConservation:
    @pytest.mark.parametrize("seed", range(8))
    def test_seed_sweep(self, seed):
        assert_chaos_invariants(run_chaos(seed))

    @pytest.mark.slow
    def test_hypothesis_property(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (installed in CI); a bare "
            "environment skips this test instead of breaking collection",
        )
        import os

        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @settings(
            max_examples=int(os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "25")),
            deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )
        @given(seed=st.integers(0, 2**31 - 1), n_slices=st.integers(1, 3))
        def prop(seed, n_slices):
            assert_chaos_invariants(run_chaos(seed, n_slices=n_slices))

        prop()


# ---------------------------------------------------------------------------
# WallClock hold/release concurrency (live-loop substrate)
# ---------------------------------------------------------------------------
class TestWallClockConcurrency:
    def test_release_without_hold_raises(self):
        loop = WallClock()
        with pytest.raises(RuntimeError, match="without a matching hold"):
            loop.release()
        loop.hold()
        loop.release()
        with pytest.raises(RuntimeError, match="without a matching hold"):
            loop.release()

    def test_concurrent_offloop_completions_all_run(self):
        loop = WallClock()
        n = 16
        got = []
        for _ in range(n):
            loop.hold()

        def poster(i):
            time.sleep(0.001 * (i % 4))
            loop.post(lambda i=i: got.append(i))
            loop.release()

        threads = [threading.Thread(target=poster, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        loop.run()  # must stay alive on the holds, then drain every post
        for t in threads:
            t.join(timeout=1.0)
        assert sorted(got) == list(range(n))

    def test_run_until_returns_with_holds_outstanding(self):
        # The no-watchdog benchmark arm: a wedged device holds the loop
        # forever; run(until=T) must still return at T.
        loop = WallClock()
        loop.hold()
        t0 = time.perf_counter()
        loop.run(until=loop.now + 0.15)
        elapsed = time.perf_counter() - t0
        assert 0.1 < elapsed < 2.0
        loop.release()


# ---------------------------------------------------------------------------
# AsyncDevice close(): join-with-timeout on a wedged waiter
# ---------------------------------------------------------------------------
class _BlockingHandle:
    def __init__(self):
        self.release = threading.Event()

    def wait(self):
        self.release.wait()


class TestAsyncDeviceClose:
    def test_clean_close_joins_waiter(self):
        loop = WallClock()
        device = AsyncDevice(loop, dispatch_fn=lambda job: _BlockingHandle())
        device.close()
        assert not device._waiter.is_alive()
        assert not device.wedged

    def test_close_times_out_and_abandons_wedged_waiter(self):
        loop = WallClock()
        handles = []

        def dispatch(job):
            h = _BlockingHandle()
            handles.append(h)
            return h

        device = AsyncDevice(loop, dispatch_fn=dispatch, join_timeout=0.1)
        done = []
        device.submit("job", 0.01, lambda j, t: done.append(j))
        t0 = time.perf_counter()
        device.close()
        elapsed = time.perf_counter() - t0
        assert elapsed < 5.0  # bounded by join_timeout (+ scheduling slack)
        assert device.wedged
        assert device._waiter.is_alive()  # abandoned daemon, still stuck
        # The in-flight hold was force-released: run() terminates.
        loop.run()
        assert done == []  # the wedged completion was swallowed
        # Late un-wedge must not double-release or re-deliver:
        handles[0].release.set()
        device._waiter.join(timeout=1.0)
        assert not device._waiter.is_alive()
        loop.run()
        assert done == []

    def test_completion_racing_close_is_swallowed(self):
        loop = WallClock()
        device = AsyncDevice(loop, dispatch_fn=lambda job: _BlockingHandle())
        done = []
        device.submit("job", 0.01, lambda j, t: done.append(j))
        loop.schedule(loop.now + 0.02, device.close)
        loop.run()
        assert done == []
        assert device.closed and not device.idle
