"""Multi-step decode chunking: slack-chosen k-step compiled decode
programs, proven bit-identical by a differential test harness.

Covers the acceptance bars of the chunking PR:

- the DIFFERENTIAL ORACLE: a k-step ``decode_chunk`` is bit-identical
  to k sequential single-step ``dispatch`` calls on a twin engine —
  every KV arena leaf, the device-resident cursors and active bitmap,
  and every step's logits / sampled (argmax) tokens — over scattered
  leased rows, heterogeneous cursors, and per-step frame-bearing row
  subsets (idle leased rows keep FROZEN cursors). Deterministic
  scenario sweep plus a hypothesis property over seed-derived
  workloads;
- the profiler's chunk WCET family: per-depth ``record_flat``,
  monotone enforcement, round-UP lookup for unprofiled depths, the
  k x WCET_1 tail beyond the family, capacity scaling, and JSON
  round-trips;
- the EDF worker's slack-driven depth policy: deep chunks only when
  every fused job's slack clears the chunk WCET + margin, depth-1
  near deadlines, fused jobs consecutive in deadline order, the
  chunk's FULL WCET charged to ``busy_until`` and the queued-WCET
  total, per-step attribution to the adaptation module (no phantom
  overruns), and unfuse-on-transient-submit-error;
- sim-vs-live determinism: the same trace + table produces the same
  chunk-depth sequence and completion order under the EventLoop/
  SequentialDevice substrate and the WallClock/AsyncDevice substrate;
- mid-chunk slice failure: the conservation identity
  ``completed + dropped + lost == ingested`` holds when a slice dies
  with a chunk in flight, and the displaced tail re-admits;
- the health watchdog receives the CHUNK-scaled expected time, so
  chunked serving under a tight slack produces zero false overdue
  signals (no k x false positives);
- the gateway's ``delay_estimate`` counts an in-flight chunk's FULL
  residue (the ``device_tail`` term), not one step's;
- live end-to-end: a backlogged live scheduler fuses chunks with ZERO
  decode recompiles after the profiling warm-up.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import tiny
from repro.core import (
    Category,
    ChunkJob,
    ChunkPolicy,
    DeepRT,
    EventLoop,
    FaultPlan,
    FaultSpec,
    FaultyDevice,
    Frame,
    HEALTHY,
    JobInstance,
    ProfileTable,
    Request,
    SequentialDevice,
    SUBMIT_ERROR,
    WatchdogConfig,
    build_sim_cluster,
)
from repro.core.bucketing import chunk_depths
from repro.core.simulator import WallClock
from repro.ingest import CameraSource, IngestGateway
from repro.serving.async_device import AsyncDevice
from repro.serving.batcher_bridge import build_live_scheduler
from repro.serving.engine import InferenceEngine

MID = "granite-3-2b"
SEQ = 16
M = 8
SHAPE = (SEQ,)
DEPTHS = (1, 2, 4, 8)

# Simulated decode category: flat 1-step WCET + a sublinear chunk family
# (a k-step chunk amortizes the per-dispatch host overhead).
SIM_MID = "m"
SIM_SHAPE = (16,)
SIM_CAT = Category(SIM_MID, SIM_SHAPE)
W1 = 0.004


def chunk_table(w1: float = W1, depths=(2, 4), sub: float = 0.8) -> ProfileTable:
    t = ProfileTable()
    t.record_flat(SIM_MID, SIM_SHAPE, w1, M)
    for k in depths:
        t.record_flat(SIM_MID, SIM_SHAPE, w1 * k * sub, M, k=k)
    return t


def sim_job(release: float, rel_dl: float, index: int = 0,
            rid: int = 0) -> JobInstance:
    f = Frame(
        request_id=rid, category=SIM_CAT, index=index,
        arrival_time=release, deadline=release + rel_dl,
    )
    return JobInstance(
        category=SIM_CAT, frames=[f], release_time=release,
        relative_deadline=rel_dl, shape_key=SIM_SHAPE,
    )


# ---------------------------------------------------------------------------
# Chunk-depth ladder (bucketing)
# ---------------------------------------------------------------------------
class TestChunkDepthLadder:
    def test_pow2_ladder(self):
        assert chunk_depths(8) == [1, 2, 4, 8]
        assert chunk_depths(1) == [1]
        # Non-pow2 maxima round up to the bucket, like batch buckets.
        assert chunk_depths(5) == [1, 2, 4, 8]

    def test_degenerate(self):
        assert chunk_depths(0) == []
        assert chunk_depths(-3) == []


# ---------------------------------------------------------------------------
# ProfileTable chunk family
# ---------------------------------------------------------------------------
class TestChunkFamilyTable:
    def test_record_and_exact_lookup(self):
        t = chunk_table()
        assert t.chunk_wcet(SIM_MID, SIM_SHAPE, 1) == pytest.approx(W1)
        assert t.chunk_wcet(SIM_MID, SIM_SHAPE, 4) == pytest.approx(W1 * 4 * 0.8)
        assert t.chunk_depths_profiled(SIM_MID, SIM_SHAPE) == [1, 2, 4]
        assert t.has_chunks(SIM_MID, SIM_SHAPE)
        assert t.has_any_chunks()

    def test_flat_only_table_has_no_chunks(self):
        t = ProfileTable()
        t.record_flat(SIM_MID, SIM_SHAPE, W1, M)
        assert not t.has_chunks(SIM_MID, SIM_SHAPE)
        assert not t.has_any_chunks()

    def test_unprofiled_depth_rounds_up(self):
        t = chunk_table()
        # k=3 is between the profiled 2 and 4: conservative = round UP.
        assert t.chunk_wcet(SIM_MID, SIM_SHAPE, 3) == \
            t.chunk_wcet(SIM_MID, SIM_SHAPE, 4)

    def test_beyond_family_charges_linear_tail(self):
        t = chunk_table()
        assert t.chunk_wcet(SIM_MID, SIM_SHAPE, 16) == pytest.approx(16 * W1)

    def test_monotone_violation_rejected(self):
        t = chunk_table()
        with pytest.raises(ValueError, match="monotone"):
            # Deeper chunk claiming to be CHEAPER than a shallower one.
            t.record_flat(SIM_MID, SIM_SHAPE, W1 * 0.5, M, k=8)

    def test_chunk_without_flat_base_rejected(self):
        t = ProfileTable()
        with pytest.raises((KeyError, ValueError)):
            t.record_flat(SIM_MID, SIM_SHAPE, W1, M, k=4)

    def test_scaled_scales_family(self):
        t = chunk_table().scaled(2.0)
        assert t.chunk_wcet(SIM_MID, SIM_SHAPE, 4) == \
            pytest.approx(2.0 * W1 * 4 * 0.8)

    def test_json_round_trip(self):
        t = chunk_table()
        back = ProfileTable.from_json(t.to_json())
        for k in (1, 2, 3, 4, 16):
            assert back.chunk_wcet(SIM_MID, SIM_SHAPE, k) == \
                pytest.approx(t.chunk_wcet(SIM_MID, SIM_SHAPE, k))
        assert back.chunk_depths_profiled(SIM_MID, SIM_SHAPE) == [1, 2, 4]


# ---------------------------------------------------------------------------
# Differential oracle: chunk vs sequential replay on twin engines
# ---------------------------------------------------------------------------
def _engine(chunk_depth: int = 8, seed: int = 0) -> InferenceEngine:
    return InferenceEngine(
        {MID: tiny(MID)}, seed=seed, max_slots=M, chunk_depth=chunk_depth
    )


def _lease(e: InferenceEngine, alloc_plan):
    """Apply an identical alloc/free sequence; returns the live rows."""
    allocs, frees = alloc_plan
    for n, start_pos in allocs:
        e.alloc_slots(MID, SEQ, n, start_pos=start_pos)
    if frees:
        e.free_slots(MID, SEQ, sorted(frees))
    return list(e.arena(MID, SEQ).live)


def run_differential(seed, alloc_plan, k, rows_plan, tok_seed):
    """THE oracle: one k-step chunk on engine A vs the same schedule
    replayed as k sequential 1-step dispatches on twin engine B must be
    bit-identical: KV arena rows, cursors, active bitmap, per-step
    logits and argmax tokens — and idle leased rows' cursors frozen."""
    a, b = _engine(seed=seed), _engine(seed=seed)
    live = _lease(a, alloc_plan)
    assert _lease(b, alloc_plan) == live
    rng = np.random.default_rng(tok_seed)
    payloads = []
    for rows_i in rows_plan:
        rows = live if rows_i is None else list(rows_i)
        payloads.append({int(r): int(rng.integers(0, 64)) for r in rows})
    aa, ab = a.arena(MID, SEQ), b.arena(MID, SEQ)
    pre_cur = np.asarray(aa.cur)

    chunk_logits = a.decode_chunk(
        MID, SHAPE, len(live), k,
        slots=live, payloads=payloads, step_rows=rows_plan,
    ).wait()
    step_logits = [
        b.dispatch(
            MID, SHAPE, len(live), "decode",
            slots=live, payload=payloads[i], step_rows=rows_plan[i],
        ).wait()
        for i in range(k)
    ]

    # 1) Every KV cache leaf bit-identical.
    for la, lb in zip(
        jax.tree_util.tree_leaves(aa.cache), jax.tree_util.tree_leaves(ab.cache)
    ):
        assert la.shape == lb.shape
        assert bool(jnp.all(la == lb))
    # 2) Device-resident cursors + active bitmap identical.
    assert bool(jnp.all(aa.cur == ab.cur))
    assert bool(jnp.all(aa.active == ab.active))
    # 3) Per-step logits and sampled (argmax) tokens identical.
    assert chunk_logits.shape[0] == k
    for i in range(k):
        assert bool(jnp.all(chunk_logits[i] == step_logits[i]))
        assert bool(
            jnp.all(chunk_logits[i].argmax(-1) == step_logits[i].argmax(-1))
        )
    # 4) Cursor arithmetic: a row advances once per step it carried a
    # frame in (clamped at seq-1); idle leased rows stay FROZEN.
    cur = np.asarray(aa.cur)
    for r in live:
        steps = sum(
            1 for rows_i in rows_plan
            if r in (live if rows_i is None else set(int(s) for s in rows_i))
        )
        assert cur[r] == min(pre_cur[r] + steps, SEQ - 1), (r, rows_plan)


class TestDifferentialOracle:
    @pytest.mark.parametrize("k", DEPTHS)
    def test_all_rows_every_step(self, k):
        run_differential(0, ([(M, 3)], set()), k, [None] * k, tok_seed=10 + k)

    @pytest.mark.parametrize("k", (2, 4))
    def test_scattered_rows_with_idle_steps(self, k):
        # Live rows 1, 3, 4, 6 (scattered); per-step subsets including an
        # EMPTY step (every leased row idle) and a full step.
        plan = ([(M, 2)], {0, 2, 5, 7})
        live = [1, 3, 4, 6]
        rows_plan = [[1, 4], [], None, [3, 6]][:k]
        run_differential(1, plan, k, rows_plan, tok_seed=21)
        assert live == sorted(set(live))  # scenario sanity

    def test_heterogeneous_cursors(self):
        # Two lease generations at different start positions, holes freed.
        plan = ([(4, 2), (4, 9)], {1, 5})
        rows_plan = [[0, 4], [2, 3, 6, 7], None, [0]]
        run_differential(2, plan, 4, rows_plan, tok_seed=33)

    def test_cursor_clamp_at_seq_end(self):
        # Rows starting at seq-2 hit the seq-1 clamp inside the chunk.
        run_differential(3, ([(3, SEQ - 2)], set()), 4, [None] * 4, tok_seed=44)

    def test_depth_one_chunk_is_a_single_step(self):
        run_differential(0, ([(5, 4)], {1}), 1, [[0, 2]], tok_seed=55)


class TestChunkValidation:
    def test_depth_beyond_ring_capacity_rejected(self):
        e = _engine(chunk_depth=1)
        e.alloc_slots(MID, SEQ, 2)
        with pytest.raises(ValueError, match="chunk_depth"):
            e.decode_chunk(MID, SHAPE, 2, 4, slots=[0, 1])

    def test_payload_and_rows_lengths_must_match_depth(self):
        e = _engine()
        live = list(e.alloc_slots(MID, SEQ, 2))
        with pytest.raises(ValueError, match="payloads"):
            e.decode_chunk(MID, SHAPE, 2, 4, slots=live, payloads=[None] * 3)
        with pytest.raises(ValueError, match="row sets"):
            e.decode_chunk(MID, SHAPE, 2, 4, slots=live,
                           step_rows=[None] * 2)

    def test_step_rows_must_be_live(self):
        e = _engine()
        live = list(e.alloc_slots(MID, SEQ, 2))
        with pytest.raises(ValueError, match="not live"):
            e.decode_chunk(MID, SHAPE, 2, 2, slots=live,
                           step_rows=[[live[0]], [7]])

    def test_prefix_chunk_refuses_leased_arena(self):
        e = _engine()
        e.alloc_slots(MID, SEQ, 2)
        with pytest.raises(ValueError, match="allocator-live"):
            e.decode_chunk(MID, SHAPE, 2, 2)

    def test_chunk_is_one_dispatch_zero_recompiles(self):
        e = _engine()
        live = list(e.alloc_slots(MID, SEQ, 4))
        e.decode_chunk(MID, SHAPE, 4, 4, slots=live).wait()  # compile
        e.reset_stats()
        e.decode_chunk(MID, SHAPE, 4, 4, slots=live).wait()
        assert e.stats["decode_compiles"] == 0
        assert e.stats["dispatches"] == 1
        assert e.stats["chunk_steps"] == 4


class TestChunkingProperty:
    @pytest.mark.slow
    def test_hypothesis_bit_identity(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (installed in CI); a bare "
            "environment skips this test instead of breaking collection",
        )
        import os

        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @settings(
            max_examples=int(os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "10")),
            deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )
        @given(seed=st.integers(0, 2**31 - 1))
        def prop(seed):
            # Seed-derived workload: random leased-row scatter, random
            # cursor origin, random per-step frame-bearing subsets
            # (including None = all rows and [] = all idle), random
            # tokens, random depth.
            rng = np.random.default_rng(seed)
            k = int(rng.choice(DEPTHS))
            n_freed = int(rng.integers(0, M - 1))
            freed = set(
                int(s) for s in rng.choice(M, size=n_freed, replace=False)
            )
            live = sorted(set(range(M)) - freed)
            start = int(rng.integers(0, SEQ - 1))
            rows_plan = []
            for _ in range(k):
                if rng.random() < 0.25:
                    rows_plan.append(None)
                else:
                    sz = int(rng.integers(0, len(live) + 1))
                    rows_plan.append(sorted(
                        int(s)
                        for s in rng.choice(live, size=sz, replace=False)
                    ))
            run_differential(
                int(rng.integers(0, 4)), ([(M, start)], freed), k,
                rows_plan, tok_seed=seed,
            )

        prop()


# ---------------------------------------------------------------------------
# EDF slack policy: depth choices, accounting, retry unfuse
# ---------------------------------------------------------------------------
def _sim_sched(table: ProfileTable, device=None) -> DeepRT:
    loop = EventLoop()
    if device is not None:
        device = device(loop)
    return DeepRT(table, loop=loop, device=device)


def _depths(sched: DeepRT):
    return [d for (_t, d, _jid) in sched.worker.chunk_log]


class TestSlackPolicy:
    def test_auto_wired_from_chunk_family(self):
        assert _sim_sched(chunk_table()).worker.chunk_policy is not None
        flat_only = ProfileTable()
        flat_only.record_flat(SIM_MID, SIM_SHAPE, W1, M)
        assert _sim_sched(flat_only).worker.chunk_policy is None

    def test_backlog_with_ample_slack_goes_deep(self):
        sched = _sim_sched(chunk_table())
        jobs = [sim_job(0.0, 5.0, index=i) for i in range(8)]
        for j in jobs:
            sched.worker.submit(j)
        sched.loop.run()
        # Dispatch is a deferred PRIO_DISPATCH event, so the whole burst
        # is queued by the first decision: two max-depth chunks.
        assert _depths(sched) == [4, 4]
        assert sched.metrics.chunk_submits == 2
        assert sched.metrics.chunked_steps == 8
        # Every job completed exactly once, in EDF (= submission) order.
        done = [j.job_id for j in sched.worker.completed_jobs]
        assert done == [j.job_id for j in jobs]

    def test_tight_deadlines_force_single_steps(self):
        sched = _sim_sched(chunk_table())
        # Slack below W2 + margin at every decision point: never fuse.
        for i in range(6):
            sched.worker.submit(sim_job(0.0, 0.009, index=i))
        sched.loop.run()
        assert _depths(sched) == [1] * 6
        assert sched.metrics.chunk_submits == 0

    def test_tight_member_degrades_depth(self):
        sched = _sim_sched(chunk_table())
        jobs = [sim_job(0.0, 5.0, index=0), sim_job(0.0, 5.0, index=1),
                sim_job(0.0, 5.0, index=2),
                # 4th-in-deadline-order job too tight for a depth-4 chunk
                # at the second dispatch (~W1 in): fused depth must drop.
                sim_job(0.0, 0.012, index=3)]
        # Tight job sorts FIRST (earliest absolute deadline).
        for j in jobs:
            sched.worker.submit(j)
        sched.loop.run()
        # Head of the queue at each decision never has a depth-4-worthy
        # run behind it that fully clears the slack rule with the tight
        # job inside it.
        assert 4 not in _depths(sched)
        assert len(sched.worker.completed_jobs) == 4

    def test_chunk_full_wcet_charged_to_busy_until_and_queue(self):
        sched = _sim_sched(chunk_table())
        for i in range(8):
            sched.worker.submit(sim_job(0.0, 5.0, index=i))
        seen = {}

        def probe():
            # Runs while the first depth-4 chunk is still in flight.
            log = sched.worker.chunk_log
            if log and log[0][1] == 4:
                seen["tail"] = sched.device.busy_until - log[0][0]
                seen["queued"] = sched.worker.queued_wcet

        w4 = chunk_table().chunk_wcet(SIM_MID, SIM_SHAPE, 4)
        sched.loop.schedule(0.5 * W1, probe)
        sched.loop.run()
        # The device tail covers the FULL 4-step WCET (x the sim's 0.97
        # actual factor), not one step's...
        assert seen["tail"] >= 0.9 * w4 > W1
        # ...and the 4 still-queued jobs keep their 1-step charges.
        assert seen["queued"] == pytest.approx(4 * W1)

    def test_chunk_completion_attributes_per_step_actuals(self):
        sched = _sim_sched(chunk_table())
        inner = sched.worker.on_job_complete
        log = []

        def spy(job, actual):
            log.append((job.job_id, actual))
            inner(job, actual)

        sched.worker.on_job_complete = spy
        for i in range(8):
            sched.worker.submit(sim_job(0.0, 5.0, index=i))
        sched.loop.run()
        assert len(log) == 8
        # Each chunked job was attributed its 1/k share: every recorded
        # actual stays at or below the 1-step WCET, so the adaptation
        # module sees zero phantom overruns from chunking.
        assert all(actual <= W1 + 1e-12 for _jid, actual in log)
        assert sched.metrics.overruns == 0

    def test_transient_submit_error_unfuses_chunk(self):
        plan = FaultPlan((FaultSpec(SUBMIT_ERROR, 1),))
        sched = _sim_sched(
            chunk_table(),
            device=lambda loop: FaultyDevice(SequentialDevice(loop), plan),
        )
        jobs = [sim_job(0.0, 5.0, index=i) for i in range(8)]
        for j in jobs:
            sched.worker.submit(j)
        sched.loop.run()
        # Submit #1 — the depth-4 chunk — was refused: its members were
        # unfused back into the queue and retried; every job still
        # completes exactly once.
        assert sched.metrics.submit_retries >= 1
        assert sched.metrics.duplicate_completions == 0
        assert sorted(j.job_id for j in sched.worker.completed_jobs) == \
            sorted(j.job_id for j in jobs)

    def test_policy_from_table_margin(self):
        pol = ChunkPolicy.from_table(chunk_table(), margin_steps=2.0)
        head = sim_job(0.0, 1.0)
        assert pol.margin_fn(head) == pytest.approx(2.0 * W1)
        assert pol.depths_fn(head) == [1, 2, 4]
        assert pol.wcet_fn(head, 4) == pytest.approx(W1 * 4 * 0.8)
        assert pol.eligible_fn(head)
        nrt = JobInstance(
            category=Category(SIM_MID, SIM_SHAPE, realtime=False),
            frames=[], release_time=0.0, relative_deadline=1.0,
            shape_key=SIM_SHAPE,
        )
        assert not pol.eligible_fn(nrt)


# ---------------------------------------------------------------------------
# Sim-vs-live determinism: same trace + table -> same depths, same order
# ---------------------------------------------------------------------------
class _InstantHandle:
    def wait(self):
        return None


class TestSimLiveDeterminism:
    def _trace(self):
        # Deadlines far from every depth threshold (seconds vs the
        # ~5 ms decision scale), plus one HARD-tight job — so wall-clock
        # jitter in the live arm cannot flip any depth decision.
        rel = [30.0, 30.0, 30.0, 30.0, 0.004, 30.0, 30.0, 30.0]
        return [sim_job(0.0, r, index=i) for i, r in enumerate(rel)]

    def _run(self, sched, jobs, live=False):
        for j in jobs:
            sched.worker.submit(j)
        if live:
            sched.loop.run(until=sched.loop.now + 0.5)
        else:
            sched.loop.run()
        base = jobs[0].job_id
        return (
            _depths(sched),
            [log_jid - base for (_t, _d, log_jid) in sched.worker.chunk_log],
            [j.job_id - base for j in sched.worker.completed_jobs],
        )

    def test_same_trace_same_depth_sequence_and_completion_order(self):
        table = chunk_table(w1=0.002)
        sim = self._run(_sim_sched(table), self._trace())

        loop = WallClock()
        live_sched = DeepRT(
            table, loop=loop,
            device=AsyncDevice(loop, lambda job: _InstantHandle()),
        )
        live = self._run(live_sched, self._trace(), live=True)

        assert sim[0] == live[0]  # chunk-depth sequence
        assert sim[1] == live[1]  # decision heads (relative job ids)
        assert sim[2] == live[2]  # completion order
        assert len(sim[2]) == 8


# ---------------------------------------------------------------------------
# Mid-chunk slice failure + watchdog chunk scaling
# ---------------------------------------------------------------------------
class TestMidChunkFailure:
    def test_fail_slice_mid_chunk_conserves_frames(self):
        # A periodic stream rides the victim slice; a same-category
        # burst of ample-slack jobs (counted as ingested, exactly like
        # the gateway's delivery path) builds the queue the EDF worker
        # fuses. The probe fails the slice WHILE a chunk is in flight.
        cluster = build_sim_cluster(chunk_table, ("s0", "s1"))
        req = Request(category=SIM_CAT, period=0.012,
                      relative_deadline=0.06, n_frames=50)
        assert cluster.submit_request(req)
        sl = cluster.slices["s0"]
        w = sl.scheduler.worker

        def burst():
            for i in range(8):
                sl.scheduler.metrics.record_ingest()
                w.submit(sim_job(cluster.loop.now, 5.0, index=100 + i,
                                 rid=999))

        cluster.loop.schedule(0.05, burst)
        state = {"failed_at": None}

        def probe():
            if state["failed_at"] is not None:
                return
            done = {j.job_id for j in w.completed_jobs}
            if (w.chunk_log and w.chunk_log[-1][1] > 1
                    and not sl.scheduler.device.idle
                    and w.chunk_log[-1][2] not in done):
                state["failed_at"] = cluster.loop.now
                cluster.fail_slice("s0")
                return
            if cluster.loop.now < 1.0:
                cluster.loop.schedule(cluster.loop.now + 0.002, probe)

        cluster.loop.schedule(0.0, probe)
        cluster.run()
        # The probe really did catch a chunk in flight.
        assert state["failed_at"] is not None
        assert sl.scheduler.metrics.chunk_submits >= 1
        # THE conservation identity survives a mid-chunk slice death:
        # every ingested frame is completed, shed, or reconciled lost.
        agg = cluster.aggregate_metrics()
        assert (agg["completed_frames"] + agg["dropped_frames"]
                + agg["lost_frames"]) == agg["ingested_frames"], agg
        # The displaced request's unconsumed tail re-admitted (or is
        # accounted): it appears in exactly one failover ledger.
        assert (req.request_id in cluster.failover_map
                or req.request_id in cluster.finished_with_slice)
        assert cluster.parked == {}
        if cluster.failover_map.get(req.request_id) is not None:
            tail_rid = cluster.failover_map[req.request_id]
            tail = cluster.requests[tail_rid]
            # Only the unconsumed steps moved — never a replay of the
            # full stream.
            assert tail.n_frames < req.n_frames
            assert cluster.placement[tail_rid] == "s1"

    def test_watchdog_uses_chunk_scaled_expectation(self):
        # Slack 2.0 < the fused depth: if the watchdog were armed with
        # the 1-STEP WCET, every depth-4 chunk (actual ~= 4 x one step)
        # would trip overdue and quarantine the slice. Chunk-scaled
        # expectations keep a healthy chunked slice HEALTHY.
        cfg = WatchdogConfig(slack=2.0, hang_slack=10.0,
                             suspect_after=1, quarantine_after=2)
        cluster = build_sim_cluster(chunk_table, ("s0",), watchdog=cfg)
        sl = cluster.slices["s0"]

        def burst():
            for i in range(8):
                sl.scheduler.worker.submit(sim_job(
                    cluster.loop.now, 5.0, index=i))

        cluster.loop.schedule(0.0, burst)
        cluster.run()
        assert sl.scheduler.metrics.chunk_submits >= 1
        assert sl.health == HEALTHY
        assert cluster.health.transitions == []


# ---------------------------------------------------------------------------
# Gateway delay estimate counts in-flight chunk residue
# ---------------------------------------------------------------------------
class TestChunkResidueAccounting:
    def test_delay_estimate_includes_full_chunk_tail(self):
        # The session streams a BUCKETED category (flat decode streams
        # need the cluster's lease path); the chunked backlog shares its
        # device, which is all ``device_tail`` measures.
        table = chunk_table()
        cls_cat = Category("cls", (4,))
        for b in (1, 2, 4, 8):
            table.record("cls", (4,), b, 0.002 + 0.0005 * b)
        sched = DeepRT(table)
        gw = IngestGateway(sched)
        src = CameraSource(period=0.05, n_frames=10, payload_shape=(4,),
                           seed=0)
        session = gw.register(src, cls_cat, relative_deadline=0.25)
        assert session.state == "active"

        def burst():
            for i in range(8):
                sched.worker.submit(sim_job(sched.loop.now, 5.0, index=i,
                                            rid=10_000))

        seen = {}

        def probe():
            if seen:
                return
            w = sched.worker
            done = {j.job_id for j in w.completed_jobs}
            if (w.chunk_log and w.chunk_log[-1][1] > 1
                    and not sched.device.idle
                    and w.chunk_log[-1][2] not in done):
                gw.delay_estimate(session)
                seen["breakdown"] = dict(session.last_delay_breakdown)
                seen["depth"] = w.chunk_log[-1][1]
                return
            if sched.loop.now < 1.0:
                sched.loop.schedule(sched.loop.now + 0.001, probe)

        sched.loop.schedule(0.001, burst)
        sched.loop.schedule(0.002, probe)
        sched.run()
        assert seen, "no chunk was ever in flight"
        bd = seen["breakdown"]
        # The in-flight chunk's residue counts in FULL: the device tail
        # exceeds a single step's WCET — without the chunk charge this
        # term would be <= W1 and CREDIT downshifts would fire k steps
        # late.
        assert bd["device_tail"] > W1
        assert bd["device_tail"] <= \
            chunk_table().chunk_wcet(SIM_MID, SIM_SHAPE, seen["depth"])
        assert set(bd) == {"device_tail", "queued_wcet", "window_wait",
                           "batch_wcet"}


# ---------------------------------------------------------------------------
# Live end-to-end: backlog fuses chunks, zero recompiles
# ---------------------------------------------------------------------------
class TestLiveChunkedServing:
    def test_backlog_fuses_chunks_zero_recompiles(self):
        sched, engine, table = build_live_scheduler(
            {MID: tiny(MID)}, [(MID, SHAPE, "decode")], chunk_depth=4,
        )
        assert table.chunk_depths_profiled(MID, SHAPE) == [1, 2, 4]
        assert sched.worker.chunk_policy is not None
        cat = Category(MID, SHAPE)
        jobs = []
        for i in range(8):
            f = Frame(request_id=0, category=cat, index=i,
                      arrival_time=0.0, deadline=30.0)
            jobs.append(JobInstance(
                category=cat, frames=[f], release_time=sched.loop.now,
                relative_deadline=30.0, shape_key=SHAPE,
            ))
        for j in jobs:
            sched.worker.submit(j)
        sched.loop.run(until=sched.loop.now + 5.0)
        assert len(sched.worker.completed_jobs) == 8
        assert sched.metrics.chunk_submits >= 1
        assert sched.metrics.chunked_steps >= 2
        # Profiling warmed every depth on the ladder: serving recompiled
        # NOTHING.
        assert engine.stats["decode_compiles"] == 0
        assert engine.stats["chunk_steps"] >= 2
