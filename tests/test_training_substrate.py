"""Training substrate: optimizer, data pipeline, checkpointing,
gradient compression, sharding rules, end-to-end loss descent."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.registry import tiny
from repro.models import model_for
from repro.training import optimizer as opt
from repro.training import train_loop
from repro.training.compression import (
    _dequantize,
    _quantize,
    compressed_pod_mean,
    init_residuals,
)
from repro.training.data import DataConfig, SyntheticTokens

KEY = jax.random.PRNGKey(0)


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = opt.AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=100,
                              weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_clip_norm(self):
        cfg = opt.AdamWConfig(clip_norm=1.0, warmup_steps=1)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        _, _, metrics = opt.update(cfg, {"w": jnp.full(3, 100.0)}, state, params)
        assert float(metrics["grad_norm"]) > 1.0  # reported pre-clip

    def test_lr_schedule_shape(self):
        cfg = opt.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
        lrs = [float(opt.cosine_lr(cfg, jnp.array(s))) for s in [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[3] < 1.0
        assert lrs[4] == pytest.approx(0.1, abs=1e-6)


class TestData:
    def test_deterministic_and_seekable(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
        ds1, ds2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
        b5a = ds1.batch(5)["tokens"]
        b5b = ds2.batch(5)["tokens"]
        np.testing.assert_array_equal(b5a, b5b)
        assert b5a.shape == (4, 32)

    def test_host_slicing_partitions_global_batch(self):
        cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=1)
        ds = SyntheticTokens(cfg)
        full = ds.batch(3)["tokens"]
        h0 = ds.batch(3, host_slice=(0, 2))["tokens"]
        h1 = ds.batch(3, host_slice=(1, 2))["tokens"]
        np.testing.assert_array_equal(np.concatenate([h0, h1]), full)

    def test_zipf_skew(self):
        cfg = DataConfig(vocab_size=5000, seq_len=256, global_batch=4, seed=2)
        toks = SyntheticTokens(cfg).batch(0)["tokens"]
        # Zipf: low token ids dominate.
        assert (toks < 50).mean() > 0.2


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        mgr.save(10, tree, blocking=True)
        assert mgr.latest_step() == 10
        out = mgr.restore(10, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.asarray(tree["b"]["c"]))

    def test_async_save_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"w": jnp.zeros(8)}
        for s in [1, 2, 3, 4]:
            mgr.save(s, tree)
        mgr.wait()
        mgr._gc()
        assert mgr.all_steps() == [3, 4]

    def test_crash_leaves_no_partial_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        # Simulate a crashed save: orphan tmp dir.
        os.makedirs(tmp_path / "step_00000099.tmp")
        assert mgr.latest_step() is None
        mgr.save(5, {"w": jnp.zeros(2)}, blocking=True)
        assert mgr.latest_step() == 5
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.zeros(4)}, blocking=True)
        with pytest.raises(ValueError):
            mgr.restore(1, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})

    def test_train_resume_is_bit_identical(self, tmp_path):
        """Train 6 steps straight vs 3 + checkpoint + resume 3."""
        cfg = tiny("granite-3-2b")
        model = model_for(cfg)
        tcfg = train_loop.TrainConfig(
            adamw=opt.AdamWConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10)
        )
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 16, 2, seed=3))
        step = jax.jit(train_loop.make_train_step(model, tcfg))

        def run(state, lo, hi):
            for i in range(lo, hi):
                state, _ = step(state, {"tokens": jnp.asarray(data.batch(i)["tokens"])})
            return state

        s_straight = run(train_loop.init_state(model, KEY), 0, 6)
        s_half = run(train_loop.init_state(model, KEY), 0, 3)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(3, s_half, blocking=True)
        s_restored = mgr.restore(3, train_loop.abstract_state(model))
        s_resumed = run(s_restored, 3, 6)
        for a, b in zip(jax.tree.leaves(s_straight), jax.tree.leaves(s_resumed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(KEY, (1000,))
        codes, scale = _quantize(x)
        out = _dequantize(codes, scale, 1000)
        max_err = float(jnp.max(jnp.abs(out - x)))
        assert max_err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6

    def test_pod_mean_with_error_feedback(self):
        """shard_map over a fake 2-'pod' mesh: compressed mean approximates
        the true mean, and error feedback keeps the bias bounded over
        repeated rounds."""
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        devs = np.array(jax.devices()[:1])
        if len(jax.devices()) < 2:
            # Single CPU device: emulate by calling the quantize path
            # directly (all_gather over axis of size 1 is identity).
            mesh = Mesh(devs.reshape(1), ("pod",))
            g = jax.random.normal(KEY, (64,))
            r = jnp.zeros((64,))

            def f(g, r):
                return compressed_pod_mean(g, r, "pod")

            out, new_r = jax.jit(
                shard_map(
                    f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                    check_rep=False,
                )
            )(g, r)
            np.testing.assert_allclose(
                np.asarray(out + new_r), np.asarray(g), atol=1e-5
            )

    def test_residual_init_matches_structure(self):
        params = {"a": jnp.zeros((2, 3)), "b": jnp.ones(4)}
        res = init_residuals(params)
        assert res["a"].shape == (2, 3) and res["b"].shape == (4,)


class TestShardingRules:
    def _mesh(self):
        from jax.sharding import Mesh

        return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    def test_divisibility_fallback(self):
        from repro.distributed.sharding import PARAM_RULES, spec_for_shape
        from jax.sharding import Mesh

        # fake mesh sizes via a Mesh over 1 device but spec logic uses
        # mesh.shape — build an abstract mesh instead:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = spec_for_shape((64, 128), ("embed", "mlp"), mesh, PARAM_RULES)
        assert spec == jax.sharding.PartitionSpec("data", "model")

    def test_abstract_mesh_divisibility(self):
        mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
        from repro.distributed.sharding import (
            CACHE_RULES,
            PARAM_RULES,
            spec_for_shape,
        )
        from jax.sharding import PartitionSpec as P

        # kv_heads=8 indivisible by model=16 -> falls through to head_dim.
        spec = spec_for_shape(
            (2048, 8, 128), ("embed", "kv_heads", "head_dim"), mesh, PARAM_RULES
        )
        assert spec == P("data", None, "model")
        # batch=1 (long_500k) falls through to sequence sharding.
        spec = spec_for_shape(
            (1, 524288, 8, 128),
            ("batch", "seq", "kv_heads", "head_dim"),
            mesh,
            CACHE_RULES,
        )
        assert spec == P(None, "data", None, "model")
        # mixtral experts 8 indivisible -> expert dim replicated, TP inside.
        spec = spec_for_shape(
            (8, 4096, 14336), ("expert", "embed", "mlp"), mesh, PARAM_RULES
        )
        assert spec == P(None, "data", "model")

    def test_multi_axis_batch(self):
        mesh = jax.sharding.AbstractMesh((2, 16, 16), ("pod", "data", "model"))
        from repro.distributed.sharding import ACT_RULES, spec_for_shape
        from jax.sharding import PartitionSpec as P

        spec = spec_for_shape((256, 4096), ("batch", "seq"), mesh, ACT_RULES)
        assert spec == P(("pod", "data"))


class TestEndToEndTraining:
    def test_loss_descends_tiny_model(self):
        cfg = tiny("granite-3-2b")
        model = model_for(cfg)
        tcfg = train_loop.TrainConfig(
            adamw=opt.AdamWConfig(peak_lr=5e-3, warmup_steps=2, total_steps=30)
        )
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 32, 4, seed=0))
        step = jax.jit(train_loop.make_train_step(model, tcfg))
        state = train_loop.init_state(model, KEY)
        losses = []
        for i in range(25):
            state, m = step(state, {"tokens": jnp.asarray(data.batch(i)["tokens"])})
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5])

    def test_grad_accum_matches_large_batch(self):
        cfg = tiny("granite-3-2b")
        model = model_for(cfg)
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 16, 4, seed=5))
        batch = {"tokens": jnp.asarray(data.batch(0)["tokens"])}
        mk = lambda k: train_loop.make_train_step(
            model,
            train_loop.TrainConfig(
                adamw=opt.AdamWConfig(peak_lr=1e-2, warmup_steps=1),
                grad_accum=k,
            ),
        )
        s1, _ = jax.jit(mk(1))(train_loop.init_state(model, KEY), batch)
        s2, _ = jax.jit(mk(2))(train_loop.init_state(model, KEY), batch)
        # Adam's rsqrt(v) amplifies f32 reduction-order noise between the
        # single-batch and accumulated paths; compare at optimizer scale.
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=2e-3, rtol=0,
            )
