"""Non-RT request support (paper §3.3) and launcher end-to-end drills."""
import subprocess
import sys

import jax.numpy as jnp
import pytest

from repro.core import Category, DeepRT, ExecutionModel, ProfileTable, Request


def make_table():
    t = ProfileTable()
    for b in [1, 2, 4, 8, 16, 32]:
        t.record("m", (3, 224, 224), b, 0.004 + 0.0015 * b)
    return t


class TestNonRealtime:
    def test_nonrt_never_causes_rt_miss(self):
        """Paper §3.3: non-RT requests batch under a large window with a
        background-server guard — RT deadlines stay intact even when
        non-RT load is heavy."""
        table = make_table()
        sched = DeepRT(table, execution=ExecutionModel(actual_fn=lambda j, w: w))
        rt = Category("m", (3, 224, 224), realtime=True)
        nrt = Category("m", (3, 224, 224), realtime=False)
        r_rt = Request(category=rt, period=0.05, relative_deadline=0.2, n_frames=60)
        assert sched.submit_request(r_rt).admitted
        # Heavy non-RT stream (bypasses admission by design).
        for _ in range(3):
            r = Request(category=nrt, period=0.001, relative_deadline=9.0, n_frames=50)
            res = sched.submit_request(r)
            assert res.admitted and res.phase == 0
        m = sched.run()
        rt_missed = [
            k for k, (a, d, c) in m.frame_records.items()
            if k[0] == r_rt.request_id and c > d + 1e-9
        ]
        assert not rt_missed, f"non-RT load caused RT misses: {rt_missed}"

    def test_nonrt_work_completes_in_slack(self):
        table = make_table()
        sched = DeepRT(table)
        nrt = Category("m", (3, 224, 224), realtime=False)
        r = Request(category=nrt, period=0.01, relative_deadline=5.0, n_frames=10)
        sched.submit_request(r)
        m = sched.run()
        assert m.completed_frames == 10

    def test_nonrt_batch_cap_bounds_jobs(self):
        from repro.core.scheduler import NONRT_BATCH_CAP

        table = make_table()
        sched = DeepRT(table)
        nrt = Category("m", (3, 224, 224), realtime=False)
        r = Request(category=nrt, period=0.001, relative_deadline=9.0, n_frames=64)
        sched.submit_request(r)
        sched.run()
        assert max(
            j.batch_size for j in sched.worker.completed_jobs
        ) <= max(NONRT_BATCH_CAP, 1)


@pytest.mark.slow
class TestLaunchers:
    def test_train_launcher_with_crash_resume(self, tmp_path):
        """Full fault-tolerance drill through the real CLI: train, crash,
        resume from checkpoint, finish."""
        base = [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "granite-3-2b", "--tiny",
            "--steps", "12", "--batch", "2", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        ]
        env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
        import os

        env.update({k: v for k, v in os.environ.items() if k not in env})
        env["PYTHONPATH"] = "src"
        r1 = subprocess.run(
            base + ["--fail-at", "7"], capture_output=True, text=True, env=env,
            cwd="/root/repo", timeout=600,
        )
        assert "simulated failure" in (r1.stdout + r1.stderr)
        r2 = subprocess.run(
            base, capture_output=True, text=True, env=env, cwd="/root/repo",
            timeout=600,
        )
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "resuming from checkpoint step 5" in r2.stdout
        assert "step   11" in r2.stdout
