"""Network transport front end: codec, LinkPlan chaos, reassembly,
flow control, re-homing, completion faults, and the UDP binding.

Covers the acceptance bars of the transport PR:

- the wire codec round-trips DATA and control messages bit-exactly;
- ``LinkPlan.from_seed`` is deterministic and prefix-stable (the
  FaultPlan property, on the wire);
- reassembly survives loss/duplication/reordering/delay: frames are
  delivered in order exactly once, duplicates are suppressed, frames
  the link destroyed are declared lost, and the conservation identity
  ``completed + dropped + lost == ingested`` extends through the
  transport (plus the wire-level identity: every datagram that reached
  the server lands in exactly one bucket);
- client-signaled backpressure: under a 2x burst overload the
  flow-control arm (credit/duty downshift at the source) achieves a
  strictly lower effective miss rate than the no-flow-control arm, and
  the downshift is observable on the StreamSession;
- session re-homing: failing a session's home slice re-admits its tail
  as an EXTERNAL request and the transport replays REAL buffered bytes
  into the new slice — post-failover deliveries are bit-identical to
  the source's payloads (never zeros);
- duplicated / reordered COMPLETION signals (device-side network
  faults) are tolerated: no double-counted frames, no double-released
  leases, ``Metrics.duplicate_completions`` counts the suppressions;
- a hypothesis property: for ANY seed-derived link schedule (with or
  without a mid-stream slice failure), in-order exactly-once delivery,
  bit-exact payloads, and both conservation identities hold.
"""
import json

import numpy as np
import pytest

from repro.core import (
    DUP_COMPLETE,
    REORDER_COMPLETE,
    Category,
    DeepRT,
    EventLoop,
    FaultPlan,
    FaultSpec,
    FaultyDevice,
    ProfileTable,
    Request,
    SequentialDevice,
    WallClock,
)
from repro.core.cluster import build_sim_cluster
from repro.ingest import (
    DROP,
    DUPLICATE,
    LINK_DELAY,
    REORDER,
    BurstSource,
    IngestGateway,
    LinkFault,
    LinkPlan,
    PeriodicSource,
    SimLink,
    TransportServer,
    TransportSource,
    UdpClientLink,
    UdpServerBinding,
)
from repro.ingest.transport import (
    CREDIT,
    DATA,
    FIN,
    STATUS,
    STATUS_REPLY,
    decode,
    encode_control,
    encode_data,
)

CAT = Category("m", (4,))


def _sim_table(a: float = 0.01, c: float = 0.04) -> ProfileTable:
    table = ProfileTable()
    for b in (1, 2, 4, 8, 16, 32):
        table.record("m", (4,), b, a + c * b)
    return table


def _cluster(loop, names=("s0", "s1")):
    return build_sim_cluster(_sim_table, list(names), loop=loop)


def _pipeline(loop, plan=None, names=("s0", "s1"), flow=True, **server_kw):
    cluster = _cluster(loop, names)
    gateway = IngestGateway(cluster)
    server = TransportServer(
        gateway, flow_control=flow, record_payloads=True, **server_kw
    )
    link = SimLink(loop, server.datagram, plan=plan)
    return cluster, server, link


def _drain(loop, server):
    loop.run()
    server.finalize_all()
    loop.run()


def _conserved(cluster) -> bool:
    agg = cluster.aggregate_metrics()
    total = (
        agg["completed_frames"] + agg["dropped_frames"] + agg["lost_frames"]
    )
    return total == agg["ingested_frames"]


# ---------------------------------------------------------------------------
# Wire codec
# ---------------------------------------------------------------------------


class TestCodec:
    def test_data_roundtrip_bit_exact(self):
        payload = np.arange(12, dtype=np.int32).reshape(3, 4) - 5
        blob = encode_data(7, 42, 1.25, payload)
        mtype, msg = decode(blob)
        assert mtype == DATA
        assert (msg.session_id, msg.seq, msg.sent_at) == (7, 42, 1.25)
        assert msg.payload.dtype == np.int32
        assert np.array_equal(msg.payload, payload)

    def test_scalar_payload_roundtrip(self):
        blob = encode_data(1, 0, 0.0, np.int32(9))
        _mtype, msg = decode(blob)
        assert msg.payload.shape == ()
        assert int(msg.payload) == 9

    def test_control_roundtrip(self):
        blob = encode_control(FIN, {"sid": 3, "total": 17})
        mtype, body = decode(blob)
        assert mtype == FIN
        assert body == {"sid": 3, "total": 17}

    def test_bad_magic_rejected(self):
        from repro.ingest.transport import MALFORMED

        mtype, reason = decode(b"NOPE" + bytes(16))
        assert mtype == MALFORMED
        assert reason == "bad_magic"


# ---------------------------------------------------------------------------
# LinkPlan
# ---------------------------------------------------------------------------


class TestLinkPlan:
    def test_from_seed_deterministic(self):
        kw = dict(p_drop=0.1, p_dup=0.1, p_reorder=0.2, p_delay=0.2)
        a = LinkPlan.from_seed(9, 200, **kw)
        b = LinkPlan.from_seed(9, 200, **kw)
        assert [(s.kind, s.at_send, s.delay) for s in a.specs] == [
            (s.kind, s.at_send, s.delay) for s in b.specs
        ]
        c = LinkPlan.from_seed(10, 200, **kw)
        assert [(s.kind, s.at_send) for s in a.specs] != [
            (s.kind, s.at_send) for s in c.specs
        ]

    def test_from_seed_prefix_stable(self):
        kw = dict(p_drop=0.15, p_dup=0.15, p_reorder=0.15, p_delay=0.15)
        short = LinkPlan.from_seed(4, 50, **kw)
        long = LinkPlan.from_seed(4, 500, **kw)
        for i in range(50):
            a, b = short.for_send(i), long.for_send(i)
            assert (a is None) == (b is None)
            if a is not None:
                assert (a.kind, a.delay, a.copies) == (b.kind, b.delay, b.copies)

    def test_arrivals_semantics(self):
        plan = LinkPlan((
            LinkFault(DROP, 0),
            LinkFault(DUPLICATE, 1, copies=3),
            LinkFault(REORDER, 2, delay=0.5),
            LinkFault(LINK_DELAY, 3, delay=0.01),
        ))
        assert plan.arrivals(0) == []
        assert plan.arrivals(1) == [0.0, 0.0, 0.0]
        assert plan.arrivals(2) == [0.5]
        assert plan.arrivals(3) == [0.01]
        assert plan.arrivals(4) == [0.0]  # clean send

    def test_validation(self):
        with pytest.raises(ValueError):
            LinkFault("gremlin", 0)
        with pytest.raises(ValueError):
            LinkFault(REORDER, 0, delay=0.0)
        with pytest.raises(ValueError):
            LinkFault(DUPLICATE, 0, copies=1)
        with pytest.raises(ValueError):
            LinkPlan((LinkFault(DROP, 2), LinkFault(DROP, 2)))
        with pytest.raises(ValueError):
            LinkPlan.from_seed(0, 10, p_drop=0.6, p_dup=0.6)


# ---------------------------------------------------------------------------
# Reassembly over a chaotic link (sim time)
# ---------------------------------------------------------------------------


class TestReassembly:
    def _run(self, plan, n_frames=24, period=0.5, deadline=2.0, **server_kw):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, plan=plan, **server_kw)
        src = PeriodicSource(
            period=period, n_frames=n_frames, payload_shape=(4,), seed=7
        )
        client = TransportSource(src, CAT, deadline, link)
        assert client.start(server)
        _drain(loop, server)
        return cluster, server, server.sessions[1], src, client

    def test_lossless_link_delivers_everything_in_order(self):
        cluster, _server, ts, src, _client = self._run(None, n_frames=16)
        assert ts.delivered == 16
        assert ts.delivered_log == list(range(16))
        assert ts.net_lost == 0 and ts.duplicates == 0
        for seq, payload in ts.delivered_payloads.items():
            assert np.array_equal(payload, src.payload(seq))
        assert _conserved(cluster)
        assert ts.wire_conserved()

    def test_duplicates_suppressed_exactly_once(self):
        plan = LinkPlan((
            LinkFault(DUPLICATE, 2, copies=4),
            LinkFault(DUPLICATE, 5, copies=2),
        ))
        cluster, _server, ts, _src, _client = self._run(plan, n_frames=10)
        assert ts.delivered == 10
        assert ts.delivered_log == list(range(10))
        assert ts.duplicates == 4  # 3 extra copies + 1 extra copy
        assert _conserved(cluster) and ts.wire_conserved()

    def test_drops_declared_lost_and_conserved(self):
        plan = LinkPlan((LinkFault(DROP, 3), LinkFault(DROP, 8)))
        cluster, _server, ts, _src, _client = self._run(plan, n_frames=12)
        assert ts.delivered == 10
        assert ts.net_lost == 2
        assert 3 not in ts.delivered_log and 8 not in ts.delivered_log
        assert ts.delivered_log == sorted(ts.delivered_log)
        assert ts.session.frames_lost == 2
        assert _conserved(cluster) and ts.wire_conserved()

    def test_reordered_frame_held_then_delivered_in_order(self):
        # Frame 4 is held 0.6s: frames 5 and 6 arrive first and must wait
        # in the reorder buffer; delivery order stays monotone.
        plan = LinkPlan((LinkFault(REORDER, 4, delay=0.6),))
        cluster, _server, ts, src, _client = self._run(plan, n_frames=12)
        assert ts.delivered == 12
        assert ts.delivered_log == list(range(12))
        for seq, payload in ts.delivered_payloads.items():
            assert np.array_equal(payload, src.payload(seq))
        assert _conserved(cluster) and ts.wire_conserved()

    def test_reorder_window_overflow_skips_gap(self):
        # Frame 1 held far beyond the stream: with a tiny window the gap
        # is skipped (frame 1 lost), later frames still deliver in order,
        # and the straggler is refused/suppressed when it finally lands.
        plan = LinkPlan((LinkFault(REORDER, 1, delay=30.0),))
        cluster, _server, ts, _src, _client = self._run(
            plan, n_frames=10, reorder_window=2, reorder_timeout=0.9
        )
        assert 1 not in ts.delivered_log
        assert ts.delivered_log == sorted(ts.delivered_log)
        assert ts.net_lost >= 1
        assert _conserved(cluster) and ts.wire_conserved()

    def test_late_frame_rejected_against_deadline(self):
        # Held for 3x the relative deadline: the frame would miss even on
        # an idle device, so it is rejected at the door as a drop.
        plan = LinkPlan((LinkFault(LINK_DELAY, 2, delay=6.0),))
        cluster, _server, ts, _src, _client = self._run(
            plan, n_frames=8, deadline=2.0, reorder_timeout=8.0
        )
        assert ts.late_rejected == 1
        assert 2 not in ts.delivered_log
        assert ts.session.frames_dropped >= 1
        assert ts.session.last_shed_reason.startswith("late")
        assert _conserved(cluster) and ts.wire_conserved()

    def test_deliveries_are_deadline_stamped_at_arrival(self):
        # A LINK_DELAY inside the deadline budget still delivers; its
        # frame is stamped at ARRIVAL, so the extra wire latency does not
        # eat scheduling slack twice.
        plan = LinkPlan((LinkFault(LINK_DELAY, 0, delay=0.2),))
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, plan=plan)
        src = PeriodicSource(period=0.5, n_frames=4, payload_shape=(4,), seed=1)
        client = TransportSource(src, CAT, 2.0, link)
        assert client.start(server)
        _drain(loop, server)
        sl = cluster.slices[server.sessions[1].session.slice_name]
        records = sl.scheduler.metrics.frame_records
        assert records and all(
            deadline == pytest.approx(arrival + 2.0)
            for arrival, deadline, _completion in records.values()
        )


# ---------------------------------------------------------------------------
# Flow control (client-signaled backpressure)
# ---------------------------------------------------------------------------


class TestFlowControl:
    def _overloaded(self, flow: bool):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, names=("s0",), flow=flow)
        src = BurstSource(
            period=0.12, n_frames=120, payload_shape=(4,), seed=3,
            burst=8, duty=0.4,
        )
        client = TransportSource(src, CAT, 0.36, link, flow_control=flow)
        assert client.start(server)
        _drain(loop, server)
        m = cluster.slices["s0"].scheduler.metrics
        eff = (
            m.missed_frames + m.dropped_frames + m.lost_frames
        ) / m.ingested_frames
        return cluster, server.sessions[1], client, eff

    def test_flow_control_strictly_beats_control_arm(self):
        _c1, ts_a, client_a, eff_a = self._overloaded(flow=True)
        _c2, ts_b, client_b, eff_b = self._overloaded(flow=False)
        assert eff_a < eff_b
        # The downshift actually happened, at the source.
        assert client_a.downshifts_applied > 0
        assert client_a.duty > client_a.plan_duty
        assert client_b.duty == client_b.plan_duty

    def test_downshift_observable_on_session(self):
        _cluster_, ts, _client, _eff = self._overloaded(flow=True)
        s = ts.session
        assert s.downshifts > 0
        assert s.credit < 1.0  # stretched below the plan's burst rate
        assert "over_budget" in s.last_downshift_reason
        assert _conserved(_cluster_) and ts.wire_conserved()

    def test_control_arm_client_ignores_credit(self):
        _cluster_, ts, client, _eff = self._overloaded(flow=False)
        assert client.credits_seen == 0  # server never sent any
        assert ts.session.downshifts == 0


# ---------------------------------------------------------------------------
# Session re-homing on slice failover
# ---------------------------------------------------------------------------


class TestRehoming:
    def _failover_run(self, fail_at=7.0, n_frames=30, plan=None):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, plan=plan)
        src = PeriodicSource(
            period=0.5, n_frames=n_frames, payload_shape=(4,), seed=11
        )
        client = TransportSource(src, CAT, 2.0, link)
        assert client.start(server)
        ts = server.sessions[1]
        home = ts.session.slice_name
        loop.schedule(fail_at, lambda: cluster.fail_slice(home), priority=0)
        _drain(loop, server)
        return cluster, server, ts, src, client, home

    def test_session_rehomes_with_real_payload(self):
        cluster, _server, ts, src, client, home = self._failover_run()
        assert ts.rehomes == 1
        assert ts.session.rehomes == 1
        assert ts.session.slice_name != home
        assert client.rehomes_seen == 1
        post = [s for s in ts.delivered_log if s >= 15]
        assert post, "no post-failover deliveries"
        for seq in post:
            payload = ts.delivered_payloads[seq]
            assert payload.any(), f"post-failover frame {seq} is zeros"
            assert np.array_equal(payload, src.payload(seq))
        assert _conserved(cluster) and ts.wire_conserved()

    def test_rehomed_tail_is_external_not_synthetic(self):
        cluster, _server, ts, _src, _client, _home = self._failover_run()
        tail_rid = ts.session.request_id
        new_slice = cluster.slices[ts.session.slice_name]
        # Synthetic re-admission would stream payload-less frames; every
        # frame the new slice completed for the tail carries real bytes.
        tail_frames = [
            f
            for job in new_slice.scheduler.worker.completed_jobs
            for f in job.frames
            if f.request_id == tail_rid
        ]
        assert tail_frames
        assert all(f.payload is not None for f in tail_frames)
        assert all(np.asarray(f.payload).any() for f in tail_frames)

    def test_rehome_under_chaotic_link(self):
        plan = LinkPlan.from_seed(
            21, 60, p_drop=0.08, p_dup=0.08, p_reorder=0.1,
            reorder_hold=(0.1, 0.5),
        )
        cluster, _server, ts, src, _client, _home = self._failover_run(
            plan=plan
        )
        assert ts.rehomes == 1
        assert ts.delivered_log == sorted(set(ts.delivered_log))
        for seq, payload in ts.delivered_payloads.items():
            assert np.array_equal(payload, src.payload(seq))
        assert _conserved(cluster) and ts.wire_conserved()

    def test_no_surviving_slice_expires_session(self):
        # Single-slice cluster: failover has nowhere to re-home; the
        # parked tail expires and the session closes, stragglers refused.
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, names=("s0",))
        src = PeriodicSource(period=0.5, n_frames=20, payload_shape=(4,), seed=2)
        client = TransportSource(src, CAT, 2.0, link)
        assert client.start(server)
        ts = server.sessions[1]
        loop.schedule(4.0, lambda: cluster.fail_slice("s0"), priority=0)
        _drain(loop, server)
        assert ts.session.state == "closed"
        assert ts.rehomes == 0
        assert cluster.parked_expired == [ts.session.request_id] or ts.finalized
        assert ts.wire_conserved()


# ---------------------------------------------------------------------------
# Status snapshot (observability)
# ---------------------------------------------------------------------------


class TestStatusSnapshot:
    def test_snapshot_is_json_and_complete(self):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop)
        src = PeriodicSource(period=0.5, n_frames=10, payload_shape=(4,), seed=5)
        client = TransportSource(src, CAT, 2.0, link)
        assert client.start(server)
        home = server.sessions[1].session.slice_name
        loop.schedule(2.2, lambda: cluster.fail_slice(home), priority=0)
        _drain(loop, server)
        snap = json.loads(server.status_json())
        assert set(snap["slices"]) == {"s0", "s1"}
        sess = snap["sessions"]["1"]
        assert sess["wire"]["conserved"] is True
        assert sess["rehomes"] == 1
        assert sess["gateway"]["ingested"] == sess["wire"]["delivered"] + sess["wire"]["shed"] + sess["wire"]["late_rejected"] + sess["wire"]["lost_to_slice"]
        # Health transitions observed through the transport's own
        # subscription (quarantine of the failed slice).
        assert any(
            t["slice"] == home and t["new"] == "quarantined"
            for t in snap["health_transitions"]
        )
        assert snap["slices"][home]["alive"] is False


# ---------------------------------------------------------------------------
# Device-side completion faults (satellite: faults.py + EDF tolerance)
# ---------------------------------------------------------------------------


class TestCompletionFaults:
    def _run_with(self, plan: FaultPlan, n_frames=12):
        loop = EventLoop()
        device = FaultyDevice(SequentialDevice(loop), plan)
        sched = DeepRT(_sim_table(), device=device, loop=loop)
        req = Request(
            category=CAT, period=0.5, relative_deadline=1.5,
            n_frames=n_frames, start_time=0.0,
        )
        assert sched.submit_request(req).admitted
        loop.run()
        return sched.metrics

    def test_duplicate_completion_not_double_counted(self):
        m = self._run_with(FaultPlan((FaultSpec(DUP_COMPLETE, 1),)))
        assert m.completed_frames == 12
        assert m.duplicate_completions == 1
        assert m.completed_frames + m.dropped_frames + m.lost_frames == m.ingested_frames

    def test_reordered_completion_tolerated(self):
        # Job 3's signal is deferred past later jobs' signals; nothing
        # crashes, nothing double-counts, every frame resolves once.
        m = self._run_with(
            FaultPlan((FaultSpec(REORDER_COMPLETE, 3, factor=6.0),))
        )
        assert m.completed_frames == 12
        assert m.duplicate_completions == 0
        assert m.completed_frames + m.dropped_frames + m.lost_frames == m.ingested_frames

    def test_mixed_completion_chaos_conserves(self):
        plan = FaultPlan.from_seed(
            13, 64, p_dup_complete=0.2, p_reorder_complete=0.2,
        )
        m = self._run_with(plan, n_frames=40)
        assert m.completed_frames == 40
        assert m.duplicate_completions >= 1
        assert m.completed_frames + m.dropped_frames + m.lost_frames == m.ingested_frames

    def test_from_seed_draws_new_kinds(self):
        plan = FaultPlan.from_seed(
            3, 400, p_dup_complete=0.25, p_reorder_complete=0.25,
        )
        kinds = {s.kind for s in plan.specs}
        assert DUP_COMPLETE in kinds and REORDER_COMPLETE in kinds
        again = FaultPlan.from_seed(
            3, 400, p_dup_complete=0.25, p_reorder_complete=0.25,
        )
        assert [(s.kind, s.at_submit) for s in plan.specs] == [
            (s.kind, s.at_submit) for s in again.specs
        ]

    def test_reorder_complete_spec_must_defer(self):
        with pytest.raises(ValueError):
            FaultSpec(REORDER_COMPLETE, 0, factor=1.0, extra=0.0)


# ---------------------------------------------------------------------------
# UDP binding (live WallClock path, loopback socket)
# ---------------------------------------------------------------------------


class TestUdpBinding:
    def test_udp_roundtrip_over_loopback(self):
        import threading
        import time

        loop = WallClock()
        sched = DeepRT(
            _sim_table(0.001, 0.002), device=SequentialDevice(loop), loop=loop
        )
        gateway = IngestGateway(sched)
        server = TransportServer(gateway, record_payloads=True)
        binding = UdpServerBinding(server).start()
        link = UdpClientLink(loop, binding.addr)
        # The loop runs on its own thread, held alive while datagrams are
        # in flight (the rx threads post work into it, same protocol as
        # AsyncDevice completions).
        loop.hold()
        runner = threading.Thread(target=loop.run, daemon=True)
        runner.start()
        try:
            src = PeriodicSource(
                period=0.02, n_frames=8, payload_shape=(4,), seed=9
            )
            client = TransportSource(src, CAT, 1.0, link)
            sid, ok = link.handshake(client)
            assert ok and sid == 1
            client.start_remote(sid)
            deadline = time.time() + 10.0
            while time.time() < deadline:
                ts = server.sessions.get(sid)
                if ts is not None and len(ts.seen) >= 8:
                    break
                time.sleep(0.02)
            loop.post(server.finalize_all)
            while time.time() < deadline and not server.sessions[sid].finalized:
                time.sleep(0.02)
            ts = server.sessions[sid]
            assert ts.finalized
            assert ts.delivered == 8
            assert ts.delivered_log == list(range(8))
            for seq, payload in ts.delivered_payloads.items():
                assert np.array_equal(payload, src.payload(seq))
            assert ts.wire_conserved()
            m = sched.metrics
            assert (
                m.completed_frames + m.dropped_frames + m.lost_frames
                == m.ingested_frames
            )
        finally:
            link.close()
            binding.close()
            loop.release()
            runner.join(timeout=2.0)

    def test_udp_status_probe(self):
        import socket as socket_mod

        loop = WallClock()
        sched = DeepRT(_sim_table(), device=SequentialDevice(loop), loop=loop)
        server = TransportServer(IngestGateway(sched))
        binding = UdpServerBinding(server).start()
        probe = socket_mod.socket(socket_mod.AF_INET, socket_mod.SOCK_DGRAM)
        probe.settimeout(2.0)
        try:
            probe.sendto(encode_control(STATUS, {}), binding.addr)
            data, _addr = probe.recvfrom(65535)
            mtype, body = decode(data)
            assert mtype == STATUS_REPLY
            assert "sessions" in body and "scheduler" in body
        finally:
            probe.close()
            binding.close()


# ---------------------------------------------------------------------------
# Hypothesis property: any chaos schedule, same guarantees (satellite d)
# ---------------------------------------------------------------------------


def _chaos_run(seed, p_drop, p_dup, p_reorder, p_delay, fail):
    loop = EventLoop()
    cluster, server, link = _pipeline(loop)
    link.plan = LinkPlan.from_seed(
        seed, 80,
        p_drop=p_drop, p_dup=p_dup, p_reorder=p_reorder, p_delay=p_delay,
        reorder_hold=(0.1, 0.6),
    )
    src = PeriodicSource(period=0.5, n_frames=24, payload_shape=(4,), seed=seed)
    client = TransportSource(src, CAT, 2.0, link)
    assert client.start(server)
    ts = server.sessions[1]
    if fail:
        home = ts.session.slice_name
        loop.schedule(5.0, lambda: cluster.fail_slice(home), priority=0)
    _drain(loop, server)
    # In-order, exactly-once delivery.
    assert ts.delivered_log == sorted(set(ts.delivered_log))
    # Bit-identical to the lossless replay of the surviving frames
    # (re-homed or not, delivered bytes are the source's bytes).
    for seq, payload in ts.delivered_payloads.items():
        assert np.array_equal(payload, src.payload(seq))
    # Conservation through the transport, and on the wire.
    assert _conserved(cluster)
    assert ts.wire_conserved()
    # Every wire frame resolved to exactly one terminal outcome.
    assert ts.finalized or ts.session.state in ("closed", "failover")


class TestLinkChaosProperty:
    @pytest.mark.slow
    def test_any_schedule_preserves_guarantees(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (installed in CI); a bare "
            "env skips instead of erroring at collection",
        )
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @settings(
            max_examples=30,
            deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )
        @given(
            seed=st.integers(0, 10_000),
            p_drop=st.floats(0.0, 0.2),
            p_dup=st.floats(0.0, 0.2),
            p_reorder=st.floats(0.0, 0.2),
            p_delay=st.floats(0.0, 0.2),
            fail=st.booleans(),
        )
        def prop(seed, p_drop, p_dup, p_reorder, p_delay, fail):
            _chaos_run(seed, p_drop, p_dup, p_reorder, p_delay, fail)

        prop()

    def test_chaos_run_without_hypothesis(self):
        # Deterministic spot-checks of the same property, so the
        # guarantees are still exercised in environments without
        # hypothesis (the property above fuzzes the same runner).
        for seed, fail in ((0, False), (17, True), (91, True)):
            _chaos_run(
                seed, p_drop=0.12, p_dup=0.1, p_reorder=0.15, p_delay=0.1,
                fail=fail,
            )
