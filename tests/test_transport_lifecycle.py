"""Fleet-scale transport hardening: bounded resources, session
lifecycle enforcement, churn gating, adversarial wire, graceful drain.

Covers the acceptance bars of the hardening PR:

- ``decode()`` NEVER raises: a deterministic corpus of truncations,
  bad magic, dim overflows, oversized payloads, and corrupt control
  JSON each classifies to a specific ``(MALFORMED, reason)`` verdict
  (plus a hypothesis fuzzer over arbitrary byte strings in the slow
  lane), and the server counts every one instead of crashing;
- the ``UdpServerBinding`` rx thread survives a garbage datagram
  mid-stream (the regression this PR fixes: one bad datagram used to
  terminate the thread and silently kill the server);
- HELLO churn gating: the token bucket answers ``HELLO_RETRY`` with a
  backoff, clients re-HELLO and are eventually admitted, and a
  draining server refuses outright;
- bounded reassembly: per-session and global byte budgets refuse
  over-budget frames into the ``refused`` conservation leg, and the
  global gauge returns to zero at quiescence;
- zombie/slowloris eviction: an idle session is evicted through the
  NORMAL gateway close path — lease released, request retired — and
  both conservation identities survive, with the discarded buffer in
  the new ``evicted`` leg;
- graceful drain: in-flight frames complete, every session finalizes,
  ``assert_conserved()`` proves both identities at shutdown;
- cohort credit: one slice-degradation event fans ONE downshift to
  every open session homed on the slice;
- ``status(summary=True)`` stays bounded (aggregates + top-K worst)
  while small tables keep full per-session detail;
- the eviction-order property: randomly interleaving zombie eviction,
  FIN, fail_slice, and drain over seeded sessions preserves both
  conservation identities and releases every lease.
"""
import os
import struct

import numpy as np
import pytest

from repro.core import (
    Category,
    DeepRT,
    EventLoop,
    ProfileTable,
    SequentialDevice,
    WallClock,
)
from repro.core.cluster import SUSPECT, build_sim_cluster
from repro.ingest import (
    HELLO_RETRY,
    MALFORMED,
    BurstSource,
    IngestGateway,
    LinkPlan,
    PeriodicSource,
    SimLink,
    TransportServer,
    TransportSource,
    UdpClientLink,
    UdpServerBinding,
)
from repro.ingest.transport import (
    DATA,
    FIN,
    HELLO,
    HELLO_ACK,
    MAGIC,
    MAX_DIM,
    MAX_NDIM,
    _ShardedSessionTable,
    decode,
    encode_control,
    encode_data,
)

CAT = Category("m", (4,))


def _sim_table(a: float = 0.01, c: float = 0.04) -> ProfileTable:
    table = ProfileTable()
    for b in (1, 2, 4, 8, 16, 32):
        table.record("m", (4,), b, a + c * b)
    return table


def _pipeline(loop, names=("s0", "s1"), plan=None, **server_kw):
    cluster = build_sim_cluster(_sim_table, list(names), loop=loop)
    gateway = IngestGateway(cluster)
    server = TransportServer(gateway, record_payloads=True, **server_kw)
    link = SimLink(loop, server.datagram, plan=plan)
    return cluster, server, link


def _conserved(cluster) -> bool:
    agg = cluster.aggregate_metrics()
    return (
        agg["completed_frames"] + agg["dropped_frames"] + agg["lost_frames"]
        == agg["ingested_frames"]
    )


def _leases_empty(cluster) -> bool:
    return all(len(sl.leases) == 0 for sl in cluster.slices.values())


# ---------------------------------------------------------------------------
# Adversarial wire: decode() corpus (fast lane)
# ---------------------------------------------------------------------------


class TestMalformedCorpus:
    CASES = [
        (b"", "truncated_header"),
        (b"DRT", "truncated_header"),
        (b"NOPE" + bytes(16), "bad_magic"),
        (MAGIC + bytes([200]), "unknown_type"),
        (MAGIC + bytes([DATA]) + b"\x00" * 4, "truncated_data_head"),
        # ndim claims beyond the bound never allocate.
        (
            MAGIC + bytes([DATA])
            + struct.pack("!IIdB", 1, 0, 0.0, MAX_NDIM + 1),
            "ndim_overflow",
        ),
        # header promises 2 dims, supplies none.
        (
            MAGIC + bytes([DATA]) + struct.pack("!IIdB", 1, 0, 0.0, 2),
            "truncated_dims",
        ),
        # a single dim over MAX_DIM: refused before multiplying out.
        (
            MAGIC + bytes([DATA])
            + struct.pack("!IIdB", 1, 0, 0.0, 1)
            + struct.pack("!I", MAX_DIM + 1),
            "dim_overflow",
        ),
        # dims individually legal but 2^20 * 2^10 ints > 4 MiB budget.
        (
            MAGIC + bytes([DATA])
            + struct.pack("!IIdB", 1, 0, 0.0, 2)
            + struct.pack("!II", 1 << 20, 1 << 10),
            "oversized_payload",
        ),
        # shape says 4 ints, payload carries 2.
        (
            MAGIC + bytes([DATA])
            + struct.pack("!IIdB", 1, 0, 0.0, 1)
            + struct.pack("!I", 4) + bytes(8),
            "payload_size_mismatch",
        ),
        # non-finite sender clock.
        (
            MAGIC + bytes([DATA])
            + struct.pack("!IIdB", 1, 0, float("nan"), 1)
            + struct.pack("!I", 1) + bytes(4),
            "bad_sent_at",
        ),
        (MAGIC + bytes([FIN]) + b"{not json", "bad_control_json"),
        (MAGIC + bytes([FIN]) + b'"a list?"', "bad_control_json"),
    ]

    @pytest.mark.parametrize(
        "blob,reason", CASES, ids=[r for _, r in CASES]
    )
    def test_classified_not_raised(self, blob, reason):
        mtype, got = decode(blob)
        assert mtype == MALFORMED
        assert got == reason

    def test_valid_messages_still_decode(self):
        mtype, msg = decode(encode_data(3, 7, 1.5, np.arange(4, dtype=np.int32)))
        assert mtype == DATA and msg.seq == 7
        mtype, body = decode(encode_control(HELLO_RETRY, {"backoff": 0.2}))
        assert mtype == HELLO_RETRY and body == {"backoff": 0.2}

    def test_server_counts_malformed(self):
        loop = EventLoop()
        _cluster, server, _link = _pipeline(loop)
        server.datagram(b"\x01")
        server.datagram(MAGIC + bytes([200]))
        # A structurally valid FIN whose body is missing fields is a
        # counted drop too, not a KeyError in the dispatcher.
        server.datagram(encode_control(FIN, {"wrong": 1}))
        assert server.malformed == 3
        assert server.malformed_by_reason == {
            "truncated_header": 1, "unknown_type": 1, "bad_fin_body": 1,
        }
        assert server.telemetry()["malformed"] == 3

    @pytest.mark.slow
    def test_decode_never_raises_fuzz(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (installed in CI); a bare "
            "env skips instead of erroring at collection",
        )
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        valid_types = {HELLO, HELLO_ACK, DATA, FIN, HELLO_RETRY}

        @settings(
            max_examples=int(
                os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "200")
            ),
            deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )
        @given(data=st.binary(max_size=256))
        def prop(data):
            mtype, payload = decode(data)  # must never raise
            if mtype == MALFORMED:
                assert isinstance(payload, str) and payload
            else:
                assert mtype in range(1, 10)

        prop()

        # Mutations of VALID messages are the adversarial sweet spot:
        # every prefix of a real datagram classifies, never raises.
        blob = encode_data(1, 2, 0.5, np.arange(6, dtype=np.int32))
        for cut in range(len(blob)):
            mtype, _ = decode(blob[:cut])
            assert mtype in (MALFORMED, DATA) or mtype in valid_types


# ---------------------------------------------------------------------------
# UDP rx thread survival (satellite a — the regression fix)
# ---------------------------------------------------------------------------


class TestUdpRxSurvival:
    def test_garbage_datagram_does_not_kill_rx_thread(self):
        import socket as socket_mod
        import threading
        import time

        loop = WallClock()
        sched = DeepRT(
            _sim_table(0.001, 0.002), device=SequentialDevice(loop), loop=loop
        )
        gateway = IngestGateway(sched)
        server = TransportServer(gateway, record_payloads=True)
        binding = UdpServerBinding(server).start()
        link = UdpClientLink(loop, binding.addr)
        attacker = socket_mod.socket(
            socket_mod.AF_INET, socket_mod.SOCK_DGRAM
        )
        loop.hold()
        runner = threading.Thread(target=loop.run, daemon=True)
        runner.start()
        try:
            src = PeriodicSource(
                period=0.02, n_frames=8, payload_shape=(4,), seed=3
            )
            client = TransportSource(src, CAT, 1.0, link)
            sid, ok = link.handshake(client)
            assert ok
            client.start_remote(sid)
            # Mid-stream, spray garbage at the same port: truncated,
            # bad magic, absurd ndim, corrupt JSON.
            time.sleep(0.05)
            for blob in (
                b"\x00",
                b"NOPE" + bytes(32),
                MAGIC + bytes([DATA]) + struct.pack("!IIdB", sid, 0, 0.0, 255),
                MAGIC + bytes([HELLO]) + b"{broken",
            ):
                attacker.sendto(blob, binding.addr)
            deadline = time.time() + 10.0
            while time.time() < deadline:
                ts = server.sessions.get(sid)
                if ts is not None and len(ts.seen) >= 8:
                    break
                time.sleep(0.02)
            loop.post(server.finalize_all)
            while time.time() < deadline and not server.sessions[sid].finalized:
                time.sleep(0.02)
            ts = server.sessions[sid]
            # The stream survived the attack end-to-end...
            assert binding._thread.is_alive()
            assert ts.delivered == 8
            assert ts.wire_conserved()
            # ...and every garbage datagram was counted, not raised.
            deadline = time.time() + 5.0
            while time.time() < deadline and server.malformed < 4:
                time.sleep(0.02)
            assert server.malformed >= 4
        finally:
            attacker.close()
            link.close()
            binding.close()
            loop.release()
            runner.join(timeout=2.0)


# ---------------------------------------------------------------------------
# HELLO gate: token bucket, retry, drain refusal
# ---------------------------------------------------------------------------


class TestHelloGate:
    def test_storm_degrades_to_delayed_admission(self):
        loop = EventLoop()
        _cluster, server, link = _pipeline(
            loop, hello_rate=2.0, hello_burst=2.0
        )
        clients = []
        for i in range(6):
            src = PeriodicSource(
                period=0.5, n_frames=3, payload_shape=(4,), seed=i
            )
            client = TransportSource(src, CAT, 2.0, link)
            assert client.start(server)  # gated, not refused
            clients.append(client)
        # Burst of 2 admitted instantly; the rest re-HELLO on backoff.
        assert server.hellos_accepted == 2
        assert server.hello_retries_sent >= 4
        loop.run()
        server.finalize_all()
        loop.run()
        assert server.hellos_accepted == 6
        assert all(c.state in ("done", "active") for c in clients)
        assert sum(c.hello_retries for c in clients) >= 4

    def test_retry_budget_exhaustion_rejects(self):
        loop = EventLoop()
        _cluster, server, link = _pipeline(loop, max_sessions=1)
        # A long-running stream holds the only slot for 10s; the starved
        # client's 0.1s-backoff retries exhaust long before it frees.
        first = TransportSource(
            PeriodicSource(period=1.0, n_frames=10, payload_shape=(4,)),
            CAT, 5.0, link,
        )
        assert first.start(server)
        starved = TransportSource(
            PeriodicSource(period=0.5, n_frames=2, payload_shape=(4,)),
            CAT, 2.0, link, hello_max_retries=2,
        )
        assert starved.start(server)  # retrying, resolution pending
        loop.run()
        assert starved.state == "rejected"
        assert starved.hello_retries == 3  # 2 allowed + the final refusal

    def test_max_sessions_caps_concurrency(self):
        loop = EventLoop()
        _cluster, server, link = _pipeline(
            loop, max_sessions=1, idle_timeout=5.0
        )
        a = TransportSource(
            PeriodicSource(period=0.1, n_frames=2, payload_shape=(4,)),
            CAT, 0.5, link,
        )
        b = TransportSource(
            PeriodicSource(period=0.1, n_frames=2, payload_shape=(4,)),
            CAT, 0.5, link, hello_max_retries=50,
        )
        assert a.start(server)
        assert b.start(server)  # parked behind the cap, retrying
        assert server.open_count == 1
        loop.run()
        # a finished and finalized -> the cap freed -> b admitted and ran.
        assert b.state == "done"
        assert server.hellos_accepted == 2

    def test_draining_refuses_new_sessions(self):
        loop = EventLoop()
        _cluster, server, link = _pipeline(loop)
        server.drain(grace=0.0)
        late = TransportSource(
            PeriodicSource(period=0.1, n_frames=2, payload_shape=(4,)),
            CAT, 0.5, link,
        )
        assert not late.start(server)
        assert late.state == "rejected"
        assert server.hello_refused_draining == 1
        loop.run()
        assert server.drained

    def test_bad_hello_body_is_counted_not_raised(self):
        loop = EventLoop()
        _cluster, server, _link = _pipeline(loop)
        mtype, body = decode(server.hello({"model_id": "m"}))  # missing keys
        assert mtype == HELLO_ACK and not body["accepted"]
        mtype, _ = decode(
            server.hello(
                {"model_id": "m", "shape_key": [4], "period": -1.0,
                 "n_frames": 5, "relative_deadline": 0.5}
            )
        )
        assert mtype == HELLO_ACK
        assert server.malformed_by_reason.get("bad_hello_body") == 2


# ---------------------------------------------------------------------------
# Bounded reassembly budgets
# ---------------------------------------------------------------------------


class TestReassemblyBudgets:
    def _open(self, server, n_frames=4, deadline=10.0):
        # Open the session directly (no sending client): the test
        # injects datagrams by hand to control the buffer precisely.
        sid, ok = server.open_session(
            category=CAT, period=1.0, n_frames=n_frames,
            relative_deadline=deadline,
        )
        assert ok
        return sid

    def test_session_buffer_cap_refuses_overflow(self):
        loop = EventLoop()
        _cluster, server, _link = _pipeline(
            loop, session_buffer_bytes=40, reorder_window=64
        )
        self._open(server)
        ts = server.sessions[1]
        pay = np.arange(4, dtype=np.int32)  # 16 bytes
        # Out-of-order seqs 1..3 (hole at 0): two fit the 40-byte cap,
        # the third bounces off it as ``refused``.
        for seq in (1, 2, 3):
            server.datagram(encode_data(1, seq, loop.now, pay))
        assert len(ts.buffer) == 2
        assert ts.refused == 1
        assert server.budget_refusals == 1
        assert ts.buffered_bytes == 32
        assert ts.wire_conserved()
        # Plug the hole: buffered frames drain, bytes return to zero;
        # the refused frame's slot resolves as net_lost at finalize.
        server.datagram(encode_data(1, 0, loop.now, pay))
        loop.run()
        server.finalize_all()
        loop.run()
        assert ts.delivered == 3
        assert ts.buffered_bytes == 0
        assert server.reassembly_bytes == 0
        assert ts.wire_conserved()
        assert _conserved(_cluster)

    def test_global_budget_spans_sessions(self):
        loop = EventLoop()
        cluster, server, _link = _pipeline(
            loop, reassembly_budget_bytes=48, reorder_window=64
        )
        self._open(server)
        self._open(server)
        pay = np.arange(4, dtype=np.int32)
        # 3 buffered frames fill the 48-byte global pool; the 4th is
        # refused even though ITS session holds only one frame.
        server.datagram(encode_data(1, 1, loop.now, pay))
        server.datagram(encode_data(1, 2, loop.now, pay))
        server.datagram(encode_data(2, 1, loop.now, pay))
        server.datagram(encode_data(2, 2, loop.now, pay))
        assert server.reassembly_bytes == 48
        assert server.reassembly_peak_bytes == 48
        assert server.budget_refusals == 1
        assert server.sessions[2].refused == 1
        for sid in (1, 2):
            server.datagram(encode_data(sid, 0, loop.now, pay))
        loop.run()
        server.finalize_all()
        loop.run()
        assert server.reassembly_bytes == 0
        assert all(ts.wire_conserved() for ts in server.sessions.values())
        assert _conserved(cluster)


# ---------------------------------------------------------------------------
# Session lifecycle: zombie eviction, drain
# ---------------------------------------------------------------------------


class TestLifecycle:
    def test_zombie_evicted_and_conserved(self):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, idle_timeout=1.0)
        zombie = TransportSource(
            PeriodicSource(period=0.1, n_frames=20, payload_shape=(4,)),
            CAT, 0.5, link, abort_after=4,
        )
        live = TransportSource(
            PeriodicSource(period=0.1, n_frames=10, payload_shape=(4,)),
            CAT, 0.5, link,
        )
        assert zombie.start(server) and live.start(server)
        loop.run()
        assert zombie.state == "aborted"
        zts, lts = server.sessions[1], server.sessions[2]
        assert zts.finalized and zts.eviction_reason == "zombie_idle"
        assert zts.session.state == "closed"
        assert server.evictions == 1
        assert lts.delivered == 10  # bystander stream unharmed
        # Eviction went through the NORMAL close path: lease released,
        # request retired, both identities intact.
        assert _leases_empty(cluster)
        assert zts.wire_conserved() and lts.wire_conserved()
        assert _conserved(cluster)
        server.assert_conserved()

    def test_slowloris_evicted_by_idle_timeout(self):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, idle_timeout=0.5)
        # Declares a 100-frame stream but trickles one frame per 10s:
        # each inter-frame gap dwarfs the idle timeout.
        slow = TransportSource(
            PeriodicSource(period=10.0, n_frames=100, payload_shape=(4,)),
            CAT, 0.4, link, abort_after=2,
        )
        assert slow.start(server)
        loop.run()
        ts = server.sessions[1]
        assert ts.finalized and ts.eviction_reason == "zombie_idle"
        assert _leases_empty(cluster)
        assert _conserved(cluster)

    def test_evicted_buffer_lands_in_evicted_leg(self):
        loop = EventLoop()
        cluster, server, _link = _pipeline(
            loop, idle_timeout=0.5, reorder_window=64, reorder_timeout=100.0
        )
        link2 = SimLink(loop, server.datagram)
        client = TransportSource(
            PeriodicSource(period=1.0, n_frames=6, payload_shape=(4,)),
            CAT, 200.0, link2,
        )
        assert client.start(server)
        ts = server.sessions[1]
        pay = np.arange(4, dtype=np.int32)
        # Hole at 0 with a huge reorder timeout: frames sit buffered
        # until the idle sweep evicts the session.
        server.datagram(encode_data(1, 1, loop.now, pay))
        server.datagram(encode_data(1, 2, loop.now, pay))
        client.state = "aborted"  # silence the sender
        loop.run()
        assert ts.finalized
        assert ts.evicted == 2
        assert len(ts.buffer) == 0
        assert server.reassembly_bytes == 0
        assert ts.wire_conserved()
        assert _conserved(cluster)

    def test_retain_finalized_false_retires_and_folds(self):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, retain_finalized=False)
        client = TransportSource(
            PeriodicSource(period=0.1, n_frames=5, payload_shape=(4,)),
            CAT, 0.5, link,
        )
        assert client.start(server)
        loop.run()
        server.finalize_all()
        loop.run()
        # The table is EMPTY — the session's legs folded into the
        # retired totals (bounded memory under churn).
        assert len(server.sessions) == 0
        assert server.retired_sessions == 1
        assert server.retired_totals["delivered"] == 5
        server.assert_conserved()
        assert _conserved(cluster)

    def test_drain_completes_inflight_and_proves_conservation(self):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop)
        clients = []
        for i in range(3):
            c = TransportSource(
                PeriodicSource(period=0.2, n_frames=8, payload_shape=(4,)),
                CAT, 0.8, link,
            )
            assert c.start(server)
            clients.append(c)
        loop.schedule(0.7, lambda: server.drain(), priority=0)
        loop.run()
        assert server.drained
        assert all(ts.finalized for ts in server.sessions.values())
        assert all(ts.wire_conserved() for ts in server.sessions.values())
        assert _leases_empty(cluster)
        server.assert_conserved()


# ---------------------------------------------------------------------------
# Cohort credit aggregation
# ---------------------------------------------------------------------------


class TestCohortCredit:
    def test_slice_degradation_fans_one_downshift_to_cohort(self):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, names=("s0",))
        clients = []
        for i in range(3):
            src = BurstSource(
                period=0.4, n_frames=20, burst=4, duty=0.4,
                payload_shape=(4,), seed=i,
            )
            c = TransportSource(src, CAT, 2.0, link)
            assert c.start(server)
            clients.append(c)
        assert server._cohort["s0"] == {1, 2, 3}

        def degrade():
            cluster.health._set_state(
                "s0", SUSPECT, "forced degradation (test)"
            )

        loop.schedule(0.5, degrade, priority=0)
        loop.run()
        server.finalize_all()
        loop.run()
        # ONE health event -> one CREDIT per open session, not a
        # per-session delay-estimate trickle.
        assert server.cohort_signals == 3
        for sid in (1, 2, 3):
            ts = server.sessions[sid]
            assert ts.cohort_downshifts >= 1
            assert "cohort: slice s0 degraded" in (
                ts.session.last_downshift_reason or ""
            )
        assert all(c.credits_seen >= 1 for c in clients)
        assert _conserved(cluster)

    def test_full_duty_sessions_are_skipped(self):
        loop = EventLoop()
        cluster, server, link = _pipeline(loop, names=("s0",))
        c = TransportSource(
            PeriodicSource(period=0.2, n_frames=10, payload_shape=(4,)),
            CAT, 1.0, link,
        )
        assert c.start(server)  # duty 1.0: nothing to downshift
        loop.schedule(
            0.3,
            lambda: cluster.health._set_state("s0", SUSPECT, "forced"),
            priority=0,
        )
        loop.run()
        server.finalize_all()
        loop.run()
        assert server.cohort_signals == 0
        assert c.credits_seen == 0


# ---------------------------------------------------------------------------
# Bounded status (satellite b) + sharded table
# ---------------------------------------------------------------------------


class TestBoundedStatus:
    def test_summary_mode_aggregates_and_top_k(self):
        loop = EventLoop()
        _cluster, server, link = _pipeline(loop)
        for i in range(10):
            c = TransportSource(
                PeriodicSource(period=1.0, n_frames=4, payload_shape=(4,)),
                CAT, 2.0, link,
            )
            assert c.start(server)
        loop.run()
        server.finalize_all()
        loop.run()
        full = server.status()
        assert len(full["sessions"]) == 10
        summ = server.status(summary=True, top_k=3)
        assert "sessions" not in summ
        ss = summ["session_summary"]
        assert ss["count"] == 10
        assert ss["wire_totals"]["delivered"] == 40
        assert ss["conservation_violations"] == 0
        assert len(ss["worst"]) <= 3
        # The bounded reply stays bounded: summary is (much) smaller.
        import json as json_mod

        assert len(json_mod.dumps(summ)) < len(json_mod.dumps(full))
        # telemetry() rides both forms.
        assert summ["transport"]["sessions"] == 10

    def test_status_json_auto_switches_on_large_tables(self):
        import json as json_mod

        from repro.ingest.transport import TransportSession

        class _StubSession:
            state = "closed"
            slice_name = None

        loop = EventLoop()
        _cluster, server, _link = _pipeline(loop)
        body = json_mod.loads(server.status_json())
        assert "sessions" in body  # small table: full detail
        # Grow the table past the threshold: auto flips to summary.
        for sid in range(1, 70):
            if sid not in server.sessions:
                server.sessions[sid] = TransportSession(
                    sid=sid, session=_StubSession(), n_frames=1,
                    relative_deadline=1.0, plan_duty=1.0, duty=1.0,
                    finalized=True,
                )
        body = json_mod.loads(server.status_json())
        assert "session_summary" in body and "sessions" not in body


class TestShardedTable:
    def test_dict_surface(self):
        t = _ShardedSessionTable(4)
        assert t.n_shards == 4
        for sid in range(40):
            t[sid] = f"s{sid}"
        assert len(t) == 40
        assert 17 in t and t[17] == "s17"
        assert t.get(99) is None
        assert sorted(t) == list(range(40))
        assert sorted(t.keys()) == list(range(40))
        assert set(t.values()) == {f"s{i}" for i in range(40)}
        assert dict(t.items())[5] == "s5"
        del t[17]
        assert 17 not in t and len(t) == 39
        assert t.pop(18) == "s18"
        assert t.pop(18, "gone") == "gone"
        with pytest.raises(KeyError):
            t.pop(18)
        # Shards partition the id space: every sid lands in exactly one.
        assert sum(len(t.shard(i)) for i in range(4)) == len(t)

    def test_rounds_up_to_power_of_two(self):
        assert _ShardedSessionTable(5).n_shards == 8
        assert _ShardedSessionTable(1).n_shards == 1


# ---------------------------------------------------------------------------
# Eviction-order conservation property (satellite d)
# ---------------------------------------------------------------------------


def _churn_run(seed: int) -> None:
    """Seeded scenario: normal / zombie / slowloris sessions over a
    chaotic wire, with fail_slice and drain interleaved at seed-chosen
    instants. Whatever the order, both conservation identities hold and
    every lease is released."""
    import random as random_mod

    rng = random_mod.Random(seed)
    loop = EventLoop()
    cluster, server, _link = _pipeline(
        loop,
        names=("s0", "s1", "s2"),
        idle_timeout=1.0,
        session_buffer_bytes=64,
        reassembly_budget_bytes=512,
    )
    clients = []
    for i in range(8):
        kind = rng.choice(("normal", "normal", "zombie", "slowloris"))
        period = 10.0 if kind == "slowloris" else 0.1
        abort_after = None
        if kind == "zombie":
            abort_after = rng.randint(1, 4)
        elif kind == "slowloris":
            abort_after = 2
        plan = LinkPlan.from_seed(
            seed * 31 + i, 40, p_drop=0.1, p_dup=0.1, p_reorder=0.2,
            p_delay=0.1, reorder_hold=(0.05, 0.3),
        )
        link = SimLink(loop, server.datagram, plan=plan)
        c = TransportSource(
            PeriodicSource(
                period=period, n_frames=rng.randint(4, 12),
                payload_shape=(4,), seed=i,
            ),
            CAT, 0.6, link, abort_after=abort_after,
        )
        c.start(server, start_in=rng.uniform(0.0, 0.3))
        clients.append(c)
    # Adversarial datagrams land mid-run too.
    for _ in range(5):
        blob = bytes(rng.randrange(256) for _ in range(rng.randrange(40)))
        loop.schedule(
            rng.uniform(0.0, 1.0),
            lambda b=blob: server.datagram(b),
            priority=0,
        )
    if rng.random() < 0.7:
        victim = rng.choice(("s0", "s1", "s2"))
        loop.schedule(
            rng.uniform(0.2, 1.0),
            lambda v=victim: cluster.fail_slice(v),
            priority=0,
        )
    loop.schedule(rng.uniform(1.0, 3.0), lambda: server.drain(), priority=0)
    loop.run()
    server.finalize_all()
    loop.run()

    assert server.drained
    for ts in server.sessions.values():
        assert ts.finalized or ts.session.state in ("closed", "rejected")
        assert ts.wire_conserved(), (seed, ts.sid)
    assert _conserved(cluster), seed
    assert _leases_empty(cluster), seed
    # Every parked tail resolved one way.
    assert len(cluster.parked) == 0, seed
    server.assert_conserved()


class TestEvictionOrderProperty:
    def test_deterministic_interleavings(self):
        for seed in (0, 7, 23, 61, 104):
            _churn_run(seed)

    @pytest.mark.slow
    def test_any_interleaving_conserves(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (installed in CI); a bare "
            "env skips instead of erroring at collection",
        )
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @settings(
            max_examples=int(
                os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "25")
            ),
            deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )
        @given(seed=st.integers(0, 100_000))
        def prop(seed):
            _churn_run(seed)

        prop()
