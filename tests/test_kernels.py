"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
all against the ref.py pure-jnp oracles, in interpret mode on CPU."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (installed in CI); a bare "
    "environment skips this module instead of breaking collection",
)
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)
SETTINGS = settings(
    max_examples=int(os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "15")),
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    # (B, S, H, KV, D)
    (1, 16, 4, 4, 16),   # MHA tiny
    (2, 100, 8, 2, 32),  # GQA, non-divisible S
    (1, 256, 4, 1, 64),  # MQA, block-exact S
    (2, 67, 6, 2, 128),  # odd S, large head dim
    (1, 300, 2, 2, 256), # gemma-style head_dim 256
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 23), (False, None)])
def test_flash_attention_sweep(shape, dtype, causal, window):
    b, s, h, kv, d = shape
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, s, h, d), dtype)
    k = _rand(ks[1], (b, s, kv, d), dtype)
    v = _rand(ks[2], (b, s, kv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@given(
    s=st.integers(4, 200),
    h=st.sampled_from([2, 4, 8]),
    kv_div=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 32, 64]),
    window=st.one_of(st.none(), st.integers(1, 64)),
)
@SETTINGS
def test_flash_attention_property(s, h, kv_div, d, window):
    kv = h // kv_div
    ks = jax.random.split(jax.random.PRNGKey(s * 31 + h), 3)
    q = _rand(ks[0], (1, s, h, d), jnp.float32)
    k = _rand(ks[1], (1, s, kv, d), jnp.float32)
    v = _rand(ks[2], (1, s, kv, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(exp), atol=3e-5, rtol=3e-5
    )


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

DECODE_SHAPES = [
    (2, 70, 8, 2, 32),
    (1, 256, 4, 4, 64),
    (3, 33, 6, 1, 128),
    (2, 500, 16, 2, 64),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 13])
def test_decode_attention_sweep(shape, dtype, window):
    b, s, h, kv, d = shape
    ks = jax.random.split(KEY, 4)
    q = _rand(ks[0], (b, 1, h, d), dtype)
    ck = _rand(ks[1], (b, s, kv, d), dtype)
    cv = _rand(ks[2], (b, s, kv, d), dtype)
    cursor = jax.random.randint(ks[3], (b,), s // 2, s)
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    valid = kv_pos <= cursor[:, None]
    out = ops.decode_attention(q, ck, cv, cursor, kv_pos, valid, window=window)
    exp = ref.decode_attention_ref(q, ck, cv, cursor, kv_pos, valid, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_decode_attention_ring_cache_semantics():
    """Ring caches present shuffled positions + partial validity; the
    kernel must honour them exactly like the oracle."""
    b, s, h, kv, d = 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 4)
    q = _rand(ks[0], (b, 1, h, d), jnp.float32)
    ck = _rand(ks[1], (b, s, kv, d), jnp.float32)
    cv = _rand(ks[2], (b, s, kv, d), jnp.float32)
    cursor = jnp.array([100, 80], jnp.int32)
    # Ring semantics: slot i holds position (cursor - (cursor - i) % s)...
    # emulate: positions are arbitrary within [cursor-s+1, cursor].
    kv_pos = jax.random.randint(ks[3], (b, s), 0, 101)
    valid = (kv_pos >= 0) & (kv_pos <= cursor[:, None])
    out = ops.decode_attention(q, ck, cv, cursor, kv_pos, valid, window=40)
    exp = ref.decode_attention_ref(q, ck, cv, cursor, kv_pos, valid, window=40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

RGLRU_SHAPES = [(1, 8, 16), (2, 90, 48), (1, 256, 128), (3, 37, 520)]


@pytest.mark.parametrize("shape", RGLRU_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_h0", [False, True])
def test_rglru_sweep(shape, dtype, with_h0):
    b, s, d = shape
    ks = jax.random.split(KEY, 3)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, d))) * 0.5 + 0.45).astype(dtype)
    x = (_rand(ks[1], (b, s, d), jnp.float32) * 0.1).astype(dtype)
    h0 = _rand(ks[2], (b, d), jnp.float32) if with_h0 else None
    out, hl = ops.rglru_scan(a, x, h0)
    eo, ehl = ref.rglru_ref(a, x, h0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(eo, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )
    np.testing.assert_allclose(
        np.asarray(hl), np.asarray(ehl), atol=_tol(dtype), rtol=_tol(dtype)
    )


@given(s=st.integers(1, 150), d=st.sampled_from([4, 32, 130]))
@SETTINGS
def test_rglru_property(s, d):
    ks = jax.random.split(jax.random.PRNGKey(s * 7 + d), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, s, d))) * 0.9 + 0.05
    x = jax.random.normal(ks[1], (1, s, d)) * 0.2
    out, hl = ops.rglru_scan(a, x)
    eo, ehl = ref.rglru_ref(a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eo), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------

WKV_SHAPES = [(1, 8, 2, 8), (2, 90, 2, 16), (1, 200, 4, 64), (2, 33, 8, 32)]


@pytest.mark.parametrize("shape", WKV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_state", [False, True])
def test_wkv6_sweep(shape, dtype, with_state):
    b, s, h, k = shape
    ks = jax.random.split(KEY, 6)
    r = (_rand(ks[0], (b, s, h, k), jnp.float32) * 0.5).astype(dtype)
    kk = (_rand(ks[1], (b, s, h, k), jnp.float32) * 0.5).astype(dtype)
    v = (_rand(ks[2], (b, s, h, k), jnp.float32) * 0.5).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, k))) * 0.5 + 0.45).astype(dtype)
    u = _rand(ks[4], (h, k), jnp.float32) * 0.1
    st0 = _rand(ks[5], (b, h, k, k), jnp.float32) * 0.1 if with_state else None
    out, sl = ops.wkv6(r, kk, v, w, u, st0)
    eo, es = ref.wkv6_ref(r, kk, v, w, u, st0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(eo, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )
    np.testing.assert_allclose(
        np.asarray(sl), np.asarray(es), atol=_tol(dtype), rtol=_tol(dtype)
    )


# ---------------------------------------------------------------------------
# Model-level: pallas impl == xla impl end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch_id", ["granite-3-2b", "recurrentgemma-9b", "rwkv6-1.6b", "mixtral-8x7b"]
)
def test_model_pallas_matches_xla(arch_id):
    from repro.configs.registry import tiny
    from repro.models import model_for

    cfg_x = tiny(arch_id, impl="dense", moe_capacity_factor=8.0)
    cfg_p = tiny(arch_id, impl="pallas", moe_capacity_factor=8.0)
    mx, mp = model_for(cfg_x), model_for(cfg_p)
    params = mx.init(KEY)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg_x.vocab_size)
    lx, _ = mx.forward(params, toks)
    lp, _ = mp.forward(params, toks)
    np.testing.assert_allclose(
        np.asarray(lx), np.asarray(lp), atol=2e-3, rtol=2e-3
    )
