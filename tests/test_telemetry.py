"""Frame-lifecycle telemetry tests (core/telemetry.py + its wiring).

Covers the observability PR's acceptance bars:

- span ordering / terminal completeness: every delivered frame's trace
  is time-ordered and ends in EXACTLY ONE terminal span (completed /
  late / shed / lost) — the trace-level mirror of the conservation
  identity ``completed + dropped + lost == ingested``;
- ring-capacity eviction correctness (bounded memory, counted losses);
- deadline-miss attribution on a deterministic 2x overload: every
  missed frame carries a per-stage budget that sums to its observed
  latency (float tolerance), aggregated per category in the snapshot;
- streaming log-bucket histogram accuracy vs exact samples (the slow
  lane runs a hypothesis sweep);
- Metrics stays O(1)-memory with ``record_samples=False``;
- sim-vs-live trace-shape determinism: the same admitted stream under
  the EventLoop and under a WallClock + AsyncDevice produces the same
  per-frame stage sequences.
"""
from __future__ import annotations

import json
import math
import os

import pytest

from repro.core import telemetry as T
from repro.core import (
    Category,
    DeepRT,
    FrameTracer,
    LatencyHistogram,
    Metrics,
    ProfileTable,
    Request,
    WallClock,
    build_sim_cluster,
    render_text,
)
from repro.ingest import BurstSource, IngestGateway
from repro.serving.async_device import AsyncDevice

MID = "m"
SHAPE = (4,)
CAT = Category(MID, SHAPE)


def _table() -> ProfileTable:
    table = ProfileTable()
    for b in (1, 2, 4, 8, 16, 32):
        table.record(MID, SHAPE, b, 0.01 + 0.04 * b)
    return table


def _frame_traces(tracer: FrameTracer):
    """Group ring events per (rid, idx) frame, preserving emit order."""
    frames = {}
    for ev in tracer.ring:
        if ev.rid >= 0 and ev.idx >= 0:
            frames.setdefault((ev.rid, ev.idx), []).append(ev)
    return frames


# ---------------------------------------------------------------------------
# Span ordering + terminal completeness
# ---------------------------------------------------------------------------
class TestSpanLifecycle:
    def _run(self, relative_deadline: float, n_frames: int = 12):
        sched = DeepRT(_table())
        tracer = FrameTracer()
        sched.attach_tracer(tracer, tag="solo")
        req = Request(category=CAT, period=0.1, n_frames=n_frames,
                      relative_deadline=relative_deadline)
        assert sched.submit_request(req).admitted
        metrics = sched.run()
        return sched, tracer, metrics

    def test_every_frame_ends_in_exactly_one_terminal(self):
        _sched, tracer, metrics = self._run(relative_deadline=0.5)
        frames = _frame_traces(tracer)
        assert len(frames) == 12
        for key, events in frames.items():
            times = [ev.t for ev in events]
            assert times == sorted(times), (key, events)
            terminals = [ev for ev in events if ev.stage in T.TERMINAL_STAGES]
            assert len(terminals) == 1, (key, [ev.stage for ev in events])
            # The terminal is the LAST span of the frame's lifecycle.
            assert events[-1] is terminals[0], (key, events)
            assert events[0].stage == T.INGEST, (key, events)
        # Trace-level conservation mirrors the metrics identity.
        assert tracer.terminals.get(T.COMPLETED, 0) == metrics.completed_frames
        assert sum(tracer.terminals.values()) == metrics.delivered_frames
        # All frames closed out: no leaked open-stamp state.
        assert not tracer._open

    def test_full_stage_sequence_on_healthy_run(self):
        _sched, tracer, _metrics = self._run(relative_deadline=0.5)
        for key, events in _frame_traces(tracer).items():
            stages = [ev.stage for ev in events]
            assert stages == [T.INGEST, T.WINDOW_CLOSE, T.EDF_ENQUEUE,
                              T.EDF_DISPATCH, T.COMPLETED], (key, stages)

    def test_overloaded_frames_still_one_terminal_each(self):
        # Admission sees the profiled WCET; reality runs 4x over it, so
        # frames go late — lateness must not double-count or skip
        # terminals.
        from repro.core import ExecutionModel

        sched = DeepRT(_table(), execution=ExecutionModel(
            actual_fn=lambda job, w: 4.0 * w))
        tracer = FrameTracer()
        sched.attach_tracer(tracer, tag="solo")
        req = Request(category=CAT, period=0.1, n_frames=12,
                      relative_deadline=0.15)
        assert sched.submit_request(req).admitted
        metrics = sched.run()
        assert metrics.missed_frames > 0
        for key, events in _frame_traces(tracer).items():
            terminals = [ev for ev in events if ev.stage in T.TERMINAL_STAGES]
            assert len(terminals) == 1, (key, [ev.stage for ev in events])
        assert tracer.terminals.get(T.LATE, 0) == metrics.missed_frames
        assert not tracer._open

    def test_events_tagged_with_slice_and_category(self):
        _sched, tracer, _metrics = self._run(relative_deadline=0.5)
        for ev in tracer.ring:
            assert ev.where == "solo"
            if ev.rid >= 0:
                assert ev.cat == str(CAT)


# ---------------------------------------------------------------------------
# Ring eviction
# ---------------------------------------------------------------------------
class TestRingEviction:
    def test_ring_keeps_newest_and_counts_evictions(self):
        tracer = FrameTracer(capacity=16)
        for i in range(50):
            tracer.emit(T.ADMISSION, float(i), where="s0", cat="c")
        assert len(tracer.ring) == 16
        assert tracer.emitted == 50
        assert tracer.evicted == 34
        assert [ev.t for ev in tracer.ring] == [float(i) for i in range(34, 50)]

    def test_eviction_does_not_corrupt_attribution(self):
        # Stamps live outside the ring: a frame whose early spans were
        # evicted still gets a full, correctly-summing breakdown.
        tracer = FrameTracer(capacity=4)
        tracer.emit(T.INGEST, 1.0, 7, 0, where="s0", cat="c")
        for i in range(10):  # flush the ring well past capacity
            tracer.emit(T.ADMISSION, 2.0 + i, where="s0", cat="c")
        tracer.emit(T.EDF_DISPATCH, 20.0, 7, 0, where="s0", cat="c",
                    meta={"profiled": 0.5})
        tracer.emit(T.LATE, 21.0, 7, 0, where="s0", cat="c")
        assert len(tracer.miss_log) == 1
        entry = tracer.miss_log[0]
        assert entry["total"] == pytest.approx(20.0)
        assert sum(entry["stages"].values()) == pytest.approx(entry["total"])

    def test_miss_log_capped_with_overflow_counter(self):
        tracer = FrameTracer(miss_log_cap=8)
        for i in range(20):
            tracer.emit(T.INGEST, float(i), 1, i, where="s0", cat="c")
            tracer.emit(T.LATE, float(i) + 0.5, 1, i, where="s0", cat="c")
        assert len(tracer.miss_log) == 8
        assert tracer.miss_log_overflow == 12
        # Aggregates keep counting past the log cap.
        agg = tracer.attribution()["by_category"]["c"]
        assert agg["frames"] == 20


# ---------------------------------------------------------------------------
# Deadline-miss attribution (THE acceptance bar)
# ---------------------------------------------------------------------------
class TestMissAttribution:
    def _overload(self, shedding: bool, n_frames: int = 40):
        """Deterministic 2x overload replay: the declared-rate stream
        delivers its whole frame budget in half the admitted time."""
        sched = DeepRT(_table())
        tracer = FrameTracer()
        sched.attach_tracer(tracer, tag="s0")
        gw = IngestGateway(sched, shedding=shedding)
        gw.tracer = tracer
        src = BurstSource(period=0.1, n_frames=n_frames, burst=4, duty=0.5,
                          payload_shape=SHAPE, seed=11)
        session = gw.register(src, CAT, relative_deadline=0.2)
        assert session.state == "active"
        metrics = sched.run()
        return session, tracer, metrics

    def test_every_miss_sums_to_observed_latency(self):
        _session, tracer, metrics = self._overload(shedding=False)
        assert metrics.missed_frames > 0
        assert len(tracer.miss_log) == metrics.missed_frames
        for entry in tracer.miss_log:
            assert set(entry["stages"]) == set(T.ATTR_STAGES), entry
            total = sum(entry["stages"].values())
            assert abs(total - entry["total"]) < 1e-9, entry
            assert entry["total"] > 0.0, entry
            assert all(v >= 0.0 for v in entry["stages"].values()), entry

    def test_aggregation_per_category_matches_entries(self):
        _session, tracer, metrics = self._overload(shedding=False)
        attr = tracer.attribution()
        agg = attr["by_category"][str(CAT)]
        assert agg["frames"] == metrics.missed_frames
        assert agg["total"] == pytest.approx(
            sum(e["total"] for e in tracer.miss_log))
        for stage in T.ATTR_STAGES:
            assert agg[stage] == pytest.approx(
                sum(e["stages"][stage] for e in tracer.miss_log))
        # Slice-scoped aggregation sees the same mass.
        assert attr["by_slice"]["s0"]["total"] == pytest.approx(agg["total"])

    def test_shed_frames_get_terminal_and_attribution_bucket(self):
        session, tracer, metrics = self._overload(shedding=True)
        assert metrics.dropped_frames > 0
        assert tracer.terminals.get(T.SHED, 0) == metrics.dropped_frames
        # Conservation at the trace level, shed included.
        assert sum(tracer.terminals.values()) == session.frames_ingested
        attr = tracer.attribution()
        assert "shed" in attr and "lost" in attr
        shed_events = [ev for ev in tracer.ring if ev.stage == T.SHED]
        assert shed_events and all(
            ev.meta and ev.meta.get("reason") for ev in shed_events)

    def test_lost_frames_terminalized_on_dead_device(self):
        sched = DeepRT(_table())
        tracer = FrameTracer()
        sched.attach_tracer(tracer, tag="s0")
        req = Request(category=CAT, period=0.1, n_frames=3,
                      relative_deadline=0.5)
        assert sched.submit_request(req, external_arrivals=True).admitted
        sched.device._closed = True
        for i in range(3):
            sched.ingest_frame(req, i)
        assert tracer.terminals.get(T.LOST, 0) == 3
        assert sched.metrics.lost_frames == 3


# ---------------------------------------------------------------------------
# Streaming histogram
# ---------------------------------------------------------------------------
class TestLatencyHistogram:
    def test_exact_count_sum_min_max(self):
        hist = LatencyHistogram()
        samples = [0.001 * (i + 1) for i in range(100)]
        for v in samples:
            hist.record(v)
        assert hist.n == 100
        assert hist.total == pytest.approx(sum(samples))
        assert hist.vmin == pytest.approx(min(samples))
        assert hist.vmax == pytest.approx(max(samples))
        assert hist.mean == pytest.approx(sum(samples) / 100)

    def test_percentile_within_one_growth_factor(self):
        hist = LatencyHistogram(growth=1.08)
        samples = [0.0005 * (i + 1) ** 1.3 for i in range(500)]
        for v in samples:
            hist.record(v)
        ordered = sorted(samples)
        for q in (0.5, 0.9, 0.95, 0.99, 1.0):
            exact = ordered[max(1, math.ceil(q * len(ordered))) - 1]
            est = hist.percentile(q)
            assert exact * (1 - 1e-9) <= est <= exact * 1.08 * (1 + 1e-9), (
                q, exact, est)

    def test_under_and_overflow_clamped_to_observed(self):
        hist = LatencyHistogram(min_value=1e-3, max_value=1.0)
        hist.record(1e-6)   # underflow bucket
        hist.record(50.0)   # overflow bucket
        assert hist.n == 2
        assert hist.percentile(1.0) == pytest.approx(50.0)  # clamp to vmax
        assert hist.percentile(0.0) <= 1e-3

    def test_merge_equals_union(self):
        a, b, u = LatencyHistogram(), LatencyHistogram(), LatencyHistogram()
        xs = [0.002 * (i + 1) for i in range(40)]
        ys = [0.05 * (i + 1) for i in range(40)]
        for v in xs:
            a.record(v)
            u.record(v)
        for v in ys:
            b.record(v)
            u.record(v)
        a.merge(b)
        assert a.n == u.n
        assert a.total == pytest.approx(u.total)
        assert a.counts == u.counts
        assert a.percentile(0.95) == pytest.approx(u.percentile(0.95))

    def test_merge_rejects_mismatched_layout(self):
        a = LatencyHistogram(growth=1.08)
        b = LatencyHistogram(growth=1.5)
        with pytest.raises(ValueError):
            a.merge(b)

    @pytest.mark.slow
    def test_percentile_accuracy_random_samples(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (installed in CI)",
        )
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @settings(
            max_examples=int(os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "25")),
            deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )
        @given(
            samples=st.lists(
                st.floats(min_value=1e-5, max_value=1e4,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=400),
            q=st.floats(min_value=0.0, max_value=1.0),
        )
        def check(samples, q):
            hist = LatencyHistogram()
            for v in samples:
                hist.record(v)
            ordered = sorted(samples)
            exact = ordered[max(1, math.ceil(q * len(ordered))) - 1]
            est = hist.percentile(q)
            # Conservative (never under-reports beyond fp noise) and
            # within one growth factor of the exact sample quantile.
            assert est >= exact * (1 - 1e-9)
            assert est <= exact * hist.growth * (1 + 1e-9)

        check()


# ---------------------------------------------------------------------------
# Metrics memory behavior
# ---------------------------------------------------------------------------
class TestMetricsMemory:
    def _run(self, record_samples: bool):
        sched = DeepRT(_table())
        sched.metrics.record_samples = record_samples
        req = Request(category=CAT, period=0.1, n_frames=20,
                      relative_deadline=0.5)
        assert sched.submit_request(req).admitted
        return sched.run()

    def test_record_samples_false_keeps_lists_empty(self):
        m = self._run(record_samples=False)
        assert m.completed_frames == 20
        assert m.frame_latencies == [] and m.e2e_latencies == []
        # Aggregates stay exact without the sample lists.
        assert m.latency_hist.n == 20 and m.e2e_hist.n == 20
        assert m.mean_latency > 0.0 and m.mean_e2e_latency > 0.0
        assert m.latency_percentile(0.99) >= m.latency_percentile(0.5) > 0.0

    def test_default_keeps_samples_and_agrees_with_hist(self):
        m = self._run(record_samples=True)
        assert len(m.frame_latencies) == 20
        assert m.mean_latency == pytest.approx(
            sum(m.frame_latencies) / 20, rel=1e-9)

    def test_metrics_standalone_flag(self):
        m = Metrics(record_samples=False)
        assert m.record_samples is False


# ---------------------------------------------------------------------------
# Sim-vs-live trace-shape determinism
# ---------------------------------------------------------------------------
class _InstantHandle:
    def wait(self):
        return None


class TestSimLiveTraceShape:
    def _shapes(self, tracer: FrameTracer):
        return {key: [ev.stage for ev in events]
                for key, events in _frame_traces(tracer).items()}

    def test_same_stream_same_stage_sequences(self):
        n_frames = 4
        # Sim arm: EventLoop + SequentialDevice.
        sim = DeepRT(_table())
        sim_tr = FrameTracer()
        sim.attach_tracer(sim_tr, tag="s0")
        req = Request(category=CAT, period=0.08, n_frames=n_frames,
                      relative_deadline=0.3)
        assert sim.submit_request(req).admitted
        sim.run()

        # Live arm: WallClock + AsyncDevice over an instant backend.
        loop = WallClock()
        live = DeepRT(_table(), loop=loop,
                      device=AsyncDevice(loop, lambda job: _InstantHandle()))
        live_tr = FrameTracer()
        live.attach_tracer(live_tr, tag="s0")
        req2 = Request(category=CAT, period=0.08, n_frames=n_frames,
                       relative_deadline=0.3)
        assert live.submit_request(req2).admitted
        live.loop.run(until=live.loop.now + 2.0)

        sim_shapes = self._shapes(sim_tr)
        live_shapes = self._shapes(live_tr)
        # Rekey by frame index: request ids differ across schedulers.
        sim_by_idx = {idx: v for (_rid, idx), v in sim_shapes.items()}
        live_by_idx = {idx: v for (_rid, idx), v in live_shapes.items()}
        assert sim_by_idx == live_by_idx
        assert len(sim_by_idx) == n_frames
        assert sim_tr.terminals == live_tr.terminals


# ---------------------------------------------------------------------------
# Cluster snapshot + exposition + chrome export
# ---------------------------------------------------------------------------
class TestClusterTelemetry:
    def _cluster(self):
        cluster = build_sim_cluster(_table, ("s0", "s1"))
        tracer = FrameTracer()
        cluster.attach_tracer(tracer)
        req = Request(category=CAT, period=0.1, n_frames=10,
                      relative_deadline=0.5)
        assert cluster.submit_request(req)
        cluster.run()
        return cluster, tracer

    def test_snapshot_is_json_serializable_and_complete(self):
        cluster, _tracer = self._cluster()
        snap = cluster.telemetry_snapshot()
        json.dumps(snap)  # must round-trip
        assert set(snap["slices"]) == {"s0", "s1"}
        for name, sl in snap["slices"].items():
            assert sl["health"] and "utilization" in sl, name
            assert "latency" in sl and "e2e" in sl, name
        assert snap["aggregate"]["completed_frames"] == 10
        assert "e2e_p99" in snap["aggregate"]
        assert snap["tracer"]["emitted"] > 0
        assert snap["attribution"]["terminals"].get("completed", 0) == 10

    def test_text_exposition_renders_numeric_leaves(self):
        cluster, _tracer = self._cluster()
        text = cluster.telemetry_text()
        lines = text.strip().splitlines()
        assert lines == sorted(lines)
        assert any(l.startswith("deeprt_aggregate_completed_frames ")
                   for l in lines), text
        for line in lines:
            name, value = line.rsplit(" ", 1)
            float(value)  # every exposed leaf is numeric

    def test_chrome_trace_export(self, tmp_path):
        _cluster, tracer = self._cluster()
        doc = tracer.chrome_trace()
        assert doc["traceEvents"]
        for ev in doc["traceEvents"]:
            assert ev["ph"] in ("i", "X")
            assert ev["ts"] >= 0.0
        out = tmp_path / "trace.json"
        tracer.dump_chrome_trace(str(out))
        loaded = json.loads(out.read_text())
        assert len(loaded["traceEvents"]) == len(doc["traceEvents"])

    def test_tracer_default_off(self):
        sched = DeepRT(_table())
        assert sched.tracer is None
        assert sched.worker.tracer is None
        assert sched.disbatcher.tracer is None
        cluster = build_sim_cluster(_table, ("s0",))
        assert cluster.tracer is None


# ---------------------------------------------------------------------------
# Capped unbounded-growth logs (satellite)
# ---------------------------------------------------------------------------
class TestCappedLogs:
    def test_chunk_log_is_capped_deque(self):
        from collections import deque

        from repro.core.edf import CHUNK_LOG_CAP

        sched = DeepRT(_table())
        assert isinstance(sched.worker.chunk_log, deque)
        assert sched.worker.chunk_log.maxlen == CHUNK_LOG_CAP
        assert sched.worker.chunk_log_overflow == 0

    def test_placement_attempts_capped_with_overflow(self):
        from collections import deque

        cluster = build_sim_cluster(_table, ("s0",))
        assert cluster.placement_attempts.maxlen is not None
        # Shrink the audit trail so the eviction path is cheap to hit;
        # the overflow logic keys off the deque's own maxlen.
        cluster.placement_attempts = deque(maxlen=8)
        for i in range(13):
            req = Request(category=CAT, period=10.0, n_frames=1,
                          relative_deadline=0.5)
            cluster.submit_request(req)
        assert len(cluster.placement_attempts) == 8
        assert cluster.placement_attempts_overflow == 5
