"""Roofline cost-walker correctness + live serving integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import HloCost
from repro.roofline.jaxpr_cost import flops_of, jaxpr_bytes, jaxpr_flops


class TestJaxprFlops:
    def test_plain_matmul(self):
        M, K, N = 32, 64, 128
        f = lambda a, b: a @ b
        flops = flops_of(
            f,
            jax.ShapeDtypeStruct((M, K), jnp.float32),
            jax.ShapeDtypeStruct((K, N), jnp.float32),
        )
        assert flops == 2 * M * N * K

    def test_scan_multiplies_by_trip_count(self):
        M, L = 32, 7

        def f(x, ws):
            def body(h, w):
                return h @ w, None

            h, _ = jax.lax.scan(body, x, ws)
            return h

        flops = flops_of(
            f,
            jax.ShapeDtypeStruct((M, M), jnp.float32),
            jax.ShapeDtypeStruct((L, M, M), jnp.float32),
        )
        assert flops == L * 2 * M**3

    def test_nested_scan_and_remat(self):
        M, L = 16, 3

        def f(x, ws):
            def body(h, w):
                return jnp.tanh(h @ w), None

            h, _ = jax.lax.scan(jax.checkpoint(body), x, ws)
            return jnp.sum(h)

        g = lambda ws, x: jax.grad(
            lambda w: f(x, w)
        )(ws)
        flops = flops_of(
            g,
            jax.ShapeDtypeStruct((L, M, M), jnp.float32),
            jax.ShapeDtypeStruct((M, M), jnp.float32),
        )
        # fwd (1) + remat-fwd (1) + bwd (2 matmuls) = 4 matmuls per layer.
        assert flops == L * 4 * 2 * M**3

    def test_batched_einsum(self):
        B, S, H, D = 2, 8, 4, 16
        f = lambda q, k: jnp.einsum("bshd,bthd->bhst", q, k)
        flops = flops_of(
            f,
            jax.ShapeDtypeStruct((B, S, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, S, H, D), jnp.float32),
        )
        assert flops == 2 * B * H * S * S * D

    def test_bytes_excludes_attention_internal(self):
        # rank-5 f32 intermediates are attention-block-internal.
        def f(q, k):
            s = jnp.einsum(
                "bkgqd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32
            )
            return s

        closed = jax.make_jaxpr(f)(
            jax.ShapeDtypeStruct((2, 2, 2, 8, 16), jnp.bfloat16),
            jax.ShapeDtypeStruct((2, 8, 2, 16), jnp.bfloat16),
        )
        b = jaxpr_bytes(closed.jaxpr)
        # q counted? q is rank-5 but bf16 -> counted; out rank5 f32 -> not.
        q_bytes = 2 * 2 * 2 * 8 * 16 * 2
        k_bytes = 2 * 8 * 2 * 16 * 2
        assert b == q_bytes + k_bytes


class TestHloCost:
    def _compile(self, f, *args):
        return jax.jit(f).lower(*args).compile()

    def test_while_trip_count_multiplies(self):
        M, L = 64, 9

        def f(x, ws):
            def body(h, w):
                return jnp.tanh(h @ w), None

            h, _ = jax.lax.scan(body, x, ws)
            return h

        compiled = self._compile(
            f,
            jax.ShapeDtypeStruct((M, M), jnp.float32),
            jax.ShapeDtypeStruct((L, M, M), jnp.float32),
        )
        hc = HloCost(compiled.as_text())
        # The while body computation must carry multiplier L.
        mults = [
            hc.multiplier[c] for c in hc._while_comps() if c in hc.multiplier
        ]
        assert any(m >= L for m in mults), (mults, hc.multiplier)

    def test_collectives_counted_with_multiplier(self):
        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device for real collectives")

    def test_collective_parse_from_text(self):
        text = """
HloModule test

%body.1 (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %g = f32[128]{0} get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%g), replica_groups={}, to_apply=%sum.1
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
}

%cond.1 (p2: (s32[], f32[128])) -> pred[] {
  %p2 = (s32[], f32[128]) parameter(0)
  ROOT %lt = pred[] compare(%p2, %p2), direction=LT
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128]{0} parameter(0)
  %c = s32[] constant(0)
  %tup = (s32[], f32[128]) tuple(%c, %a)
  %w = (s32[], f32[128]) while(%tup), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[128]{0} get-tuple-element(%w), index=1
}
"""
        hc = HloCost(text)
        coll = hc.collective_bytes()
        assert coll["all-reduce"] == 5 * 128 * 4  # trip count x operand


class TestLiveServing:
    def test_engine_profile_and_serve(self):
        from repro.configs.registry import tiny
        from repro.serving.batcher_bridge import build_live_scheduler
        from repro.core import Category, Request

        configs = {"granite-3-2b": tiny("granite-3-2b")}
        sched, engine, table = build_live_scheduler(
            configs, [("granite-3-2b", (16,), "prefill")],
            batch_sizes=(1, 2, 4),
        )
        assert table.has("granite-3-2b", (16,))
        cat = Category("granite-3-2b", (16,))
        wcet1 = table.wcet("granite-3-2b", (16,), 1)
        req = Request(
            category=cat,
            period=max(wcet1 * 4, 0.02),
            relative_deadline=max(wcet1 * 20, 0.2),
            n_frames=6,
        )
        res = sched.submit_request(req)
        assert res.admitted
        m = sched.run()
        assert m.completed_frames == 6
        # Live wall-clock: allow slack, but gross misses mean breakage.
        assert m.miss_rate <= 0.5

    def test_engine_decode_path(self):
        from repro.configs.registry import tiny
        from repro.serving.engine import InferenceEngine

        engine = InferenceEngine({"rwkv6-1.6b": tiny("rwkv6-1.6b")})
        t = engine.execute("rwkv6-1.6b", (32,), 2, kind="decode")
        assert t > 0
