"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward pass + one train-style loss/grad step + a decode-parity
probe on CPU, asserting output shapes and no NaNs. The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct lowering).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, SHAPES, applicable_shapes, get_config, tiny
from repro.models import model_for

ALL_ARCHS = list(ARCHS)
KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _inputs(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.encdec:
        frames = 0.1 * jax.random.normal(KEY, (B, 16, cfg.d_model), jnp.float32)
        return {"frames": frames, "dec_tokens": toks}
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S)[None, None, :], (3, B, S))
        return {"tokens": toks, "positions": pos}
    return {"tokens": toks}


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_forward_shapes_and_finite(arch_id):
    cfg = tiny(arch_id)
    model = model_for(cfg)
    params = model.init(KEY)
    inp = _inputs(cfg)
    if cfg.encdec:
        logits, aux = model.forward(params, inp["frames"], inp["dec_tokens"])
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        logits, aux = model.forward(
            params, inp["tokens"], inp.get("positions")
        )
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_train_step_grads_finite(arch_id):
    cfg = tiny(arch_id)
    model = model_for(cfg)
    params = model.init(KEY)
    inp = _inputs(cfg)

    if cfg.encdec:
        loss_fn = lambda p: model.loss(p, inp["frames"], inp["dec_tokens"])
    else:
        loss_fn = lambda p: model.loss(p, inp["tokens"], inp.get("positions"))
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    assert loss > 0
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves)
    # At least some gradient signal everywhere important (embed at minimum).
    assert float(jnp.abs(grads["embed"]).max()) > 0


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_decode_matches_forward(arch_id):
    # MoE archs: use no-drop capacity so routing drops don't differ
    # between the prefill-shape and decode-shape dispatch.
    cfg = tiny(arch_id, moe_capacity_factor=8.0)
    model = model_for(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.encdec:
        frames = 0.1 * jax.random.normal(KEY, (B, 16, cfg.d_model), jnp.float32)
        full_logits, _ = model.forward(params, frames, toks)
        cache = model.init_cache(B, S, enc_len=16)
        cache = model.encode_for_decode(params, frames, cache)
        step = jax.jit(model.decode_step)
        errs = []
        for t in range(S):
            cursor = jnp.full((B,), t, jnp.int32)
            lg, cache = step(params, cache, toks[:, t], cursor)
            errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    else:
        pos3 = (
            jnp.broadcast_to(jnp.arange(S)[None, None, :], (3, B, S))
            if cfg.rope_kind == "mrope"
            else None
        )
        full_logits, _ = model.forward(params, toks, pos3)
        cache = model.init_cache(B, S)
        step = jax.jit(model.decode_step)
        errs = []
        for t in range(S):
            cursor = jnp.full((B,), t, jnp.int32)
            mp = pos3[:, :, t : t + 1] if pos3 is not None else None
            lg, cache = step(params, cache, toks[:, t], cursor, mp)
            errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    # Logit-scale tolerance; gemma-style embed scaling amplifies noise.
    assert max(errs) < 5e-3, f"decode/forward divergence {max(errs)}"


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_full_config_matches_assignment(arch_id):
    """The registry's FULL configs carry the exact assigned hyperparams."""
    expected = {
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    }[arch_id]
    cfg = get_config(arch_id)
    got = (
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == expected


def test_moe_flags():
    assert get_config("llama4-maverick-400b-a17b").n_experts == 128
    assert get_config("llama4-maverick-400b-a17b").top_k == 1
    assert get_config("mixtral-8x7b").n_experts == 8
    assert get_config("mixtral-8x7b").top_k == 2


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    runs_500k = {
        a for a in ARCHS if "long_500k" in applicable_shapes(get_config(a))
    }
    assert runs_500k == {
        "rwkv6-1.6b",
        "recurrentgemma-9b",
        "gemma3-12b",
        "mixtral-8x7b",
    }


def test_shape_specs():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1
