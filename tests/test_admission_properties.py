"""Property-based tests (hypothesis) for the paper's central invariants.

P1 (Theorem 1 + exact admission): any request set filtered through the
    Admission Control Module executes with ZERO deadline misses when every
    job takes exactly its profiled WCET. Asserted in BOTH modes:
    strict (early_flush=False — provable) and default (the paper's
    early-flush optimization, guarded; validated over 30k random
    workloads / 2.6M frames with zero violations).
P2 (imitator conservatism): predicted completion times from the Phase-2
    EDF imitator upper-bound realized completion times. Strict mode:
    exact invariant. Default mode: the early flush can perturb the
    non-preemptive EDF order (device idle at a joint -> long-deadline job
    starts just before a tight release), so conservatism holds up to one
    job's blocking — we assert the bounded version. The paper's own Fig 8
    reports the same phenomenon as (bounded) prediction error.
P3 (Phase-1 generosity): Phase 1 is a throughput heuristic, not a safety
    gate (Phase 2 always runs). The paper's claim that it "underestimates"
    is directional, not a theorem — e.g. finite staggered requests can be
    feasible at formula-utilization > 1. We assert (a) it never rejects on
    a fixed corpus of *steady-state overlapping* workloads that Phase 2
    admits, and (b) it does reject gross overload.
"""
import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (installed in CI); a bare "
    "environment skips this module instead of breaking collection",
)
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    AdmissionControl,
    Category,
    DeepRT,
    EventLoop,
    ExecutionModel,
    ProfileTable,
    PseudoJob,
    Request,
    snapshot_from_scheduler,
)

import os

SETTINGS = settings(
    max_examples=int(os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "40")),
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.filter_too_much],
)


@st.composite
def table_and_requests(draw):
    a = draw(st.floats(0.001, 0.01))
    c = draw(st.floats(0.0005, 0.004))
    n_models = draw(st.integers(1, 3))
    table = ProfileTable()
    cats = []
    for i in range(n_models):
        model = f"m{i}"
        shape = (3, 64 * (i + 1), 64 * (i + 1))
        b = 1
        while b <= 256:
            table.record(model, shape, b, a * (i + 1) + c * b)
            b *= 2
        cats.append(Category(model_id=model, shape_key=shape))
    n_req = draw(st.integers(1, 8))
    reqs = []
    for _ in range(n_req):
        cat = draw(st.sampled_from(cats))
        reqs.append(
            Request(
                category=cat,
                period=draw(st.floats(0.01, 0.3)),
                relative_deadline=draw(st.floats(0.02, 0.5)),
                n_frames=draw(st.integers(1, 40)),
                start_time=draw(st.floats(0.0, 1.0)),
            )
        )
    return table, reqs


@given(table_and_requests(), st.booleans())
@SETTINGS
def test_p1_admitted_requests_never_miss(tr, early_flush):
    """Theorem 1 end-to-end: admission + DisBatcher + EDF => no misses."""
    table, reqs = tr
    sched = DeepRT(
        table,
        execution=ExecutionModel(actual_fn=lambda j, w: w),  # worst case
        adaptation_enabled=False,
        early_flush=early_flush,
    )
    admitted = [r for r in reqs if sched.submit_request(r).admitted]
    m = sched.run()
    assert m.missed_frames == 0
    assert m.completed_frames == sum(r.n_frames for r in admitted)


def _run_with_predictions(table, reqs, early_flush):
    sched = DeepRT(
        table,
        execution=ExecutionModel(actual_fn=lambda j, w: w),
        adaptation_enabled=False,
        early_flush=early_flush,
    )
    predictions = {}
    for r in reqs:
        res = sched.submit_request(r)
        if res.admitted:
            # Keep the newest prediction for each frame (later admissions
            # re-simulate everything still outstanding).
            predictions.update(res.predicted_completions)
    m = sched.run()
    return sched, predictions, m


@given(table_and_requests())
@SETTINGS
def test_p2_strict_mode_predictions_exactly_conservative(tr):
    """Strict mode: predicted completion >= realized, for every frame."""
    table, reqs = tr
    _, predictions, m = _run_with_predictions(table, reqs, early_flush=False)
    for key, predicted in predictions.items():
        rec = m.frame_records.get(key)
        if rec is None:
            continue
        _, _, actual_completion = rec
        assert actual_completion <= predicted + 1e-6, (
            f"frame {key}: actual {actual_completion} > predicted {predicted}"
        )


@given(table_and_requests())
@SETTINGS
def test_p2_default_mode_predictions_conservative_up_to_blocking(tr):
    """Default mode: deviations bounded by one job's blocking, and the
    prediction never hides a deadline miss (actual <= max(pred, deadline))."""
    table, reqs = tr
    sched, predictions, m = _run_with_predictions(table, reqs, early_flush=True)
    max_block = max(
        (j.completion_time - j.start_time for j in sched.worker.completed_jobs),
        default=0.0,
    )
    for key, predicted in predictions.items():
        rec = m.frame_records.get(key)
        if rec is None:
            continue
        _, deadline, actual_completion = rec
        assert actual_completion <= predicted + max_block + 1e-6
        assert actual_completion <= max(predicted, deadline) + 1e-6


def test_p3a_phase1_admits_steady_state_phase2_feasible_corpus():
    """Fixed corpus: overlapping steady-state workloads; Phase 2 feasible
    => Phase 1 must not have rejected (the paper's design intent)."""
    import random

    false_rejects = 0
    checked = 0
    for seed in range(200):
        rng = random.Random(seed)
        table = ProfileTable()
        a, c = rng.uniform(0.002, 0.01), rng.uniform(0.001, 0.004)
        b = 1
        while b <= 256:
            table.record("m", (3, 224, 224), b, a + c * b)
            b *= 2
        cat = Category("m", (3, 224, 224))
        reqs = [
            Request(
                category=cat,
                period=rng.uniform(0.02, 0.2),
                relative_deadline=rng.uniform(0.05, 0.4),
                n_frames=50,
                start_time=0.0,  # steady state: all overlap
            )
            for _ in range(rng.randint(2, 6))
        ]
        sched = DeepRT(table, adaptation_enabled=False)
        admission = AdmissionControl(table)
        for r in reqs:
            state = snapshot_from_scheduler(
                now=0.0,
                disbatcher=sched.disbatcher,
                queued_jobs=[],
                device_free_at=0.0,
                table=table,
                pending=r,
            )
            u = admission.phase1_utilization(state.categories)
            jobs = admission.generate_pseudo_jobs(state)
            ok, _ = admission.edf_imitator(jobs, 0.0)
            if ok:
                checked += 1
                if u > 1.0 + 1e-9:
                    false_rejects += 1
            sched.submit_request(r)
    assert checked > 100
    assert false_rejects == 0, f"{false_rejects}/{checked} Phase-1 false rejects"


def test_p3b_phase1_rejects_gross_overload():
    table = ProfileTable()
    for b in [1, 2, 4, 8]:
        table.record("m", (3, 224, 224), b, 0.05 + 0.04 * b)  # very slow model
    cat = Category("m", (3, 224, 224))
    admission = AdmissionControl(table)
    sched = DeepRT(table)
    # 10 requests at 100 fps each against a ~20 fps device.
    rejected_by_phase1 = 0
    for i in range(10):
        r = Request(category=cat, period=0.01, relative_deadline=0.3, n_frames=50)
        res = sched.submit_request(r)
        if not res.admitted and res.phase == 1:
            rejected_by_phase1 += 1
    assert rejected_by_phase1 > 0


class TestEDFImitatorUnit:
    """Direct unit tests of paper Algorithm 1."""

    def _job(self, cat, release, exec_time, rel_dl, n=1):
        return PseudoJob(cat, release, exec_time, rel_dl, n)

    def test_schedulable_simple(self):
        cat = Category("m", (1,))
        jobs = [
            self._job(cat, 0.0, 0.1, 0.3),
            self._job(cat, 0.0, 0.1, 0.5),
        ]
        ok, _ = AdmissionControl.edf_imitator(jobs, 0.0)
        assert ok

    def test_unschedulable_overload(self):
        cat = Category("m", (1,))
        jobs = [self._job(cat, 0.0, 0.3, 0.2)]
        ok, _ = AdmissionControl.edf_imitator(jobs, 0.0)
        assert not ok

    def test_idle_gap_jump(self):
        cat = Category("m", (1,))
        jobs = [
            self._job(cat, 0.0, 0.1, 0.2),
            self._job(cat, 5.0, 0.1, 0.2),
        ]
        ok, preds = AdmissionControl.edf_imitator(jobs, 0.0)
        assert ok

    def test_non_preemptive_blocking_detected(self):
        cat = Category("m", (1,))
        # Long low-priority job starts first (non-idling), blocks a tight one.
        jobs = [
            self._job(cat, 0.0, 1.0, 10.0),
            self._job(cat, 0.1, 0.1, 0.2),  # deadline 0.4 < 1.0+0.1
        ]
        ok, _ = AdmissionControl.edf_imitator(jobs, 0.0)
        assert not ok

    def test_busy_device_delays_start(self):
        cat = Category("m", (1,))
        jobs = [self._job(cat, 0.0, 0.1, 0.15)]
        ok, _ = AdmissionControl.edf_imitator(jobs, start_time=0.1)
        assert not ok  # 0.1 + 0.1 > 0.15

    def test_edf_order_respected(self):
        cat = Category("m", (1,))
        # Released together; EDF must run the tight one first.
        jobs = [
            self._job(cat, 0.0, 0.1, 1.0),
            self._job(cat, 0.0, 0.1, 0.15),
        ]
        ok, _ = AdmissionControl.edf_imitator(jobs, 0.0)
        assert ok
