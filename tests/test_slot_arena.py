"""Slot-arena continuous batching: allocator, single-program decode,
donation survival, flat WCET.

Covers the acceptance bars of the arena PR:
- the slot allocator reuses freed rows and rejects oversubscription /
  double frees;
- arena-gathered decode (k live rows of max_slots, scattered or prefix)
  is bit-identical to the dense per-batch reference on the live rows;
- one compiled decode program serves every batch size 1..max_slots —
  a batch sweep that used to cross power-of-two bucket boundaries (and
  recompile per bucket) triggers ZERO additional compiles;
- the resident arena survives donation: the same device buffer backs the
  cache across steps (no per-step O(cache) allocation);
- in-place row reset (``cache_reset_rows``) wipes exactly the requested
  rows, including ring-cache position sentinels;
- decode WCETs are flat: one ``record_flat`` entry answers every batch
  size, survives JSON round-trips and capacity scaling.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import tiny
from repro.core.bucketing import arena_slots
from repro.core.profiler import ProfileTable
from repro.models import model_for
from repro.models.kvcache import cache_reset_rows
from repro.serving.engine import InferenceEngine

MID = "granite-3-2b"
SEQ = 16


def _engine(**kw):
    kw.setdefault("max_slots", 8)
    return InferenceEngine({MID: tiny(MID)}, **kw)


class TestSlotAllocator:
    def test_alloc_free_reuse(self):
        e = _engine()
        first = e.alloc_slots(MID, SEQ, 3)
        assert first == (0, 1, 2)
        e.free_slots(MID, SEQ, [1])
        # The freed row is recycled (lowest-id-first) — not a fresh one.
        assert e.alloc_slots(MID, SEQ, 1) == (1,)
        arena = e.arena(MID, SEQ)
        assert sorted(arena.live) == [0, 1, 2]
        assert len(arena.free) == 5

    def test_exhaustion_raises(self):
        e = _engine(max_slots=2)
        e.alloc_slots(MID, SEQ, 2)
        with pytest.raises(RuntimeError, match="exhausted"):
            e.alloc_slots(MID, SEQ, 1)

    def test_double_free_raises(self):
        e = _engine()
        slots = e.alloc_slots(MID, SEQ, 2)
        e.free_slots(MID, SEQ, slots)
        with pytest.raises(ValueError, match="double free"):
            e.free_slots(MID, SEQ, slots)

    def test_free_validates_ids(self):
        e = _engine(max_slots=4)
        e.alloc_slots(MID, SEQ, 2)
        with pytest.raises(ValueError, match="out of range"):
            e.free_slots(MID, SEQ, [99])
        with pytest.raises(ValueError, match="duplicate"):
            e.free_slots(MID, SEQ, [1, 1])
        with pytest.raises(ValueError, match="never-allocated"):
            e.free_slots(MID, SEQ, [3])
        e.free_slots(MID, SEQ, [])  # freeing nothing: no-op
        # Nothing was mutated by the rejected/empty frees.
        assert sorted(e.arena(MID, SEQ).live) == [0, 1]

    def test_slot_dispatch_must_step_all_live_rows(self):
        """A strict subset would silently clobber the skipped live rows'
        cache at their cursors — rejected until masked writes exist."""
        e = _engine(max_slots=4)
        e.alloc_slots(MID, SEQ, 3)
        with pytest.raises(ValueError, match="ALL live rows"):
            e.dispatch(MID, (SEQ,), 2, kind="decode", slots=(0, 1))
        # Duplicate ids cannot fake the live set via set-equality.
        with pytest.raises(ValueError, match="distinct"):
            e.dispatch(MID, (SEQ,), 3, kind="decode", slots=(0, 0, 1))

    def test_prefix_dispatch_rejected_while_rows_live(self):
        """The synthetic prefix workload may not run over an arena that
        holds allocator-live requests — it would overwrite their KV."""
        e = _engine(max_slots=4)
        slots = e.alloc_slots(MID, SEQ, 2)
        with pytest.raises(ValueError, match="allocator-live"):
            e.dispatch(MID, (SEQ,), 2, kind="decode")
        e.free_slots(MID, SEQ, slots)
        e.dispatch(MID, (SEQ,), 2, kind="decode").wait()  # free again

    def test_oversize_decode_rejected(self):
        e = _engine(max_slots=4)
        with pytest.raises(ValueError, match="max_slots"):
            e.dispatch(MID, (SEQ,), 5, kind="decode")

    def test_realloc_resets_rows_in_place(self):
        """Recycling a slot wipes exactly its KV rows (a decode step had
        written nonzero K/V there) without re-creating the arena."""
        e = _engine(max_slots=4)
        slots = e.alloc_slots(MID, SEQ, 2)
        e.dispatch(MID, (SEQ,), 2, kind="decode", slots=slots).wait()
        arena = e.arena(MID, SEQ)

        def batch_rows(leaf, path, idx):
            names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
            axis = 1 if names[0] == "super" else 0
            return jnp.take(leaf, jnp.array(idx), axis=axis)

        # The step wrote K/V at the cursor: the dispatched rows are dirty.
        dirty = any(
            bool(jnp.any(batch_rows(leaf, path, list(slots)) != 0))
            for path, leaf in jax.tree_util.tree_leaves_with_path(arena.cache)
        )
        assert dirty
        before_resets = arena.resets
        e.free_slots(MID, SEQ, slots)
        again = e.alloc_slots(MID, SEQ, 2)
        assert again == slots
        assert arena.resets == before_resets + 2
        # ... and recycling wiped exactly those rows back to zero.
        for path, leaf in jax.tree_util.tree_leaves_with_path(arena.cache):
            assert bool(jnp.all(batch_rows(leaf, path, list(again)) == 0)), path


class TestArenaDecodeEquivalence:
    def test_prefix_rows_bit_identical_to_dense_reference(self):
        """k live rows in the max_slots arena == the k-row dense program,
        bit for bit (row-parallel model; dead rows masked out)."""
        e = _engine()
        k = 3
        logits = e.dispatch(MID, (SEQ,), k, kind="decode").wait()
        model = model_for(tiny(MID))
        tok = jnp.zeros((k,), jnp.int32)
        cur = jnp.full((k,), SEQ - 1, jnp.int32)
        ref, _ = jax.jit(model.decode_step)(
            e.params[MID], model.init_cache(k, SEQ), tok, cur
        )
        assert bool(jnp.all(logits[:k] == ref))

    def test_scattered_slots_bit_identical(self):
        """Allocator-assigned (non-contiguous) live rows match the dense
        reference row-for-row: batch size really is data, not shape."""
        e = _engine()
        e.alloc_slots(MID, SEQ, 4, start_pos=SEQ - 1)
        e.free_slots(MID, SEQ, [0, 2])  # live rows: 1, 3 (scattered)
        logits = e.dispatch(
            MID, (SEQ,), 2, kind="decode", slots=(1, 3)
        ).wait()
        model = model_for(tiny(MID))
        tok = jnp.zeros((2,), jnp.int32)
        cur = jnp.full((2,), SEQ - 1, jnp.int32)
        ref, _ = jax.jit(model.decode_step)(
            e.params[MID], model.init_cache(2, SEQ), tok, cur
        )
        assert bool(jnp.all(logits[jnp.array([1, 3])] == ref))

    def test_donated_matches_copying(self):
        outs = {}
        for donate in (False, True):
            e = _engine(donate_cache=donate)
            hs = [
                e.dispatch(MID, (SEQ,), 3, kind="decode") for _ in range(3)
            ]
            outs[donate] = [h.wait() for h in hs]
        for a, c in zip(outs[True], outs[False]):
            assert bool(jnp.all(a == c))


class TestSingleProgramNoRecompiles:
    def test_batch_sweep_zero_recompiles(self):
        """The sequence 3 -> 5 crossed the old 4 -> 8 bucket boundary and
        recompiled; the arena serves the whole 1..max_slots sweep (and
        back) from ONE program."""
        e = _engine()
        e.execute(MID, (SEQ,), 1, kind="decode")  # warm-up: the compile
        assert e.stats["decode_compiles"] == 1
        e.reset_stats()
        for b in [1, 2, 3, 4, 5, 6, 7, 8, 5, 3, 2]:
            e.dispatch(MID, (SEQ,), b, kind="decode")
        e.dispatch(MID, (SEQ,), 8, kind="decode").wait()
        assert e.stats["decode_compiles"] == 0
        # And no per-bucket cache churn: exactly one resident arena.
        assert list(e._arenas) == [(MID, SEQ)]

    def test_prefill_still_bucketed(self):
        e = _engine()
        for b in (1, 2, 3, 4):
            e.execute(MID, (SEQ,), b, kind="prefill")
        # buckets 1, 2, 4 -> three programs; batch 3 reuses bucket 4.
        assert e.stats["prefill_compiles"] == 3


class TestArenaDonationSurvival:
    def test_buffer_identity_across_steps(self):
        """With donation the SAME device buffer backs the arena across
        steps — the in-place property the per-step O(batch) cost claim
        rests on. (CPU jax honors aliasing; only its dispatch-overhead
        economics differ, which is why donation is default-off on cpu.)"""
        e = _engine(donate_cache=True, max_slots=4)
        e.execute(MID, (SEQ,), 2, kind="decode")
        ptr0 = jax.tree.leaves(e.arena(MID, SEQ).cache)[0].unsafe_buffer_pointer()
        for b in (1, 3, 4, 2):
            e.execute(MID, (SEQ,), b, kind="decode")
        ptr1 = jax.tree.leaves(e.arena(MID, SEQ).cache)[0].unsafe_buffer_pointer()
        assert ptr0 == ptr1

    def test_backend_gated_default(self):
        e = _engine()
        assert e.donate_cache == (jax.default_backend() != "cpu")


class TestCacheResetRows:
    def test_reset_rows_and_ring_sentinel(self):
        cfg = tiny("gemma3-12b")  # swa blocks -> ring caches with pos
        model = model_for(cfg)
        cache = model.init_cache(4, SEQ)
        dirty = jax.tree.map(lambda x: x + 1, cache)
        rows = jnp.array([True, False, True, False])
        clean = cache_reset_rows(dirty, rows)

        def names_of(path):
            return [getattr(k, "key", getattr(k, "name", None)) for k in path]

        for path, leaf in jax.tree_util.tree_leaves_with_path(clean):
            names = names_of(path)
            axis = 1 if names[0] == "super" else 0
            fill = -1 if "pos" in names else 0
            wiped = jnp.take(leaf, jnp.array([0, 2]), axis=axis)
            kept = jnp.take(leaf, jnp.array([1, 3]), axis=axis)
            assert bool(jnp.all(wiped == fill)), names
            assert bool(jnp.all(kept != fill)), names


class TestKernelActiveBitmap:
    def test_dead_rows_skip_all_blocks_and_output_zero(self):
        """The Pallas decode kernel's active path: dead rows match the
        oracle's attend-to-nothing semantics (exact 0), live rows are
        untouched relative to the no-bitmap call."""
        from repro.kernels.decode_attention import decode_attention
        from repro.kernels.ref import decode_attention_ref

        key = jax.random.PRNGKey(7)
        b, s, h, kv, d = 4, 32, 4, 2, 16
        q = jax.random.normal(key, (b, 1, h, d), jnp.float32)
        ck = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, d))
        cv = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, d))
        cur = jnp.array([s - 1, s - 1, 5, 0], jnp.int32)
        kv_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        valid = jnp.ones((b, s), bool)
        active = jnp.array([True, True, False, False])
        out = decode_attention(
            q, ck, cv, cur, kv_pos, valid, active, interpret=True
        )
        exp = decode_attention_ref(q, ck, cv, cur, kv_pos, valid, active)
        assert float(jnp.abs(out - exp).max()) < 1e-5
        assert bool(jnp.all(out[2:] == 0.0))
        # Live rows bit-match the bitmap-free call (active only masks).
        plain = decode_attention(q, ck, cv, cur, kv_pos, valid, interpret=True)
        assert bool(jnp.all(out[:2] == plain[:2]))


class TestFlatDecodeWCET:
    def test_flat_entry_answers_every_batch(self):
        t = ProfileTable()
        t.record_flat("m", (SEQ,), 0.004, max_slots=8)
        assert t.has("m", (SEQ,))
        assert t.wcet("m", (SEQ,), 1) == t.wcet("m", (SEQ,), 8) == 0.004
        assert t.wcet_optimistic("m", (SEQ,), 3) == 0.004
        assert t.max_profiled_batch("m", (SEQ,)) == 8
        assert t.wcet("m", (SEQ,), 0) == 0.0
        # Beyond the arena there is NO program: infinity, so admission
        # rejects instead of the engine crashing at dispatch time.
        assert t.wcet("m", (SEQ,), 9) == float("inf")
        assert t.wcet_optimistic("m", (SEQ,), 9) == float("inf")

    def test_admission_rejects_unservable_batches(self):
        """A request stream that would batch more frames per DisBatcher
        window than max_slots is rejected up front (phase 1 sees the inf
        utilization) — mid-serving it would be an engine ValueError."""
        from repro.core import Category, DeepRT, EventLoop, Request

        t = ProfileTable()
        t.record_flat("m", (SEQ,), 0.0001, max_slots=4)
        sched = DeepRT(t, loop=EventLoop())
        # window = 0.5 * 1.0 deadline = 0.5s; period 0.05 -> ~10 frames
        # per window > 4 slots.
        too_dense = Request(
            category=Category("m", (SEQ,)), period=0.05,
            relative_deadline=1.0, n_frames=30,
        )
        res = sched.submit_request(too_dense)
        assert not res.admitted
        # A stream whose windows stay within the arena is admitted.
        sched2 = DeepRT(t, loop=EventLoop())
        ok = Request(
            category=Category("m", (SEQ,)), period=0.2,
            relative_deadline=1.0, n_frames=10,
        )
        assert sched2.submit_request(ok).admitted

    def test_flat_entry_json_roundtrip_and_scaling(self):
        t = ProfileTable()
        t.record_flat("m", (SEQ,), 0.004, max_slots=8)
        t.record("m", (32,), 2, 0.01)
        t2 = ProfileTable.from_json(t.to_json())
        assert t2.wcet("m", (SEQ,), 5) == 0.004
        assert t2.wcet("m", (32,), 2) == 0.01
        assert t2.scaled(2.0).wcet("m", (SEQ,), 5) == pytest.approx(0.008)

    def test_arena_slots_sizing(self):
        assert arena_slots(1) == 1
        assert arena_slots(5) == 8
        assert arena_slots(8) == 8
        with pytest.raises(ValueError):
            arena_slots(0)

    def test_bridge_profiles_decode_flat(self):
        from repro.serving.batcher_bridge import profile_engine

        e = _engine(max_slots=4)
        table = profile_engine(
            e, [(MID, (SEQ,), "decode")], batch_sizes=(1, 2, 4), runs=2
        )
        key = (MID, (SEQ,))
        assert key in table.flat_entries
        assert table.flat_entries[key][0] == 4
        assert key not in table.entries  # no leftover bucketed curve
        # One program profiled == one program served.
        assert e.stats["decode_compiles"] == 1

    def test_bridge_rejects_dual_kind_category(self):
        """WCET keys carry no kind: profiling one (model, shape) as both
        prefill and decode would let the flat decode entry shadow the
        prefill curve — refused loudly."""
        from repro.serving.batcher_bridge import profile_engine

        e = _engine(max_slots=4)
        with pytest.raises(ValueError, match="both"):
            profile_engine(
                e,
                [(MID, (SEQ,), "prefill"), (MID, (SEQ,), "decode")],
                batch_sizes=(1, 2),
                runs=1,
            )

    def test_live_metrics_charge_arena_rows_for_decode(self):
        """Metrics.bucket_rows must reflect the rows the engine actually
        launched: max_slots per decode job, not bucket(batch)."""
        from repro.core import Category, Request
        from repro.serving.batcher_bridge import build_live_scheduler

        e = _engine(max_slots=4)
        sched, engine, table = build_live_scheduler(
            {MID: tiny(MID)}, [(MID, (SEQ,), "decode")],
            batch_sizes=(1, 2, 4), engine=e,
        )
        w = table.wcet(MID, (SEQ,), 1)
        # Window = 0.5 * deadline = 0.125s; period 0.05 -> ~2 frames per
        # window, comfortably within the 4-slot arena (denser streams are
        # rejected by the flat table's inf beyond max_slots).
        req = Request(
            category=Category(MID, (SEQ,)), period=max(w * 4, 0.05),
            relative_deadline=max(w * 24, 0.25), n_frames=4,
        )
        assert sched.submit_request(req).admitted
        m = sched.run()
        assert m.completed_frames == 4
        assert m.job_count > 0
        assert m.bucket_rows == m.job_count * e.max_slots
        # Non-RT requests bypass admission; their batch cap shrank to the
        # arena so they can never form an unservable decode batch.
        assert sched.nonrt_batch_cap == e.max_slots
