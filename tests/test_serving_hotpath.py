"""Zero-stall serving hot path: async dispatch, donation, masked batches.

Covers the acceptance bars of the hot-path PR:
- completion ordering under the virtual-clock SequentialDevice is
  deterministic (the async contract changes nothing in simulation);
- a masked batch of k < bucket is bit-identical to the unpadded
  reference on the real rows;
- donated-cache decode equals the copying path;
- the shared bucket utility and the bucket-aware WCET lookup agree;
- WallClock fires events at their exact times (no 50 ms quantization)
  and supports cross-thread post/hold/release.
"""
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    Category,
    DeepRT,
    EventLoop,
    ExecutionModel,
    ProfileTable,
    Request,
    WallClock,
)
from repro.core.bucketing import bucket, bucket_sizes, padding_fraction


class TestBucketing:
    def test_bucket_values(self):
        assert [bucket(n) for n in [0, 1, 2, 3, 4, 5, 8, 9, 17]] == [
            0, 1, 2, 4, 4, 8, 8, 16, 32,
        ]

    def test_bucket_negative_raises(self):
        with pytest.raises(ValueError):
            bucket(-1)

    def test_bucket_sizes_grid(self):
        assert bucket_sizes(8) == [1, 2, 4, 8]
        assert bucket_sizes(5) == [1, 2, 4, 8]
        assert bucket_sizes(1) == [1]
        assert bucket_sizes(0) == []

    def test_padding_fraction(self):
        assert padding_fraction(5) == pytest.approx(3 / 8)
        assert padding_fraction(8) == 0.0

    def test_wcet_charges_engine_bucket(self):
        """The admission lookup rounds through the SAME bucket the engine
        executes: batch 5 with a pow2 grid is charged the batch-8 entry,
        and beyond-table extrapolation happens at the bucket."""
        t = ProfileTable()
        for b in [1, 2, 4, 8]:
            t.record("m", (16,), b, 0.001 * b + 0.004)
        assert t.wcet("m", (16,), 5) == t.wcet("m", (16,), 8)
        assert t.wcet("m", (16,), 9) == t.wcet("m", (16,), 16)

    def test_engine_and_table_rounding_agree(self):
        from repro.serving.engine import InferenceEngine  # noqa: F401
        import repro.serving.engine as eng

        # The engine imports THE shared bucket — no local duplicate.
        assert eng.bucket is bucket
        assert not hasattr(eng, "_bucket")


class TestDeterministicSimulation:
    """The async-capable worker must leave virtual-time runs bit-stable."""

    def _run_once(self):
        table = ProfileTable()
        for b in [1, 2, 4, 8, 16]:
            table.record("m", (1,), b, 0.004 + 0.0015 * b)
            table.record("n", (1,), b, 0.006 + 0.0020 * b)
        sched = DeepRT(
            table,
            loop=EventLoop(),
            execution=ExecutionModel(actual_fn=lambda job, wcet: 0.95 * wcet),
        )
        for i, (mid, period, dl) in enumerate(
            [("m", 0.05, 0.2), ("n", 0.07, 0.25), ("m", 0.11, 0.4)]
        ):
            req = Request(
                category=Category(mid, (1,)),
                period=period,
                relative_deadline=dl,
                n_frames=20,
                start_time=0.013 * i,
            )
            sched.submit_request(req)
        m = sched.run()
        order = [
            (j.category.model_id, j.start_time, j.completion_time, j.batch_size)
            for j in sched.worker.completed_jobs
        ]
        return order, m

    def test_completion_ordering_deterministic(self):
        o1, m1 = self._run_once()
        o2, m2 = self._run_once()
        assert o1 == o2
        # request_id is a process-global counter; compare records by
        # (frame index, timing), not by id.
        rec1 = sorted((fi, v) for (_rid, fi), v in m1.frame_records.items())
        rec2 = sorted((fi, v) for (_rid, fi), v in m2.frame_records.items())
        assert rec1 == rec2
        assert m1.completed_frames == 60

    def test_padding_metrics_recorded(self):
        _, m = self._run_once()
        assert m.bucket_rows >= m.real_rows > 0
        assert 0.0 <= m.padding_waste < 1.0
        assert len(m.dispatch_overheads) == m.job_count


class TestMaskedBatchDecode:
    def test_masked_rows_bit_identical_to_unpadded(self):
        """k real rows in a bucket(k)-slot buffer == the k-row reference,
        bit for bit (row-parallel model; pad rows parked at cursor 0)."""
        from repro.configs.registry import tiny
        from repro.models import model_for

        cfg = tiny("granite-3-2b")
        model = model_for(cfg)
        params = model.init(jax.random.PRNGKey(0))
        seq, k, b = 16, 3, bucket(3)
        tok_b = jnp.arange(b, dtype=jnp.int32) % 7
        cur_b = jnp.concatenate(
            [jnp.full((k,), seq - 1, jnp.int32), jnp.zeros((b - k,), jnp.int32)]
        )
        logits_b, _ = jax.jit(model.decode_step)(
            params, model.init_cache(b, seq), tok_b, cur_b
        )
        logits_k, _ = jax.jit(model.decode_step)(
            params, model.init_cache(k, seq), tok_b[:k],
            jnp.full((k,), seq - 1, jnp.int32),
        )
        assert bool(jnp.all(logits_b[:k] == logits_k))

    def test_donated_cache_matches_copying(self):
        from repro.configs.registry import tiny
        from repro.serving.engine import InferenceEngine

        outs = {}
        for donate in (False, True):
            engine = InferenceEngine(
                {"granite-3-2b": tiny("granite-3-2b")}, donate_cache=donate
            )
            hs = [
                engine.dispatch("granite-3-2b", (16,), 3, kind="decode")
                for _ in range(3)
            ]
            outs[donate] = [h.wait() for h in hs]
        for a, c in zip(outs[True], outs[False]):
            assert bool(jnp.all(a == c))

    def test_engine_padding_accounting(self):
        from repro.configs.registry import tiny
        from repro.serving.engine import InferenceEngine

        masked = InferenceEngine({"granite-3-2b": tiny("granite-3-2b")})
        blind = InferenceEngine(
            {"granite-3-2b": tiny("granite-3-2b")}, masked_decode=False
        )
        for e in (masked, blind):
            e.execute("granite-3-2b", (16,), 5, kind="decode")
        assert masked.padding_waste < blind.padding_waste
        assert blind.padding_waste == pytest.approx(3 / 8)

    def test_staging_buffers_are_reused(self):
        from repro.configs.registry import tiny
        from repro.serving.engine import InferenceEngine

        engine = InferenceEngine({"granite-3-2b": tiny("granite-3-2b")})
        for _ in range(4):
            engine.execute("granite-3-2b", (16,), 2, kind="prefill")
        # one (kind, mid, seq, bucket) ring; a fixed scratch pool cycled
        # across every call — zero fresh host allocations after build.
        assert len(engine._rings) == 1
        (ring,) = engine._rings.values()
        assert ring.shape == (2, 16)
        assert ring.fills == 4
        assert ring.host_allocs == ring.depth == engine.staging_depth


class TestWallClock:
    def test_exact_event_timing(self):
        loop = WallClock()
        fired = []
        t0 = loop.now
        loop.schedule(t0 + 0.08, lambda: fired.append(loop.now))
        loop.run()
        assert fired and abs(fired[0] - (t0 + 0.08)) < 0.02  # not 50ms bins

    def test_cross_thread_post_wakes_loop(self):
        loop = WallClock()
        got = []
        loop.hold()

        def waiter():
            time.sleep(0.05)
            loop.post(lambda: got.append(loop.now))
            loop.release()

        threading.Thread(target=waiter, daemon=True).start()
        t0 = time.perf_counter()
        loop.run()  # heap empty; must stay alive on the hold, then drain
        assert got and time.perf_counter() - t0 < 1.0


class TestAsyncLiveServing:
    def test_async_dispatch_serves_all_frames(self):
        from repro.configs.registry import tiny
        from repro.serving.async_device import AsyncDevice
        from repro.serving.batcher_bridge import build_live_scheduler

        configs = {"granite-3-2b": tiny("granite-3-2b")}
        sched, engine, table = build_live_scheduler(
            configs, [("granite-3-2b", (16,), "prefill")], batch_sizes=(1, 2, 4),
        )
        assert isinstance(sched.device, AsyncDevice)
        w1 = table.wcet("granite-3-2b", (16,), 1)
        req = Request(
            category=Category("granite-3-2b", (16,)),
            period=max(w1 * 4, 0.02),
            relative_deadline=max(w1 * 24, 0.25),
            n_frames=8,
        )
        assert sched.submit_request(req).admitted
        m = sched.run()
        assert m.completed_frames == 8
        assert sched.device.idle
        assert sched.device.last_error is None
        # The whole point: host stall per job is far below one exec time.
        assert m.mean_dispatch_overhead < max(w1, 0.005)

    def test_failed_execution_raises_not_completes(self):
        """A device-side failure must surface from run(), never be
        recorded as a met deadline."""
        from repro.serving.async_device import AsyncDevice

        loop = WallClock()

        class BoomHandle:
            def wait(self):
                raise ValueError("xla exploded")

        device = AsyncDevice(loop, dispatch_fn=lambda job: BoomHandle())
        completions = []
        device.submit("job", 0.01, lambda j, t: completions.append(j))
        with pytest.raises(RuntimeError, match="device execution failed"):
            loop.run()
        assert completions == []
        assert isinstance(device.last_error, ValueError)
        assert device.idle  # state released despite the failure
