"""Unit tests for the DeepRT core: DisBatcher, EDF worker, profiler."""
import pytest

from repro.core import (
    Category,
    DeepRT,
    DisBatcher,
    EventLoop,
    ExecutionModel,
    Frame,
    ProfileTable,
    Request,
    WINDOW_FRACTION,
)

CAT = Category(model_id="m", shape_key=(3, 224, 224))


def make_table(a=0.004, c=0.0015, model="m", shape=(3, 224, 224), bmax=128):
    t = ProfileTable()
    b = 1
    while b <= bmax:
        t.record(model, shape, b, a + c * b)
        b *= 2
    return t


class TestProfileTable:
    def test_exact_lookup(self):
        t = make_table()
        assert t.wcet("m", (3, 224, 224), 4) == pytest.approx(0.004 + 0.0015 * 4)

    def test_rounds_up_unprofiled(self):
        t = make_table()
        # batch 5 -> rounds to 8 (conservative)
        assert t.wcet("m", (3, 224, 224), 5) == pytest.approx(0.004 + 0.0015 * 8)

    def test_extrapolates_beyond_table(self):
        t = make_table(bmax=8)
        w8 = t.wcet("m", (3, 224, 224), 8)
        w16 = t.wcet("m", (3, 224, 224), 16)
        assert w16 == pytest.approx(w8 + 0.0015 * 8)

    def test_monotone_in_batch(self):
        t = make_table()
        prev = 0.0
        for b in range(1, 200):
            w = t.wcet("m", (3, 224, 224), b)
            assert w >= prev - 1e-12
            prev = w

    def test_zero_batch_is_free(self):
        assert make_table().wcet("m", (3, 224, 224), 0) == 0.0

    def test_capacity_scale(self):
        t = make_table()
        assert t.scaled(2.0).wcet("m", (3, 224, 224), 1) == pytest.approx(
            2 * t.wcet("m", (3, 224, 224), 1)
        )

    def test_json_roundtrip(self):
        t = make_table()
        t2 = ProfileTable.from_json(t.to_json())
        assert t2.wcet("m", (3, 224, 224), 4) == t.wcet("m", (3, 224, 224), 4)

    def test_missing_profile_raises(self):
        with pytest.raises(KeyError):
            make_table().wcet("nope", (1,), 1)


class TestDisBatcher:
    def _collect(self):
        jobs = []
        loop = EventLoop()
        db = DisBatcher(loop, emit=jobs.append)
        return loop, db, jobs

    def test_window_is_half_min_deadline(self):
        loop, db, jobs = self._collect()
        r1 = Request(category=CAT, period=0.1, relative_deadline=0.4, n_frames=3)
        r2 = Request(category=CAT, period=0.1, relative_deadline=0.2, n_frames=3)
        db.add_request(r1)
        assert db.window_of(CAT) == pytest.approx(WINDOW_FRACTION * 0.4)
        db.add_request(r2)
        assert db.window_of(CAT) == pytest.approx(WINDOW_FRACTION * 0.2)

    def test_frames_in_same_window_batch_together(self):
        loop, db, jobs = self._collect()
        r = Request(category=CAT, period=0.01, relative_deadline=0.5, n_frames=5)
        db.add_request(r)  # window 0.25
        for i in range(5):
            loop.schedule(
                i * 0.01,
                lambda i=i: db.on_frame(
                    Frame(r.request_id, CAT, i, loop.now, loop.now + 0.5)
                ),
            )
        loop.run(until=0.3)
        assert len(jobs) == 1
        assert jobs[0].batch_size == 5
        assert jobs[0].release_time == pytest.approx(0.25)
        assert jobs[0].relative_deadline == pytest.approx(0.25)

    def test_job_deadline_bounds_frame_deadlines(self):
        # Theorem 1's structural core: job deadline <= every frame deadline.
        loop, db, jobs = self._collect()
        r = Request(category=CAT, period=0.04, relative_deadline=0.3, n_frames=20)
        db.add_request(r)
        for i in range(20):
            loop.schedule(
                r.frame_arrival(i),
                lambda i=i: db.on_frame(
                    Frame(r.request_id, CAT, i, loop.now, loop.now + 0.3)
                ),
            )
        loop.run()
        assert sum(j.batch_size for j in jobs) == 20
        for j in jobs:
            for f in j.frames:
                assert j.deadline <= f.deadline + 1e-9

    def test_early_flush(self):
        loop, db, jobs = self._collect()
        r = Request(category=CAT, period=0.1, relative_deadline=1.0, n_frames=1)
        db.add_request(r)  # window 0.5
        loop.schedule(
            0.01,
            lambda: db.on_frame(Frame(r.request_id, CAT, 0, 0.01, 1.01)),
        )
        loop.schedule(0.02, lambda: db.flush_early())
        loop.run(until=0.03)
        assert len(jobs) == 1 and jobs[0].release_time == pytest.approx(0.02)

    def test_category_timer_restarts_for_late_request(self):
        loop, db, jobs = self._collect()
        r1 = Request(category=CAT, period=0.05, relative_deadline=0.2, n_frames=2)
        db.add_request(r1)
        loop.run(until=5.0)  # r1 exhausted, timer retired
        r2 = Request(
            category=CAT, period=0.05, relative_deadline=0.2, n_frames=2, start_time=5.0
        )
        db.add_request(r2)
        loop.schedule(5.0, lambda: db.on_frame(Frame(r2.request_id, CAT, 0, 5.0, 5.2)))
        loop.run(until=6.0)
        assert sum(j.batch_size for j in jobs) == 1

    def test_nonrt_uses_large_window(self):
        loop, db, jobs = self._collect()
        nrt = Category(model_id="m", shape_key=(3, 224, 224), realtime=False)
        r = Request(category=nrt, period=0.05, relative_deadline=0.1, n_frames=2)
        db.add_request(r)
        assert db.window_of(nrt) == pytest.approx(10.0)


class TestDeepRTSystem:
    def test_exact_wcet_zero_misses(self):
        table = make_table()
        sched = DeepRT(table, execution=ExecutionModel(actual_fn=lambda j, w: w))
        reqs = [
            Request(category=CAT, period=0.05, relative_deadline=0.2, n_frames=40),
            Request(category=CAT, period=0.03, relative_deadline=0.3, n_frames=60),
            Request(category=CAT, period=0.08, relative_deadline=0.15, n_frames=30),
        ]
        admitted = [r for r in reqs if sched.submit_request(r).admitted]
        m = sched.run()
        assert admitted, "expected at least one admission"
        assert m.missed_frames == 0
        assert m.completed_frames == sum(r.n_frames for r in admitted)

    def test_rejected_requests_get_no_frames(self):
        table = make_table()
        sched = DeepRT(table)
        # Infeasible: per-frame cost >> deadline budget
        r = Request(category=CAT, period=0.001, relative_deadline=0.002, n_frames=100)
        res = sched.submit_request(r)
        assert not res.admitted
        m = sched.run()
        assert m.completed_frames == 0

    def test_nonrt_bypasses_admission_and_completes(self):
        table = make_table()
        nrt = Category(model_id="m", shape_key=(3, 224, 224), realtime=False)
        sched = DeepRT(table)
        r = Request(category=nrt, period=0.01, relative_deadline=0.1, n_frames=5)
        res = sched.submit_request(r)
        assert res.admitted and res.phase == 0
        m = sched.run()
        assert m.completed_frames == 5

    def test_edf_ordering_across_categories(self):
        table = make_table()
        for b in [1, 2, 4, 8]:
            table.record("m2", (3, 112, 112), b, 0.002 + 0.001 * b)
        cat2 = Category(model_id="m2", shape_key=(3, 112, 112))
        sched = DeepRT(table, execution=ExecutionModel(actual_fn=lambda j, w: w))
        r1 = Request(category=CAT, period=0.1, relative_deadline=0.4, n_frames=10)
        r2 = Request(category=cat2, period=0.1, relative_deadline=0.1, n_frames=10)
        assert sched.submit_request(r1).admitted
        assert sched.submit_request(r2).admitted
        m = sched.run()
        assert m.missed_frames == 0
        # Tight-deadline category jobs must not be starved by the loose one.
        jobs = sched.worker.completed_jobs
        assert any(j.category == cat2 for j in jobs)
