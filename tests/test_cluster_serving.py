"""Cluster-grade tests for live multi-slice serving (core/cluster.py +
serving/batcher_bridge.build_live_cluster).

Covers the three properties the live cluster must hold:

- FAILOVER: killing a slice mid-decode re-admits or explicitly rejects
  every in-flight request (none silently dropped), never touches the
  dead slice's arena rows again, and re-leases rows on surviving
  slices' resident arenas (no arena re-creation, no recompiles).
- PLACEMENT: no sequence of submissions drives any slice past its
  Phase-1 utilization bound; spill-on-reject tries slices in
  utilization order; a slice with no free arena row is skipped.
- ARENA ISOLATION: slices hosting the same (model, seq) hold distinct
  resident buffers and compile independently — churn on one slice never
  recompiles or reshapes another.

Plus the component contracts these rest on: ``slice_arena_slots``
sizing, ``InferenceEngine.freeze``, and ``AsyncDevice.close``.

Wall-clock runs are kept short (tiny models, sub-second periods); the
assertions are accounting invariants, not timings, so they hold on slow
CI runners.
"""
import time

import jax
import pytest

from repro.configs.registry import tiny
from repro.core import Category, Request
from repro.core.bucketing import arena_slots, slice_arena_slots
from repro.serving.async_device import AsyncDevice
from repro.serving.batcher_bridge import build_live_cluster
from repro.serving.engine import InferenceEngine
from repro.core.simulator import WallClock

MID = "granite-3-2b"
SEQ_PRE = 16  # prefill category shape
SEQ_DEC = 8  # decode category shape (distinct: one kind per shape key)

DEC_CAT = Category(MID, (SEQ_DEC,))
PRE_CAT = Category(MID, (SEQ_PRE,))


def make_cluster(n=2, bounds=None, batch_sizes=(1, 2), nonrt_cap=1):
    """Tiny live cluster: one model, prefill + decode categories.

    ``nonrt_cap=1`` keeps per-slice arenas at ``bucket(max(batch_sizes))``
    rows so lease-exhaustion paths are reachable with few requests.
    """
    configs = {MID: tiny(MID)}
    cats = [(MID, (SEQ_PRE,), "prefill"), (MID, (SEQ_DEC,), "decode")]
    return build_live_cluster(
        configs,
        cats,
        slice_names=tuple(f"s{i}" for i in range(n)),
        batch_sizes=batch_sizes,
        utilization_bounds=bounds,
        profile_runs=2,
        nonrt_cap=nonrt_cap,
    )


def decode_request(period=0.2, deadline=0.4, n_frames=12):
    return Request(
        category=DEC_CAT, period=period, relative_deadline=deadline,
        n_frames=n_frames,
    )


# ---------------------------------------------------------------------------
# Per-slice arena sizing rule
# ---------------------------------------------------------------------------
class TestSliceArenaSizing:
    def test_full_bound_matches_single_device_rule(self):
        for b in (1, 2, 5, 8, 12):
            assert slice_arena_slots(b, 1.0) == arena_slots(b)

    def test_bound_scales_rows_down(self):
        assert slice_arena_slots(8, 0.5) == arena_slots(4) == 4
        assert slice_arena_slots(8, 0.25) == 2
        assert slice_arena_slots(6, 0.5) == arena_slots(3) == 4

    def test_floor_and_validation(self):
        # A thin slice still hosts at least one decode stream.
        assert slice_arena_slots(8, 0.01) == 1
        assert slice_arena_slots(8, 0.01, min_slots=2) == 2
        for bad in (0.0, -0.5, 1.5):
            with pytest.raises(ValueError):
                slice_arena_slots(8, bad)
        with pytest.raises(ValueError):
            slice_arena_slots(8, 0.5, min_slots=0)


# ---------------------------------------------------------------------------
# Engine freeze (fail-stop contract)
# ---------------------------------------------------------------------------
class TestEngineFreeze:
    @pytest.fixture(scope="class")
    def frozen_engine(self):
        engine = InferenceEngine({MID: tiny(MID)}, max_slots=2)
        engine.execute(MID, (SEQ_DEC,), 1, kind="decode")  # arena + program
        slots = engine.alloc_slots(MID, SEQ_DEC, 1)
        stats = dict(engine.stats)
        arena = engine.arena(MID, SEQ_DEC)
        counters = (arena.allocs, arena.resets, tuple(arena.live))
        engine.freeze()
        return engine, slots, stats, counters

    def test_all_ops_raise_after_freeze(self, frozen_engine):
        engine, slots, _, _ = frozen_engine
        with pytest.raises(RuntimeError, match="frozen"):
            engine.dispatch(MID, (SEQ_DEC,), 1, kind="decode")
        with pytest.raises(RuntimeError, match="frozen"):
            engine.dispatch(MID, (SEQ_PRE,), 1, kind="prefill")
        with pytest.raises(RuntimeError, match="frozen"):
            engine.alloc_slots(MID, SEQ_DEC, 1)
        with pytest.raises(RuntimeError, match="frozen"):
            engine.free_slots(MID, SEQ_DEC, slots)

    def test_frozen_engine_state_untouched(self, frozen_engine):
        engine, slots, stats, counters = frozen_engine
        for op in (
            lambda: engine.dispatch(MID, (SEQ_DEC,), 1, kind="decode"),
            lambda: engine.alloc_slots(MID, SEQ_DEC, 1),
            lambda: engine.free_slots(MID, SEQ_DEC, slots),
        ):
            with pytest.raises(RuntimeError):
                op()
        arena = engine.arena(MID, SEQ_DEC)
        assert dict(engine.stats) == stats
        assert (arena.allocs, arena.resets, tuple(arena.live)) == counters

    def test_freeze_is_idempotent(self, frozen_engine):
        engine, _, _, _ = frozen_engine
        engine.freeze()
        assert engine.frozen


# ---------------------------------------------------------------------------
# AsyncDevice fail-stop
# ---------------------------------------------------------------------------
class _InstantHandle:
    def wait(self):
        time.sleep(0.01)


class TestAsyncDeviceClose:
    def test_open_device_delivers_completion(self):
        loop = WallClock()
        done = []
        dev = AsyncDevice(loop, dispatch_fn=lambda job: _InstantHandle())
        assert dev.idle and not dev.closed
        dev.submit("j", 0.5, lambda job, now: done.append(job))
        assert not dev.idle
        loop.run()
        assert done == ["j"]
        assert dev.idle

    def test_closed_device_swallows_inflight_completion(self):
        loop = WallClock()
        done = []
        dev = AsyncDevice(loop, dispatch_fn=lambda job: _InstantHandle())
        dev.submit("j", 0.5, lambda job, now: done.append(job))
        dev.close()  # slice fails while the job is in flight
        loop.run()  # waiter posts the completion; it must be swallowed
        assert done == []
        assert dev.closed
        assert not dev.idle  # never idle again: EDF will not re-dispatch
        assert dev.busy_until is None  # device state itself is released

    def test_submit_after_close_raises_and_close_is_idempotent(self):
        loop = WallClock()
        dev = AsyncDevice(loop, dispatch_fn=lambda job: _InstantHandle())
        dev.close()
        dev.close()
        with pytest.raises(RuntimeError, match="closed"):
            dev.submit("j", 0.1, lambda job, now: None)


# ---------------------------------------------------------------------------
# Placement invariants
# ---------------------------------------------------------------------------
class TestPlacementInvariants:
    def test_no_submission_sequence_exceeds_phase1_bound(self):
        bounds = {"s0": 0.4, "s1": 0.4}
        # slice_arena_slots(4, 0.4) = 2: each bounded slice gets a 2-row
        # arena (the bound scales rows down from the unbounded 4).
        cluster, slices = make_cluster(2, bounds=bounds, batch_sizes=(1, 2, 4))
        # W = 0.5 * 0.4 = 0.2 and period 0.11 give each decode stream
        # n_g = floor(0.2/0.11) = 1 mean frame per window (incommensurate
        # period: no frame ever lands exactly on a joint, so live batches
        # stay <= 2). One stream per slice fits; folding a THIRD stream
        # into either slice makes n_g = 3 > max_slots = 2, pushing the
        # flat WCET lookup to inf: Phase 1 must reject rather than let
        # any slice exceed its bound (or its arena program).
        results = []
        for _ in range(4):
            r = decode_request(period=0.11, deadline=0.4, n_frames=100)
            results.append(cluster.submit_request(r))
            for name, sl in slices.items():
                assert sl.utilization() <= bounds[name] + 1e-6, (
                    f"{name} pushed past its Phase-1 bound"
                )
        assert results == [True, True, False, False]
        assert len(cluster.dropped) == 2
        # The rejections came from admission, not the lease gate: both
        # slices still had a free arena row when they refused.
        for _rid, ranked, chosen in list(cluster.placement_attempts)[2:]:
            assert chosen is None and len(ranked) == 2
        for sl in slices.values():
            assert len(sl.engine.arena(MID, SEQ_DEC).live) == 1
            assert len(sl.engine.arena(MID, SEQ_DEC).free) == 1

    def test_placement_spreads_and_attempts_are_utilization_ordered(self):
        cluster, _slices = make_cluster(2)
        r1, r2 = decode_request(), decode_request()
        assert cluster.submit_request(r1)
        assert cluster.submit_request(r2)
        # Identical requests land on different slices: the second sees the
        # first slice's risen utilization and takes the emptier one.
        assert (
            cluster.placement[r1.request_id] != cluster.placement[r2.request_id]
        )
        for _rid, ranked, _chosen in cluster.placement_attempts:
            utils = [u for _name, u in ranked]
            assert utils == sorted(utils)

    def test_lease_exhaustion_spills_then_sheds(self):
        # 2 rows per slice (batch_sizes=(1,2), nonrt_cap=1): four decode
        # streams fill the pod; the fifth finds no free row anywhere.
        cluster, slices = make_cluster(2)
        reqs = [decode_request() for _ in range(5)]
        results = [cluster.submit_request(r) for r in reqs]
        assert results[:4] == [True, True, True, True]
        assert results[4] is False
        assert [r.request_id for r in cluster.dropped] == [reqs[4].request_id]
        for sl in slices.values():
            arena = sl.engine.arena(MID, SEQ_DEC)
            assert len(arena.live) == 2  # full, never oversubscribed
        # The shed attempt ranked both slices but chose none.
        rid, ranked, chosen = cluster.placement_attempts[-1]
        assert rid == reqs[4].request_id
        assert len(ranked) == 2 and chosen is None

    def test_unknown_bound_key_fails_loudly(self):
        # A typoed slice name must not silently default to bound 1.0.
        with pytest.raises(ValueError, match="unknown slices"):
            make_cluster(2, bounds={"slice-0": 0.25})

    def test_per_slice_bound_spills_to_bigger_slice(self):
        # s0's Phase-1 ceiling is below any real request's utilization, so
        # even as the lowest-utilization candidate it must reject and the
        # request must spill to s1.
        cluster, slices = make_cluster(2, bounds={"s0": 0.001, "s1": 1.0})
        r = Request(
            category=PRE_CAT, period=0.01, relative_deadline=0.1, n_frames=50
        )
        assert cluster.submit_request(r)
        assert cluster.placement[r.request_id] == "s1"
        _rid, ranked, chosen = cluster.placement_attempts[-1]
        assert [name for name, _u in ranked][0] == "s0"  # tried first
        assert chosen == "s1"
        assert slices["s0"].utilization() <= 0.001 + 1e-9


# ---------------------------------------------------------------------------
# Failover: one live fault-injection scenario, several invariants
# ---------------------------------------------------------------------------
class TestFailover:
    @pytest.fixture(scope="class")
    def scenario(self):
        """Kill one slice mid-decode; drain the survivor to completion."""
        cluster, slices = make_cluster(2, batch_sizes=(1, 2, 4))  # 4 rows
        reqs = [decode_request(period=0.2, deadline=0.4, n_frames=12)
                for _ in range(4)]
        for r in reqs:
            assert cluster.submit_request(r), "probe workload must admit"
        by_slice = {}
        for rid, name in cluster.placement.items():
            by_slice.setdefault(name, []).append(rid)
        assert len(by_slice) == 2, "placement must use both slices"
        # Run into the streams so the failure hits mid-decode.
        cluster.run(until=cluster.loop.now + 0.45)
        dead = max(by_slice, key=lambda n: (len(by_slice[n]), n))
        survivor = next(n for n in by_slice if n != dead)
        victims = [rid for rid, n in cluster.placement.items() if n == dead]
        assert victims, "the failed slice must hold in-flight requests"
        at_failure = {
            "completed": cluster.aggregate_metrics()["completed_frames"],
            "survivor_allocs": slices[survivor].engine.arena(MID, SEQ_DEC).allocs,
            "survivor_live": len(slices[survivor].engine.arena(MID, SEQ_DEC).live),
        }
        parked_now = cluster.fail_slice(dead)
        dead_eng = slices[dead].engine
        dead_arena = dead_eng.arena(MID, SEQ_DEC)
        after_fail = {
            "dead_stats": dict(dead_eng.stats),
            "dead_live": tuple(dead_arena.live),
            "dead_counters": (dead_arena.allocs, dead_arena.resets),
            "survivor_live": len(slices[survivor].engine.arena(MID, SEQ_DEC).live),
        }
        cluster.run()  # drain everything
        return dict(
            cluster=cluster, slices=slices, dead=dead, survivor=survivor,
            victims=victims, parked_now=parked_now, at_failure=at_failure,
            after_fail=after_fail,
        )

    def test_every_inflight_request_accounted(self, scenario):
        cluster = scenario["cluster"]
        for rid in scenario["victims"]:
            # Each victim must appear in exactly one ledger: rerouted
            # (failover_map -> tail id, immediately or via the parked
            # retry queue), expired while parked (failover_map -> None,
            # rid in parked_expired), or finished arriving pre-failure.
            in_map = rid in cluster.failover_map
            finished = rid in cluster.finished_with_slice
            assert in_map or finished, (
                f"request {rid} silently dropped by failover"
            )
            assert not (in_map and finished)
            if in_map and cluster.failover_map[rid] is None:
                assert rid in cluster.parked_expired
            assert rid not in cluster.placement  # no longer on the dead slice
        # After the drain every parked tail has resolved one way:
        assert cluster.parked == {}
        assert len(cluster.parked_admitted) + len(cluster.parked_expired) == len(
            scenario["parked_now"]
        )

    def test_conservation_across_failover(self, scenario):
        # completed + shed + lost == ingested even though a slice died
        # mid-decode with frames in its pipeline.
        agg = scenario["cluster"].aggregate_metrics()
        assert (
            agg["completed_frames"] + agg["dropped_frames"] + agg["lost_frames"]
            == agg["ingested_frames"]
        ), agg
        assert agg["lost_frames"] > 0  # the dead pipeline was reconciled

    def test_rerouted_tails_land_on_survivor_arena(self, scenario):
        cluster = scenario["cluster"]
        tails = [t for t in cluster.failover_map.values() if t is not None]
        assert tails, "at least one tail must re-admit"
        for tail_rid in tails:
            assert cluster.placement[tail_rid] == scenario["survivor"]
        # Re-admission LEASED rows on the survivor's existing arena:
        grew = (
            scenario["after_fail"]["survivor_live"]
            - scenario["at_failure"]["survivor_live"]
        )
        assert grew == len(tails)
        assert cluster.reroutes == len(tails)

    def test_dead_slice_arena_never_touched_again(self, scenario):
        dead_eng = scenario["slices"][scenario["dead"]].engine
        assert dead_eng.frozen
        arena = dead_eng.arena(MID, SEQ_DEC)
        # Counters and live-row set identical after the full drain:
        assert dict(dead_eng.stats) == scenario["after_fail"]["dead_stats"]
        assert tuple(arena.live) == scenario["after_fail"]["dead_live"]
        assert (arena.allocs, arena.resets) == (
            scenario["after_fail"]["dead_counters"]
        )
        # The victims' rows are still held exactly as the failure left them.
        assert scenario["after_fail"]["dead_live"], "victims held leased rows"

    def test_survivor_has_zero_decode_recompiles(self, scenario):
        surv_eng = scenario["slices"][scenario["survivor"]].engine
        assert surv_eng.stats["decode_compiles"] == 0
        assert surv_eng.stats["dispatches"] > 0  # it did serve

    def test_serving_continues_after_failure(self, scenario):
        cluster = scenario["cluster"]
        agg = cluster.aggregate_metrics()
        assert agg["completed_frames"] > scenario["at_failure"]["completed"]
        assert agg["miss_rate"] < 1.0

    def test_leases_released_when_streams_drain(self, scenario):
        surv_eng = scenario["slices"][scenario["survivor"]].engine
        arena = surv_eng.arena(MID, SEQ_DEC)
        assert tuple(arena.live) == ()  # all rows recycled to the allocator
        surv = scenario["slices"][scenario["survivor"]]
        assert surv.leases == {}


# ---------------------------------------------------------------------------
# Per-slice arena isolation
# ---------------------------------------------------------------------------
class TestArenaIsolation:
    @pytest.fixture(scope="class")
    def pair(self):
        cluster, slices = make_cluster(2)
        return cluster, slices["s0"], slices["s1"]

    def test_same_category_distinct_arena_buffers(self, pair):
        _cluster, s0, s1 = pair
        a0 = s0.engine.arena(MID, SEQ_DEC)
        a1 = s1.engine.arena(MID, SEQ_DEC)
        assert a0 is not a1
        ids0 = {id(leaf) for leaf in jax.tree_util.tree_leaves(a0.cache)}
        ids1 = {id(leaf) for leaf in jax.tree_util.tree_leaves(a1.cache)}
        assert ids0.isdisjoint(ids1)

    def test_decode_churn_on_one_slice_never_recompiles_the_other(self, pair):
        _cluster, s0, s1 = pair
        before0 = s0.engine.stats["decode_compiles"]
        before1 = s1.engine.stats["decode_compiles"]
        # s1 opens a brand-new decode seq and churns batch sizes across it.
        for b in (1, 2, 1, 2, 1):
            s1.engine.execute(MID, (10,), b, kind="decode")
        assert s1.engine.stats["decode_compiles"] == before1 + 1  # one program
        assert s0.engine.stats["decode_compiles"] == before0
        assert (MID, 10) not in s0.engine._arenas  # no cross-slice arena

    def test_prefill_compiles_are_per_slice(self, pair):
        _cluster, s0, s1 = pair
        before0 = s0.engine.stats["prefill_compiles"]
        s1.engine.execute(MID, (SEQ_PRE,), 3, kind="prefill")  # new bucket 4
        assert s1.engine.stats["prefill_compiles"] >= 1
        assert s0.engine.stats["prefill_compiles"] == before0

    def test_steady_slice_buffers_stable_under_neighbor_churn(self, pair):
        _cluster, s0, s1 = pair
        a0 = s0.engine.arena(MID, SEQ_DEC)
        ids_before = [id(leaf) for leaf in jax.tree_util.tree_leaves(a0.cache)]
        for b in (1, 2, 1):
            s1.engine.execute(MID, (SEQ_DEC,), b, kind="decode")
        a0_after = s0.engine.arena(MID, SEQ_DEC)
        assert a0_after is a0
        ids_after = [id(leaf) for leaf in jax.tree_util.tree_leaves(a0.cache)]
        assert ids_after == ids_before
        assert s0.engine.stats["decode_compiles"] == 0


# ---------------------------------------------------------------------------
# Live watchdog: a wedged step quarantines its slice with no operator call
# ---------------------------------------------------------------------------
class TestLiveWatchdogQuarantine:
    @pytest.fixture(scope="class")
    def chaos(self):
        from repro.configs.registry import tiny
        from repro.core import (
            FaultPlan,
            FaultSpec,
            STALL,
            WatchdogConfig,
        )
        from repro.ingest.session import IngestGateway
        from repro.ingest.sources import CameraSource

        wd = WatchdogConfig(
            slack=3.0, hang_slack=9.0, min_deadline=0.05,
            suspect_after=2, quarantine_after=4,
        )
        plans = {"s0": FaultPlan((FaultSpec(STALL, 2),))}
        configs = {MID: tiny(MID)}
        cats = [(MID, (SEQ_PRE,), "prefill"), (MID, (SEQ_DEC,), "decode")]
        cluster, slices = build_live_cluster(
            configs, cats, slice_names=("s0", "s1"), batch_sizes=(1, 2),
            profile_runs=2, nonrt_cap=1, watchdog=wd, fault_plans=plans,
        )
        gw = IngestGateway(cluster)
        sessions = [
            gw.register(
                CameraSource(period=0.2, n_frames=8, payload_shape=(), seed=40 + i),
                DEC_CAT, relative_deadline=0.4,
            )
            for i in range(3)
        ]
        assert all(s.state == "active" for s in sessions)
        cluster.run()  # no operator intervention from here on
        return cluster, slices, gw, sessions

    def test_stalled_slice_auto_quarantined(self, chaos):
        from repro.core import QUARANTINED

        cluster, slices, _, _ = chaos
        assert slices["s0"].health == QUARANTINED
        assert not slices["s0"].alive
        reasons = [r for _, name, _, new, r in cluster.health.transitions
                   if name == "s0" and new == QUARANTINED]
        assert reasons and "hung" in reasons[0]

    def test_wedged_waiter_abandoned_not_inherited(self, chaos):
        _, slices, _, _ = chaos
        # The slice's device is the FaultyDevice wrapper; the REAL waiter
        # thread underneath wedged inside the injected handle and close()
        # had to abandon it with the join timeout.
        inner = slices["s0"].device.inner
        assert isinstance(inner, AsyncDevice)
        assert inner.wedged
        assert inner.closed

    def test_dead_slice_sessions_moved_to_failover(self, chaos):
        _, _, _, sessions = chaos
        states = {s.slice_name: [] for s in sessions}
        for s in sessions:
            states[s.slice_name].append(s.state)
        assert all(st == "failover" for st in states.get("s0", [])), states
        assert all(st == "active" for name, lst in states.items()
                   if name != "s0" for st in lst), states
        assert any(s.slice_name == "s0" for s in sessions)  # non-vacuous
        assert all(s.conserved() for s in sessions)

    def test_victims_accounted_and_parked_resolved(self, chaos):
        cluster, _, _, _ = chaos
        # Every request the dead slice held resolved into one ledger and
        # none still claims placement there.
        assert list(cluster.failover_map) + cluster.finished_with_slice
        assert all(name != "s0" for name in cluster.placement.values())
        assert cluster.parked == {}
        for rid, tail in cluster.failover_map.items():
            if tail is None:
                assert rid in cluster.parked_expired

    def test_conservation_across_live_quarantine(self, chaos):
        cluster, _, _, _ = chaos
        agg = cluster.aggregate_metrics()
        assert (
            agg["completed_frames"] + agg["dropped_frames"] + agg["lost_frames"]
            == agg["ingested_frames"]
        ), agg

    def test_survivor_zero_decode_recompiles_and_rows_recycled(self, chaos):
        _, slices, _, _ = chaos
        surv = slices["s1"]
        assert surv.engine.stats["decode_compiles"] == 0
        assert surv.leases == {}
        arena = surv.engine.arena(MID, SEQ_DEC)
        assert len(arena.free) == arena.max_slots
