"""Pod-scale DeepRT: multi-slice cluster with failures, stragglers, and
elastic re-admission (DESIGN.md §5 / core/cluster.py).

Four slices serve a multi-tenant trace; mid-run one slice fails (its
requests re-admit elsewhere) and another degrades 3x (its WCET table
rescales, future admissions see the reduced capacity; in-flight overruns
drain through the paper's adaptation machinery).

    PYTHONPATH=src python examples/cluster_sim.py
"""
from repro.core import (
    ClusterScheduler,
    ExecutionModel,
    SliceSpec,
    TraceSpec,
    generate_trace,
)
from benchmarks.common import paper_table

cluster = ClusterScheduler(
    execution=ExecutionModel(actual_fn=lambda j, w: 0.95 * w)
)
for i in range(4):
    cluster.add_slice(SliceSpec(name=f"slice{i}", table=paper_table()))

trace = generate_trace(
    TraceSpec(
        mean_period=0.1,
        mean_deadline=0.25,
        n_requests=40,
        frames_per_request=(200, 400),
        models=("resnet50", "resnet101", "vgg16", "mobilenet_v2"),
        shapes=((3, 224, 224), (3, 240, 352)),
        seed=11,
        mean_interarrival=0.2,
    )
)
placed = sum(cluster.submit_request(r) for r in trace)
print(f"placed {placed}/{len(trace)} requests across 4 slices")
print({name: sum(1 for s in cluster.placement.values() if s == name)
       for name in cluster.slices})

cluster.run(until=5.0)
print("\nt=5s: slice0 FAILS (node loss) — re-admitting its requests...")
lost = cluster.fail_slice("slice0")
print(f"  re-routed {cluster.reroutes}, shed {len(lost)} (admission-protected)")

cluster.run(until=8.0)
print("t=8s: slice1 degrades 3x (straggler) — future admissions rescaled")
cluster.mark_slow("slice1", 3.0)

cluster.run()
agg = cluster.aggregate_metrics()
print(
    f"\nfinal: completed={agg['completed_frames']} "
    f"missed={agg['missed_frames']} (miss rate {agg['miss_rate']:.2%}) "
    f"rerouted={agg['reroutes']} dropped={agg['dropped_requests']}"
)
