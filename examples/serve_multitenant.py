"""End-to-end driver: multi-tenant LIVE serving with real JAX execution.

Two reduced LM architectures share one device. The engine compiles
batched prefill steps, the offline profiler (paper §4.1) measures WCETs,
and DeepRT schedules actual jit-compiled executions on a wall clock —
admission control included. Dispatch is asynchronous (zero-stall): the
scheduler loop keeps batching/admitting while XLA executes, and the
footer reports how little host time each job dispatch cost. A
BATCH(Triton-style) baseline runs the same accepted trace for
comparison.

With ``--slices N`` (N > 1) the same workload runs on a LIVE CLUSTER
(``build_live_cluster``): N slices on one wall clock, each owning its
own engine / resident arenas / AsyncDevice / WCET table; placement
routes each request to the lowest-utilization capable slice and
admission on that slice decides finally (spill-on-reject).

With ``--source camera|burst|trace`` the demo streams REAL payload
bytes through the ingest gateway (``repro.ingest``): every frame
carries tokens produced by a jittery camera, a bursty WebRTC-like
source, or a trace replay, deadline-stamped at arrival, staged through
the engine's double-buffered rings, with adaptation-driven load
shedding accounted in the metrics.

With ``--transport`` the cluster additionally sits behind the NETWORK
front door (``repro.ingest.transport``): each stream becomes a
datagram client behind a seed-derived chaotic link (drops, duplicates,
reordering, delay), reassembled in order at the server, with
credit-based backpressure signaled back to the client and session
re-homing armed for slice failover.

    PYTHONPATH=src python examples/serve_multitenant.py [--requests 8]
    PYTHONPATH=src python examples/serve_multitenant.py --slices 2
    PYTHONPATH=src python examples/serve_multitenant.py --slices 2 --source camera
    PYTHONPATH=src python examples/serve_multitenant.py --slices 2 --transport
"""
import argparse
import copy
import json
import sys

from repro.configs.registry import tiny
from repro.core import (
    BATCH,
    Category,
    EventLoop,
    FrameTracer,
    TraceSpec,
    generate_trace,
)
from repro.ingest import (
    BurstSource,
    CameraSource,
    IngestGateway,
    LinkPlan,
    SimLink,
    TraceSource,
    TransportSource,
)
from repro.serving.batcher_bridge import (
    build_live_cluster,
    build_live_scheduler,
    build_live_transport,
)

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--seq", type=int, default=48)
ap.add_argument("--frames", type=int, default=15)
ap.add_argument("--slices", type=int, default=1,
                help="N > 1 serves through a live multi-slice cluster")
ap.add_argument("--source", choices=("camera", "burst", "trace"), default=None,
                help="stream real payload bytes through the ingest gateway")
ap.add_argument("--transport", action="store_true",
                help="serve through the network front door: chaotic link, "
                     "reassembly, client backpressure (implies a cluster)")
ap.add_argument("--chaos-seed", type=int, default=7,
                help="seed for the per-stream LinkPlan (--transport)")
ap.add_argument("--trace", metavar="PATH", default=None,
                help="dump the frame-lifecycle trace as Chrome "
                     "trace_event JSON (load via chrome://tracing or "
                     "https://ui.perfetto.dev)")
args = ap.parse_args()

# One tracer spans whatever topology the flags select — wire receive,
# gateway shed verdicts, window closes, EDF dispatch, completions.
TRACER = FrameTracer() if args.trace else None


def dump_trace() -> None:
    if TRACER is None:
        return
    TRACER.dump_chrome_trace(args.trace)
    snap = TRACER.snapshot()
    print(f"trace  : {snap['events']} spans ({snap['emitted']} emitted, "
          f"{snap['evicted']} evicted) -> {args.trace}")

arch_ids = ["granite-3-2b", "rwkv6-1.6b"]
configs = {a: tiny(a) for a in arch_ids}
categories = [(a, (args.seq,), "prefill") for a in arch_ids]


def make_trace():
    spec = TraceSpec(
        mean_period=0.3,
        mean_deadline=0.6,
        n_requests=args.requests,
        frames_per_request=(args.frames, args.frames),
        models=tuple(arch_ids),
        shapes=((args.seq,),),
        seed=3,
    )
    return generate_trace(spec)


def make_sources():
    """One payload-carrying source per request slot (--source mode)."""
    if args.source == "trace":
        spec = TraceSpec(
            mean_period=0.3, mean_deadline=0.6, n_requests=args.requests,
            frames_per_request=(args.frames, args.frames),
            models=tuple(arch_ids), shapes=((args.seq,),), seed=3,
        )
        return [
            (req.category, req.relative_deadline, src)
            for req, src in TraceSource.from_trace(spec, payload_shape=(args.seq,))
        ]
    out = []
    for i in range(args.requests):
        cat = Category(arch_ids[i % len(arch_ids)], (args.seq,))
        if args.source == "camera":
            src = CameraSource(period=0.3, n_frames=args.frames,
                               jitter_frac=0.3, payload_shape=(args.seq,),
                               seed=i)
        else:  # burst: same declared rate, delivered 2x in bursts
            src = BurstSource(period=0.3, n_frames=args.frames, burst=4,
                              duty=0.5, payload_shape=(args.seq,), seed=i)
        out.append((cat, 0.6, src))
    return out


def serve_ingest(target, engines):
    """Stream real payloads through the gateway over ``target`` (a live
    DeepRT or a ClusterScheduler); print the ingest scorecard."""
    gw = IngestGateway(target)
    gw.tracer = TRACER
    sessions = []
    for cat, deadline, src in make_sources():
        s = gw.register(src, cat, relative_deadline=deadline)
        where = f" @{s.slice_name}" if s.slice_name else ""
        print(f"stream {s.request_id} ({cat}): "
              f"{'ADMIT' + where if s.state == 'active' else 'REJECT'}")
        sessions.append(s)
    print(f"\nserving live --source {args.source} "
          f"(payload bytes staged per step, zero-stall)...")
    target.run()
    active = [s for s in sessions if s.state == "active"]
    ingested = sum(s.frames_ingested for s in active)
    delivered = sum(s.frames_delivered for s in active)
    dropped = sum(s.frames_dropped for s in active)
    print(f"ingest : streams={len(active)}/{len(sessions)} "
          f"ingested={ingested} delivered={delivered} shed={dropped} "
          f"(conserved={all(s.conserved() for s in sessions)})")
    for name, eng in engines.items():
        fills = eng.staging_fills
        bps = eng.staging_bytes / fills if fills else 0.0
        print(f"  {name}: staged {eng.staging_bytes}B over {fills} steps "
              f"({bps:.0f} B/step), host_allocs={eng.staging_host_allocs}, "
              f"decode_compiles={eng.stats['decode_compiles']}")


def serve_transport():
    """--transport: the full networked path. Every stream is a datagram
    client behind its own seed-derived chaotic link; the server
    reassembles, backpressures, and (if a slice dies) re-homes."""
    n_slices = max(2, args.slices)
    print(f"compiling + profiling {n_slices} slices (per-slice §4.1 pass)...")
    cluster, slices, _gateway, transport, _binding = build_live_transport(
        configs, categories,
        slice_names=tuple(f"slice{i}" for i in range(n_slices)),
        record_payloads=False,
        tracer=TRACER,
    )
    loop = cluster.loop
    clients, links = [], []
    for i, (cat, deadline, src) in enumerate(make_sources()):
        plan = LinkPlan.from_seed(
            args.chaos_seed + i, src.n_frames * 4,
            p_drop=0.05, p_dup=0.05, p_reorder=0.08, p_delay=0.05,
            reorder_hold=(0.05, 0.2),
        )
        link = SimLink(loop, transport.datagram, plan=plan)
        client = TransportSource(src, cat, deadline, link)
        ok = client.start(transport)
        ts = transport.sessions.get(client.sid)
        where = f" @{ts.session.slice_name}" if ok else ""
        print(f"stream {client.sid} ({cat}): "
              f"{'ADMIT' + where if ok else 'REJECT'}")
        clients.append(client)
        links.append(link)
    print("\nserving through the chaotic link (wall clock, zero-stall)...")
    cluster.run()
    transport.finalize_all()
    cluster.run(until=loop.now + 0.5)
    snap = json.loads(transport.status_json())
    print(f"link   : sends={sum(l.sends for l in links)} "
          f"dropped={sum(l.dropped for l in links)} "
          f"duplicated={sum(l.duplicated for l in links)} "
          f"reordered={sum(l.reordered for l in links)} "
          f"delayed={sum(l.delayed for l in links)}")
    for sid, sess in sorted(snap["sessions"].items(), key=lambda kv: int(kv[0])):
        w = sess["wire"]
        print(f"  session {sid} @{sess['slice']}: received={w['received']} "
              f"delivered={w['delivered']} dup={w['duplicates']} "
              f"lost={w['net_lost']} late={w['late_rejected']} "
              f"credit={sess['credit']:.2f} downshifts={sess['downshifts']} "
              f"conserved={w['conserved']}")
    agg = cluster.aggregate_metrics()
    print(f"cluster: completed={agg['completed_frames']} "
          f"missed={agg['missed_frames']} ({agg['miss_rate']:.1%}) "
          f"shed={agg['dropped_frames']} lost={agg['lost_frames']} "
          f"conserved={agg['completed_frames'] + agg['dropped_frames'] + agg['lost_frames'] == agg['ingested_frames']}")
    for name, sl in slices.items():
        print(f"  {name}: decode_compiles={sl.engine.stats['decode_compiles']} "
              f"device_busy={sl.device.busy_time:.2f}s")
    dump_trace()


if args.transport:
    if args.source is None:
        args.source = "camera"  # transport clients need payload sources
    serve_transport()
    sys.exit(0)

if args.slices > 1:
    print(f"compiling + profiling {args.slices} slices (per-slice §4.1 pass)...")
    cluster, slices = build_live_cluster(
        configs, categories,
        slice_names=tuple(f"slice{i}" for i in range(args.slices)),
        tracer=TRACER,
    )
    if args.source:
        serve_ingest(cluster, {n: sl.engine for n, sl in slices.items()})
        agg = cluster.aggregate_metrics()
        print(f"cluster: completed={agg['completed_frames']} "
              f"missed={agg['missed_frames']} ({agg['miss_rate']:.1%}) "
              f"shed={agg['dropped_frames']} "
              f"e2e={agg['mean_e2e_latency']*1e3:.1f}ms")
        dump_trace()
        sys.exit(0)
    for r in make_trace():
        r.start_time = 0.0
        ok = cluster.submit_request(r)
        where = cluster.placement.get(r.request_id, "-")
        print(f"request {r.request_id} ({r.category}): "
              f"{'ADMIT @' + where if ok else 'REJECT (all slices)'}")
    print("\nserving live across slices (one wall clock, zero-stall)...")
    cluster.run()
    agg = cluster.aggregate_metrics()
    print(f"cluster: completed={agg['completed_frames']} "
          f"missed={agg['missed_frames']} ({agg['miss_rate']:.1%}) "
          f"jobs={agg['jobs']} dropped={agg['dropped_requests']}")
    for name, sl in slices.items():
        m = sl.scheduler.metrics
        st = sl.engine.stats
        print(f"  {name}: frames={m.completed_frames} "
              f"decode_compiles={st['decode_compiles']} "
              f"prefill_compiles={st['prefill_compiles']} "
              f"device_busy={sl.device.busy_time:.2f}s")
    dump_trace()
    sys.exit(0)

print("compiling + profiling engine (paper §4.1 offline pass)...")
sched, engine, table = build_live_scheduler(configs, categories,
                                            tracer=TRACER)

if args.source:
    serve_ingest(sched, {"device0": engine})
    m = sched.metrics
    print(f"DeepRT : completed={m.completed_frames} missed={m.missed_frames} "
          f"({m.miss_rate:.1%}) shed={m.dropped_frames} "
          f"e2e={m.mean_e2e_latency*1e3:.1f}ms "
          f"sched-latency={m.mean_latency*1e3:.1f}ms")
    dump_trace()
    sys.exit(0)
for (mid, shape), batches in sorted(
    ((k, v) for k, v in table.entries.items()), key=lambda kv: kv[0]
):
    b1 = batches.get(1)
    b8 = batches.get(8)
    print(f"  {mid} shape={shape}: E(1)={b1*1e3:.1f}ms E(8)={b8*1e3:.1f}ms")

trace = make_trace()
accepted = []
for r in trace:
    r.start_time = 0.0
    res = sched.submit_request(r)
    print(
        f"request {r.request_id} ({r.category}): "
        f"{'ADMIT' if res.admitted else 'REJECT'} (U={res.utilization:.2f})"
    )
    if res.admitted:
        accepted.append(copy.deepcopy(r))

print("\nserving live (wall clock, async zero-stall dispatch)...")
m = sched.run()
print(
    f"DeepRT : completed={m.completed_frames} missed={m.missed_frames} "
    f"({m.miss_rate:.1%}) jobs={m.job_count} mean_batch={m.mean_batch:.2f}"
)
print(
    f"         host stall/job={m.mean_dispatch_overhead*1e6:.0f}us "
    f"padding_waste={m.padding_waste:.1%} "
    f"device_busy={sched.device.busy_time:.2f}s"
)

# Baseline on the same accepted trace, simulated with the measured table.
base = BATCH(table, loop=EventLoop(), batch_size=4)
for r in accepted:
    base.submit_request(copy.deepcopy(r))
bm = base.run()
print(
    f"BATCH-4: completed={bm.completed_frames} missed={bm.missed_frames} "
    f"({bm.miss_rate:.1%}) jobs={bm.job_count} mean_batch={bm.mean_batch:.2f}"
)
dump_trace()
