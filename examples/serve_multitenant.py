"""End-to-end driver: multi-tenant LIVE serving with real JAX execution.

Two reduced LM architectures share one device. The engine compiles
batched prefill steps, the offline profiler (paper §4.1) measures WCETs,
and DeepRT schedules actual jit-compiled executions on a wall clock —
admission control included. Dispatch is asynchronous (zero-stall): the
scheduler loop keeps batching/admitting while XLA executes, and the
footer reports how little host time each job dispatch cost. A
BATCH(Triton-style) baseline runs the same accepted trace for
comparison.

    PYTHONPATH=src python examples/serve_multitenant.py [--requests 8]
"""
import argparse
import copy

from repro.configs.registry import tiny
from repro.core import BATCH, EventLoop, TraceSpec, generate_trace
from repro.serving.batcher_bridge import build_live_scheduler

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--seq", type=int, default=48)
ap.add_argument("--frames", type=int, default=15)
args = ap.parse_args()

arch_ids = ["granite-3-2b", "rwkv6-1.6b"]
configs = {a: tiny(a) for a in arch_ids}
categories = [(a, (args.seq,), "prefill") for a in arch_ids]

print("compiling + profiling engine (paper §4.1 offline pass)...")
sched, engine, table = build_live_scheduler(configs, categories)
for (mid, shape), batches in sorted(
    ((k, v) for k, v in table.entries.items()), key=lambda kv: kv[0]
):
    b1 = batches.get(1)
    b8 = batches.get(8)
    print(f"  {mid} shape={shape}: E(1)={b1*1e3:.1f}ms E(8)={b8*1e3:.1f}ms")

spec = TraceSpec(
    mean_period=0.3,
    mean_deadline=0.6,
    n_requests=args.requests,
    frames_per_request=(args.frames, args.frames),
    models=tuple(arch_ids),
    shapes=((args.seq,),),
    seed=3,
)
trace = generate_trace(spec)
accepted = []
for r in trace:
    r.start_time = 0.0
    res = sched.submit_request(r)
    print(
        f"request {r.request_id} ({r.category}): "
        f"{'ADMIT' if res.admitted else 'REJECT'} (U={res.utilization:.2f})"
    )
    if res.admitted:
        accepted.append(copy.deepcopy(r))

print("\nserving live (wall clock, async zero-stall dispatch)...")
m = sched.run()
print(
    f"DeepRT : completed={m.completed_frames} missed={m.missed_frames} "
    f"({m.miss_rate:.1%}) jobs={m.job_count} mean_batch={m.mean_batch:.2f}"
)
print(
    f"         host stall/job={m.mean_dispatch_overhead*1e6:.0f}us "
    f"padding_waste={m.padding_waste:.1%} "
    f"device_busy={sched.device.busy_time:.2f}s"
)

# Baseline on the same accepted trace, simulated with the measured table.
base = BATCH(table, loop=EventLoop(), batch_size=4)
for r in accepted:
    base.submit_request(copy.deepcopy(r))
bm = base.run()
print(
    f"BATCH-4: completed={bm.completed_frames} missed={bm.missed_frames} "
    f"({bm.miss_rate:.1%}) jobs={bm.job_count} mean_batch={bm.mean_batch:.2f}"
)
