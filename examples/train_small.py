"""Train a small LM for a few hundred steps with the full substrate:
sharded train step (rules engine on the host mesh), AdamW, synthetic
Zipf data pipeline, async checkpointing, crash-resume drill.

    PYTHONPATH=src python examples/train_small.py --steps 200
    # Fault-tolerance drill:
    PYTHONPATH=src python examples/train_small.py --steps 200 --fail-at 120
    PYTHONPATH=src python examples/train_small.py --steps 200   # resumes
"""
import subprocess
import sys

if __name__ == "__main__":
    args = sys.argv[1:] or ["--steps", "200"]
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "granite-3-2b", "--tiny",
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_small",
        *args,
    ]
    raise SystemExit(subprocess.call(cmd))
