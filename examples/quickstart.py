"""Quickstart: the DeepRT scheduler in 40 lines.

Builds a WCET profile table, admits a few periodic inference requests
through the two-phase Admission Control Module, and runs the DisBatcher
+ EDF pipeline on the virtual clock.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Category, DeepRT, ProfileTable, Request

# 1. Profile table (paper §4.1): (model, shape, batch) -> worst-case secs.
table = ProfileTable()
for batch in [1, 2, 4, 8, 16, 32]:
    table.record("resnet50", (3, 224, 224), batch, 0.0035 * (1 + 0.35 * (batch - 1)))
    table.record("resnet50", (3, 112, 112), batch, 0.0012 * (1 + 0.35 * (batch - 1)))

# 2. The scheduler: DisBatcher + EDF worker + admission + adaptation.
sched = DeepRT(table)

# 3. Clients submit periodic soft real-time requests.
cat = Category(model_id="resnet50", shape_key=(3, 224, 224))
for i, (period, deadline) in enumerate([(0.033, 0.1), (0.05, 0.08), (0.02, 0.15)]):
    req = Request(category=cat, period=period, relative_deadline=deadline, n_frames=90)
    result = sched.submit_request(req)
    print(
        f"request {i}: period={period*1e3:.0f}ms deadline={deadline*1e3:.0f}ms -> "
        f"{'ADMITTED' if result.admitted else 'REJECTED'} "
        f"(phase {result.phase}, utilization {result.utilization:.2f})"
    )

# 4. Run to completion (virtual time) and inspect the guarantees.
metrics = sched.run()
print(
    f"\ncompleted={metrics.completed_frames} frames, "
    f"missed={metrics.missed_frames} deadlines "
    f"({metrics.miss_rate:.1%} miss rate)\n"
    f"jobs executed={metrics.job_count}, mean batch={metrics.mean_batch:.2f}, "
    f"throughput={metrics.throughput:.1f} frames/s"
)
assert metrics.missed_frames == 0, "admitted requests must meet deadlines"
print("Theorem 1 held: every admitted frame met its deadline.")
