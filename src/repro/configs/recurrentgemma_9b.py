"""RecurrentGemma-9B [arXiv:2402.19427 Griffin]: RG-LRU recurrent blocks
with local attention, 1 attention : 2 recurrent, MQA (kv=1), window 2048."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    block_pattern=("rglru", "rglru", "swa"),
    sliding_window=2048,
    d_rnn=4096,
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2402.19427 (unverified tier)",
)
