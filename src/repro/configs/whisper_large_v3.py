"""Whisper large-v3 [arXiv:2212.04356]: encoder-decoder, 32+32 layers,
LayerNorm + GELU + attention biases, MHA (kv = heads = 20). The conv/mel
frontend is a STUB per the assignment: input_specs() provides precomputed
frame embeddings (B, T, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    n_layers=32,  # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    activation="gelu",
    norm="layernorm",
    rope_kind="none",
    block_pattern=("attn",),
    encdec=True,
    attn_bias=True,
    tie_embeddings=True,
    source="arXiv:2212.04356 (unverified tier)",
)
