"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4 family]: MoE with 128
routed experts, top-1 routing + shared expert, GQA kv=8. The multimodal
early-fusion frontend is out of scope (text backbone per assignment)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
    block_pattern=("attn",),
    n_experts=128,
    top_k=1,
    shared_expert=True,
    tie_embeddings=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E scaled per assignment (unverified tier)",
)
