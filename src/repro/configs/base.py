"""ModelConfig: the single description every subsystem consumes.

``block_pattern`` is the repeating unit of layer kinds; it tiles to
``n_layers`` (a non-divisible remainder becomes unscanned tail layers).
Kinds: ``attn`` (global attention), ``swa`` (sliding window), ``rglru``
(Griffin recurrent block), ``rwkv`` (RWKV-6 time-mix block).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 500000.0
    rope_kind: str = "rope"  # rope | mrope | none
    block_pattern: Tuple[str, ...] = ("attn",)
    sliding_window: int = 4096
    d_rnn: Optional[int] = None  # Griffin recurrent width
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    # Dense MoE: every token through every expert (no dispatch). Viable
    # for small expert counts (mixtral: 4x active FLOPs) where the
    # dispatch collectives cost far more than the extra compute — the
    # training-side workaround for the shard_map-grad XLA limitation.
    moe_dense: bool = False
    # Encoder-decoder (whisper)
    encdec: bool = False
    n_encoder_layers: int = 0
    max_dec_positions: int = 65536  # learned decoder positions (whisper)
    attn_bias: bool = False
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma-style sqrt(d_model) input scaling
    # Compilation / runtime
    scan_layers: bool = True
    impl: str = "xla"  # xla | pallas | dense
    param_dtype: str = "bfloat16"
    remat: bool = True  # activation checkpointing over layer blocks
    # Notes for DESIGN/EXPERIMENTS provenance
    source: str = ""

    # ----- derived -------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def pattern_layers(self) -> Tuple[str, ...]:
        reps = self.n_layers // len(self.block_pattern)
        tail = self.n_layers - reps * len(self.block_pattern)
        return self.block_pattern * reps + self.block_pattern[:tail]

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        reps = self.n_super
        return self.pattern_layers[reps * len(self.block_pattern):]

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.param_dtype]

    @property
    def attention_free(self) -> bool:
        return all(k in ("rglru", "rwkv") for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no block kind does full-length global attention, or
        global layers are rare enough that a 500k cache is bounded for the
        majority of layers (gemma3's 5:1 local:global still qualifies for
        the long_500k decode shape per DESIGN.md §4)."""
        kinds = set(self.pattern_layers)
        if "attn" not in kinds:
            return True
        # Hybrid local:global with at most 1 global per pattern unit.
        return (
            self.block_pattern.count("attn") <= 1 and len(self.block_pattern) >= 3
        )

    def param_count_estimate(self) -> int:
        """Rough parameter count (for 6ND model-FLOPs accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        out = v * d if self.tie_embeddings else 2 * v * d
        total = out
        for kind in self.pattern_layers:
            if kind in ("attn", "swa"):
                total += attn
            elif kind == "rglru":
                dr = self.d_rnn or d
                total += 2 * d * dr + 2 * dr * dr + dr * d
            elif kind == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,g,o
            if self.is_moe and kind in ("attn", "swa"):
                total += 3 * self.n_experts * d * f
                if self.shared_expert:
                    total += 3 * d * f
            elif kind == "rwkv":
                total += 2 * d * f + d * d  # channel mix
            else:
                total += (3 if self.activation in ("swiglu", "geglu") else 2) * d * f
        if self.encdec:
            # encoder layers: attn + mlp (+ cross-attn on decoder side
            # already counted via pattern_layers = decoder layers)
            enc = self.n_encoder_layers * (attn + 2 * d * f)
            cross = self.n_layers * attn
            total += enc + cross
        return int(total)

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count_estimate()
        d, f = self.d_model, self.d_ff
        total = self.param_count_estimate()
        moe_layers = sum(1 for k in self.pattern_layers if k in ("attn", "swa"))
        inactive = 3 * (self.n_experts - self.top_k) * d * f * moe_layers
        return int(total - inactive)
