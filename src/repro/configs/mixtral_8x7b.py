"""Mixtral 8x7B [arXiv:2401.04088]: 8 experts top-2, GQA kv=8, sliding-
window attention (Mistral lineage, 4096 window)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    block_pattern=("swa",),
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    shared_expert=False,
    tie_embeddings=False,
    source="arXiv:2401.04088 (hf tier)",
)
