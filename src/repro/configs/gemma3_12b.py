"""Gemma-3 12B [hf:google/gemma-3 family]: dense, 5 local : 1 global
attention pattern, 1024-token sliding window on locals, 262k vocab,
GeGLU + sqrt(d) embedding scaling (gemma lineage)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    block_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
    sliding_window=1024,
    tie_embeddings=True,
    embed_scale=True,
    source="hf:google/gemma-3-1b-pt scaled per assignment (unverified tier)",
)
