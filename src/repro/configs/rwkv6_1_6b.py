"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892]: attention-free, data-dependent
decay time-mix + channel-mix, O(1) decode state."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # wkv heads (head_dim 64)
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    activation="gelu",  # channel-mix uses its own relu^2; unused elsewhere
    norm="layernorm",
    rope_kind="none",
    block_pattern=("rwkv",),
    tie_embeddings=True,
    source="arXiv:2404.05892 (unverified tier)",
)
