"""Llama-3.1 405B [arXiv:2407.21783]: dense, GQA kv=8, 128k vocab."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
    block_pattern=("attn",),
    tie_embeddings=False,
    source="arXiv:2407.21783 (unverified tier)",
)
