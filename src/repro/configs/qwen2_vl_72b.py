"""Qwen2-VL 72B [arXiv:2409.12191]: VLM text backbone with M-RoPE
(temporal/height/width rotary sections). The dynamic-resolution vision
frontend is a STUB per the assignment: input_specs() provides the token
stream plus precomputed M-RoPE position ids (3, B, S)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    rope_kind="mrope",
    block_pattern=("attn",),
    tie_embeddings=False,
    source="arXiv:2409.12191 (hf tier)",
)
