"""Phi-4-mini 3.8B [arXiv:2412.08905]: dense, RoPE + SwiGLU + GQA, 200k vocab."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    block_pattern=("attn",),
    tie_embeddings=True,
    source="arXiv:2412.08905 (hf tier)",
)
