"""Architecture registry: 10 assigned configs + tiny smoke variants.

Each full config matches the assignment exactly; ``tiny()`` produces a
same-family reduced config (few layers, small width, few experts, small
vocab) for CPU smoke tests. The FULL configs are exercised only by the
dry-run (ShapeDtypeStruct lowering — never allocated).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from repro.configs.base import ModelConfig
from repro.configs.llama3_405b import CONFIG as llama3_405b
from repro.configs.granite_3_2b import CONFIG as granite_3_2b
from repro.configs.phi4_mini_3_8b import CONFIG as phi4_mini_3_8b
from repro.configs.gemma3_12b import CONFIG as gemma3_12b
from repro.configs.llama4_maverick_400b_a17b import CONFIG as llama4_maverick
from repro.configs.mixtral_8x7b import CONFIG as mixtral_8x7b
from repro.configs.recurrentgemma_9b import CONFIG as recurrentgemma_9b
from repro.configs.qwen2_vl_72b import CONFIG as qwen2_vl_72b
from repro.configs.whisper_large_v3 import CONFIG as whisper_large_v3
from repro.configs.rwkv6_1_6b import CONFIG as rwkv6_1_6b

ARCHS: Dict[str, ModelConfig] = {
    c.arch_id: c
    for c in [
        llama3_405b,
        granite_3_2b,
        phi4_mini_3_8b,
        gemma3_12b,
        llama4_maverick,
        mixtral_8x7b,
        recurrentgemma_9b,
        qwen2_vl_72b,
        whisper_large_v3,
        rwkv6_1_6b,
    ]
}


def get_config(arch_id: str, **overrides) -> ModelConfig:
    cfg = ARCHS[arch_id]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def tiny(arch_id: str, **overrides) -> ModelConfig:
    """Same-family reduced config for CPU smoke tests and examples."""
    cfg = ARCHS[arch_id]
    pattern = cfg.block_pattern
    n_layers = max(len(pattern), 2)
    if len(pattern) > 4:  # gemma3's 6-layer pattern: keep one full unit
        n_layers = len(pattern)
    changes = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        d_rnn=64 if cfg.d_rnn else None,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        sliding_window=min(cfg.sliding_window, 16),
        n_encoder_layers=2 if cfg.encdec else 0,
        max_dec_positions=128,
        param_dtype="float32",
        remat=False,
        scan_layers=True,
    )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM pool (seq_len, global_batch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    """Which of the four assigned shapes run for this arch (skips are
    documented in DESIGN.md §4: long_500k only for sub-quadratic archs)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
