import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the
device count on first initialization). 512 host-platform placeholder
devices back both the 16x16 single-pod mesh and the 2x16x16 multi-pod
mesh; programs are lowered and compiled (SPMD, per-device module) but
NEVER executed — inputs are ShapeDtypeStructs, no allocation happens.

Per cell this script records:
  - compiled.memory_analysis()   (per-device argument/output/temp bytes)
  - compiled.cost_analysis()     (per-device FLOPs / bytes accessed)
  - collective bytes parsed from the optimized HLO
  - the three roofline terms + dominant bottleneck (repro.roofline)

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k \
      --opt seq_shard   # named optimization variants (EXPERIMENTS.md §Perf)
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, SHAPES, applicable_shapes, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import model_for
from repro.roofline import analysis as roofline
from repro.training import train_loop

DEC_LEN_TRAIN = 448  # whisper decoder length for the train shape
ENC_LEN_DECODE = 1500  # whisper encoder frames for decode shapes


# ---------------------------------------------------------------------------
# Optimization variants (EXPERIMENTS.md §Perf) — applied as config/rule edits.
# ---------------------------------------------------------------------------


def apply_opt(cfg: ModelConfig, opt: Optional[str]) -> ModelConfig:
    if not opt or opt == "baseline":
        return cfg
    for o in opt.split("+"):
        if o == "no_remat":
            cfg = dataclasses.replace(cfg, remat=False)
        elif o == "remat":
            cfg = dataclasses.replace(cfg, remat=True)
        elif o == "moe_dense":
            cfg = dataclasses.replace(cfg, moe_dense=True)
        elif o in OPT_RULES or o == "moe_local":
            pass  # rule/hook-level variant, applied in run_cell
        else:
            raise ValueError(f"unknown opt variant {o}")
    return cfg


# Named sharding-rule variants (EXPERIMENTS.md §Perf). Composable with
# '+', e.g. --opt kv_replicate+seqpar.
OPT_RULES: Dict[str, Dict[str, Dict]] = {
    # H1: GQA/MHA kv_heads that don't divide the model axis fall back to
    # head_dim sharding in the BASELINE, which shards the attention
    # contraction dim and forces per-layer logits all-reduces. Variant:
    # drop the fallback — replicate indivisible head projections instead.
    "kv_replicate": {"param": {"head_dim": []}},
    # H2: sequence parallelism — activations shard the sequence dim on
    # the model axis (long-prefill archs whose heads can't use it).
    "seqpar": {"act": {"seq": ["model"], "batch": ["pod", "data"]}},
    # H3: decode activations shard d_model on data (batch tiny per step);
    # turns FSDP weight all-gathers into small activation psums.
    "decode_dshard": {
        "act": {"batch": [], "embed": ["data"]},
        "cache": {"batch": ["model", "pod", "data"], "seq": ["data", "pod"]},
    },
    # H4: decode cache sequence sharding on model axis (flash-decoding
    # style distributed softmax).
    "cache_seq_model": {
        "cache": {"batch": ["pod", "data"], "seq": ["model"],
                  "kv_heads": [], "head_dim": []},
    },
}


def opt_rule_context(opt: Optional[str]):
    merged = {"param": {}, "act": {}, "cache": {}}
    if opt:
        for o in opt.split("+"):
            for kind, upd in OPT_RULES.get(o, {}).items():
                merged[kind].update(upd)
    return shd.rule_overrides(
        param=merged["param"], act=merged["act"], cache=merged["cache"]
    )


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins per (arch, shape)
# ---------------------------------------------------------------------------


def input_specs(
    cfg: ModelConfig, shape_name: str, mesh: Mesh
) -> Tuple[Any, Any, Any, Any]:
    """Returns (fn, abstract_args, in_shardings, out_shardings) ready for
    jax.jit(fn, in_shardings=...).lower(*abstract_args)."""
    spec = SHAPES[shape_name]
    model = model_for(cfg)
    S, B = spec.seq_len, spec.global_batch

    def act_sh(shape, axes=None):
        return train_loop.batch_sharding(mesh, shape, axes)

    if spec.kind == "train":
        tcfg = train_loop.TrainConfig()
        step = train_loop.make_train_step(model, tcfg)
        state = train_loop.abstract_state(model)
        state_sh = train_loop.shardings_for_state(model, mesh)
        if cfg.encdec:
            batch = {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype),
                "dec_tokens": jax.ShapeDtypeStruct((B, DEC_LEN_TRAIN), jnp.int32),
            }
            batch_sh = {
                "frames": act_sh((B, S, cfg.d_model), ("batch", "seq", "embed")),
                "dec_tokens": act_sh((B, DEC_LEN_TRAIN)),
            }
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            batch_sh = {"tokens": act_sh((B, S))}
            if cfg.rope_kind == "mrope":
                batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
                batch_sh["positions"] = act_sh((3, B, S), (None, "batch", "seq"))
        return (
            step,
            (state, batch),
            (state_sh, batch_sh),
            (state_sh, None),
        )

    if spec.kind == "prefill":
        params = model.abstract_params()
        params_sh = shd.tree_shardings(params, model.axes(), mesh)
        if cfg.encdec:

            def prefill(params, frames, dec_tokens):
                logits, _ = model.forward(params, frames, dec_tokens)
                return logits[:, -1].argmax(-1)

            args = (
                params,
                jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype),
                jax.ShapeDtypeStruct((B, DEC_LEN_TRAIN), jnp.int32),
            )
            in_sh = (
                params_sh,
                act_sh((B, S, cfg.d_model), ("batch", "seq", "embed")),
                act_sh((B, DEC_LEN_TRAIN)),
            )
            return prefill, args[0:1] + args[1:], in_sh, None
        if cfg.rope_kind == "mrope":

            def prefill(params, tokens, positions):
                logits, _ = model.forward(params, tokens, positions)
                return logits[:, -1].argmax(-1)

            args = (
                params,
                jax.ShapeDtypeStruct((B, S), jnp.int32),
                jax.ShapeDtypeStruct((3, B, S), jnp.int32),
            )
            in_sh = (
                params_sh,
                act_sh((B, S)),
                act_sh((3, B, S), (None, "batch", "seq")),
            )
            return prefill, args, in_sh, None

        def prefill(params, tokens):
            logits, _ = model.forward(params, tokens)
            return logits[:, -1].argmax(-1)

        args = (params, jax.ShapeDtypeStruct((B, S), jnp.int32))
        in_sh = (params_sh, act_sh((B, S)))
        return prefill, args, in_sh, None

    # decode shapes: one new token against a seq_len cache (serve_step)
    params = model.abstract_params()
    params_sh = shd.tree_shardings(params, model.axes(), mesh)
    if cfg.encdec:
        cache = model.init_cache(B, S, enc_len=ENC_LEN_DECODE, abstract=True)
        cache_sh = shd.cache_shardings(cache, cfg, mesh)

        def serve_step(params, cache, token, cursor):
            return model.decode_step(params, cache, token, cursor)

        args = (
            params,
            cache,
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        )
        in_sh = (params_sh, cache_sh, act_sh((B,)), act_sh((B,)))
        out_sh = (None, cache_sh)
        return serve_step, args, in_sh, out_sh
    cache = model.init_cache(B, S, abstract=True)
    cache_sh = shd.cache_shardings(cache, cfg, mesh)

    def serve_step(params, cache, token, cursor):
        return model.decode_step(params, cache, token, cursor)

    args = (
        params,
        cache,
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
    )
    in_sh = (params_sh, cache_sh, act_sh((B,)), act_sh((B,)))
    out_sh = (None, cache_sh)
    return serve_step, args, in_sh, out_sh


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    opt: Optional[str] = None,
) -> Dict[str, Any]:
    cfg = apply_opt(get_config(arch), opt)
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = SHAPES[shape_name]
    t0 = time.time()
    from repro.models import sharding_hooks

    with mesh, opt_rule_context(opt):
        shd.install_activation_resolver(mesh)
        if opt and "moe_local" in opt:
            sharding_hooks.set_moe_mesh(mesh)
        try:
            fn, args, in_sh, out_sh = input_specs(cfg, shape_name, mesh)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        finally:
            shd.clear_activation_resolver()
            sharding_hooks.clear_moe_mesh()

    hlo = compiled.as_text()
    mem = compiled.memory_analysis()
    mem_stats = {}
    if mem is not None:
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(mem, attr):
                mem_stats[attr] = int(getattr(mem, attr))
    model_flops = roofline.model_flops_for(
        cfg, spec.kind, spec.seq_len, spec.global_batch
    )
    from repro.roofline.jaxpr_cost import costs_of

    jflops, jbytes = costs_of(fn, *args)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    report = roofline.analyze(
        compiled,
        hlo,
        model_flops_global=model_flops,
        n_devices=mesh.size,
        jaxpr_flops_global=jflops,
        jaxpr_bytes_global=jbytes,
    )
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "opt": opt or "baseline",
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_stats,
        "roofline": report.to_dict(),
        "xla_cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "note": "XLA counts while bodies ONCE (no trip count); kept "
            "for reference only — roofline uses jaxpr/hlo_cost.",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt", default=None, help="optimization variant")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in applicable_shapes(get_config(arch)):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}_{shape}_{'multi' if multi else 'single'}"
            if args.opt:
                tag += f"_{args.opt}"
            try:
                result = run_cell(arch, shape, multi, args.opt)
                r = result["roofline"]
                print(
                    f"OK   {tag}: compile={result['compile_s']}s "
                    f"dominant={r['dominant']} "
                    f"compute={r['compute_s']:.3e}s "
                    f"memory={r['memory_s']:.3e}s "
                    f"collective={r['collective_s']:.3e}s",
                    flush=True,
                )
            except Exception as e:
                failures += 1
                result = {
                    "arch": arch,
                    "shape": shape,
                    "mesh": "2x16x16" if multi else "16x16",
                    "opt": args.opt or "baseline",
                    "ok": False,
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-3000:],
                }
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            with open(os.path.join(args.out, f"{tag}.json"), "w") as f:
                json.dump(result, f, indent=1)
    if failures:
        raise SystemExit(f"{failures} dry-run cell(s) failed")


if __name__ == "__main__":
    main()
