"""Serving launcher: DeepRT live over compiled JAX models.

Builds an InferenceEngine over reduced configs, profiles it (paper §4.1),
then serves a synthesized multi-tenant request trace through the full
DeepRT stack (admission -> DisBatcher -> EDF -> engine) on a wall clock.

  PYTHONPATH=src python -m repro.launch.serve --archs granite-3-2b,rwkv6-1.6b \
      --requests 12 --seconds 20
"""
from __future__ import annotations

import argparse

from repro.configs.registry import tiny
from repro.core import Category, Request, TraceSpec, generate_trace
from repro.serving.batcher_bridge import build_live_scheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="granite-3-2b,rwkv6-1.6b")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--mean-period", type=float, default=0.25)
    ap.add_argument("--mean-deadline", type=float, default=0.5)
    ap.add_argument("--frames", type=int, default=20)
    args = ap.parse_args()

    arch_ids = args.archs.split(",")
    configs = {a: tiny(a) for a in arch_ids}
    categories = [(a, (args.seq,), "prefill") for a in arch_ids]
    print("profiling engine (paper §4.1 offline pass)...")
    sched, engine, table = build_live_scheduler(configs, categories)
    print(table.to_json())

    spec = TraceSpec(
        mean_period=args.mean_period,
        mean_deadline=args.mean_deadline,
        n_requests=args.requests,
        frames_per_request=(args.frames, args.frames),
        models=tuple(arch_ids),
        shapes=((args.seq,),),
        seed=1,
    )
    admitted = 0
    for r in generate_trace(spec):
        r.start_time = 0.0
        res = sched.submit_request(r)
        admitted += res.admitted
        print(
            f"request {r.request_id} ({r.category}): "
            f"{'ADMIT' if res.admitted else 'REJECT'} "
            f"(phase {res.phase}, U={res.utilization:.2f})"
        )
    print(f"admitted {admitted} requests; serving...")
    m = sched.run()
    print(
        f"completed={m.completed_frames} missed={m.missed_frames} "
        f"miss_rate={m.miss_rate:.3f} jobs={m.job_count} "
        f"mean_batch={m.mean_batch:.2f} throughput={m.throughput:.1f} fps"
    )


if __name__ == "__main__":
    main()
