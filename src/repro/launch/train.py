"""Training launcher: end-to-end sharded training with checkpointing.

On this CPU container it runs reduced configs on the host mesh (the same
code path would run full configs on a real pod — the mesh and shardings
come from the identical rules engine the dry-run exercises).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --tiny --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance drill: --fail-at N simulates a crash after step N; rerun
the same command and training resumes from the latest checkpoint with
bit-identical data order (the pipeline is seekable by step).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.registry import get_config, tiny
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import model_for
from repro.training import optimizer as opt
from repro.training import train_loop
from repro.training.data import DataConfig, SyntheticTokens


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = tiny(args.arch) if args.tiny else get_config(args.arch)
    model = model_for(cfg)
    mesh = make_host_mesh()
    tcfg = train_loop.TrainConfig(
        adamw=opt.AdamWConfig(
            peak_lr=args.lr, warmup_steps=5, total_steps=args.steps
        ),
        grad_accum=args.grad_accum,
    )
    data = SyntheticTokens(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    )
    step_fn = train_loop.make_train_step(model, tcfg)

    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    with mesh:
        shd.install_activation_resolver(mesh)
        try:
            state_sh = train_loop.shardings_for_state(model, mesh)
            if mgr is not None and mgr.latest_step() is not None:
                start_step = mgr.latest_step()
                print(f"resuming from checkpoint step {start_step}")
                state = mgr.restore(
                    start_step, train_loop.abstract_state(model), state_sh
                )
            else:
                state = train_loop.init_state(model, jax.random.PRNGKey(args.seed))
                state = jax.device_put(state, state_sh)
            jitted = jax.jit(step_fn)
            losses = []
            for i in range(start_step, args.steps):
                batch = {
                    k: jnp.asarray(v) for k, v in data.batch(i).items()
                }
                t0 = time.perf_counter()
                state, metrics = jitted(state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.perf_counter() - t0
                if i % 10 == 0 or i == args.steps - 1:
                    print(
                        f"step {i:4d} loss {loss:.4f} "
                        f"gnorm {float(metrics['grad_norm']):.3f} "
                        f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms"
                    )
                if mgr is not None and (i + 1) % args.ckpt_every == 0:
                    mgr.save(i + 1, state)
                if args.fail_at is not None and i + 1 >= args.fail_at:
                    if mgr is not None:
                        mgr.wait()
                    raise SystemExit(
                        f"simulated failure at step {i + 1} (rerun to resume)"
                    )
            if mgr is not None:
                mgr.save(args.steps, state, blocking=True)
            if len(losses) >= 10:
                first, last = np.mean(losses[:5]), np.mean(losses[-5:])
                print(f"loss {first:.4f} -> {last:.4f} ({'improved' if last < first else 'NOT improved'})")
        finally:
            shd.clear_activation_resolver()


if __name__ == "__main__":
    main()
