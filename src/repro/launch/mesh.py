"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches JAX device state (the dry-run must set XLA_FLAGS before any
device query).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the pod axis
is the cross-DCI dimension (data parallelism / ZeRO across pods; the
gradient-compression path targets exactly this axis).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over the real local device(s) — used by examples
    and tests that want the sharded code path on CPU."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
