"""Network transport front end: frame ingestion that survives the network.

The gateway (``ingest/session.py``) made ingestion real but in-process:
its deterministic plans arrive in order, exactly once, with no way for a
client to react to shedding, and a slice failover re-admits tails that
stream synthetic zeros. This module puts a datagram wire between the
client and the gateway and makes the whole path survive what real edge
links do — drop, duplicate, reorder, and delay frames — while keeping
every replay bit-reproducible:

  FrameSource -> TransportSource --datagrams--> SimLink(LinkPlan) -->
    TransportServer --reassembly--> IngestGateway.deliver -->
      DeepRT.ingest_frame

- THE WIRE IS A PLAN. :class:`LinkPlan` is the network analogue of
  ``core.faults.FaultPlan``: a seed-derivable per-send fault schedule
  (DROP / DUPLICATE / REORDER / DELAY). ``SimLink`` applies it under
  either clock — the same seed replays the same chaos on a virtual
  ``EventLoop`` and a live ``WallClock``. A thin UDP binding
  (:class:`UdpServerBinding` / :class:`UdpClientLink`) speaks the same
  codec over a real socket for the live path.
- ROBUST REASSEMBLY. Per-session sequence numbers with a bounded
  reorder window, duplicate suppression, late-frame rejection against
  the send-stamped age vs. the stream's relative deadline, and
  idempotent delivery into ``DeepRT.ingest_frame``: every distinct wire
  frame resolves to exactly ONE of delivered / dropped / lost, so the
  conservation identity ``completed + dropped + lost == ingested``
  extends through the transport. Frames the link destroyed are declared
  lost with the same accounting convention a closed device uses
  (``record_ingest + record_lost``), so nothing silently vanishes.
- FLOW CONTROL. Backpressure is signaled BACK to the client instead of
  shedding silently at the server: after each delivery the server reads
  the gateway's queueing-delay estimate (which already folds in
  ``AdaptationModule.shed_scale``) and, when over budget, sends a
  CREDIT message downshifting the client's duty toward 1.0 —
  ``BurstSource.duty`` is the actuator, so a 2x-overloaded burst stream
  is stretched back toward its admitted rate at the source. Credit
  decays back toward the planned duty when the backlog clears.
- SESSION RE-HOMING. The server registers as the cluster's rehome
  owner and subscribes to its health monitor: when a slice is
  quarantined and ``fail_slice`` re-admits the session's tail, the
  server rebinds the session to the tail request, drains the frames
  buffered in its reorder window into the NEW slice (real payload, not
  zeros), and asks the client to retransmit the unresolved window from
  its retransmit buffer.

Determinism caveat: everything scheduled here uses only
``loop.schedule / schedule_in / cancel / now``, so sim runs are exact;
live runs reproduce the same *plan* subject to wall-clock jitter.
"""
from __future__ import annotations

import itertools
import json
import math
import random
import socket
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import telemetry as T
from repro.core.request import Category
from repro.ingest.session import IngestGateway, StreamSession
from repro.ingest.sources import FrameSource, PeriodicSource

# ---------------------------------------------------------------------------
# Wire codec
# ---------------------------------------------------------------------------

MAGIC = b"DRT1"

MALFORMED = 0     # decode verdict: not a message (reason string attached)
HELLO = 1         # client -> server: open a session (control, JSON body)
HELLO_ACK = 2     # server -> client: session id + admission verdict
DATA = 3          # client -> server: one frame (binary hot path)
CREDIT = 4        # server -> client: duty downshift/upshift
REHOME = 5        # server -> client: session re-homed, retransmit window
FIN = 6           # client -> server: stream complete (total frames sent)
STATUS = 7        # probe -> server: scrape the JSON status snapshot
STATUS_REPLY = 8  # server -> probe: the snapshot
HELLO_RETRY = 9   # server -> client: admission gated, retry after backoff

_CONTROL_TYPES = frozenset(
    (HELLO, HELLO_ACK, CREDIT, REHOME, FIN, STATUS, STATUS_REPLY, HELLO_RETRY)
)

# Adversarial-wire bounds: a datagram that claims more than these is a
# counted ``malformed`` drop, never an allocation (or an exception).
MAX_NDIM = 8
MAX_DIM = 1 << 20
MAX_PAYLOAD_BYTES = 1 << 22  # 4 MiB of int32 payload per frame

_HEADER = struct.Struct("!4sB")
_DATA_HEAD = struct.Struct("!IIdB")  # session_id, seq, sent_at, ndim


@dataclass(frozen=True)
class DataMsg:
    session_id: int
    seq: int
    sent_at: float  # sender's clock at send (late rejection input)
    payload: np.ndarray


def encode_data(session_id: int, seq: int, sent_at: float, payload) -> bytes:
    # asarray, not ascontiguousarray: the latter promotes 0-d payloads
    # (decode tokens) to 1-d, silently changing the delivered shape.
    arr = np.asarray(payload, dtype=np.int32)
    parts = [
        _HEADER.pack(MAGIC, DATA),
        _DATA_HEAD.pack(session_id, seq, sent_at, arr.ndim),
        struct.pack(f"!{arr.ndim}I", *arr.shape) if arr.ndim else b"",
        arr.astype("<i4").tobytes(),
    ]
    return b"".join(parts)


def encode_control(mtype: int, body: Dict) -> bytes:
    return _HEADER.pack(MAGIC, mtype) + json.dumps(body, sort_keys=True).encode()


def decode(data: bytes) -> Tuple[int, object]:
    """Parse one datagram. NEVER raises: any input that is not a valid
    message decodes to ``(MALFORMED, reason)`` with a specific reason
    string. The wire is adversarial — a truncated header, bad magic, an
    absurd ``ndim``/dim claim, an oversized payload, or corrupt control
    JSON must be a counted drop in the rx path, not an exception that
    can kill it (and never an attacker-sized allocation)."""
    try:
        if len(data) < _HEADER.size:
            return MALFORMED, "truncated_header"
        magic, mtype = _HEADER.unpack_from(data)
        if magic != MAGIC:
            return MALFORMED, "bad_magic"
        off = _HEADER.size
        if mtype == DATA:
            if len(data) < off + _DATA_HEAD.size:
                return MALFORMED, "truncated_data_head"
            sid, seq, sent_at, ndim = _DATA_HEAD.unpack_from(data, off)
            off += _DATA_HEAD.size
            if ndim > MAX_NDIM:
                return MALFORMED, "ndim_overflow"
            if len(data) < off + 4 * ndim:
                return MALFORMED, "truncated_dims"
            shape = struct.unpack_from(f"!{ndim}I", data, off) if ndim else ()
            off += 4 * ndim
            elements = 1
            for dim in shape:
                if dim > MAX_DIM:
                    return MALFORMED, "dim_overflow"
                elements *= dim
            if 4 * elements > MAX_PAYLOAD_BYTES:
                return MALFORMED, "oversized_payload"
            if len(data) - off != 4 * elements:
                return MALFORMED, "payload_size_mismatch"
            if not math.isfinite(sent_at):
                return MALFORMED, "bad_sent_at"
            payload = np.frombuffer(data, dtype="<i4", offset=off).astype(
                np.int32
            )
            return DATA, DataMsg(sid, seq, sent_at, payload.reshape(shape))
        if mtype not in _CONTROL_TYPES:
            return MALFORMED, "unknown_type"
        if len(data) == off:
            return mtype, {}
        try:
            body = json.loads(data[off:].decode())
        except (UnicodeDecodeError, ValueError):
            return MALFORMED, "bad_control_json"
        if not isinstance(body, dict):
            return MALFORMED, "bad_control_json"
        return mtype, body
    except Exception as e:  # pragma: no cover — fuzzer safety net
        return MALFORMED, f"internal:{type(e).__name__}"


# ---------------------------------------------------------------------------
# LinkPlan: the deterministic chaos wire
# ---------------------------------------------------------------------------

DROP = "drop"            # the datagram never arrives
DUPLICATE = "duplicate"  # the datagram arrives ``copies`` times
REORDER = "reorder"      # held back long enough to land after later sends
LINK_DELAY = "link_delay"  # extra one-way latency, order usually preserved

LINK_FAULT_KINDS = (DROP, DUPLICATE, REORDER, LINK_DELAY)


@dataclass(frozen=True)
class LinkFault:
    """One injected link fault, keyed by the client's send index (every
    datagram that enters the chaotic wire counts, retransmits included —
    the wire does not know which bytes are retries)."""

    kind: str
    at_send: int
    delay: float = 0.0  # hold time for REORDER / LINK_DELAY
    copies: int = 2     # total arrivals for DUPLICATE

    def __post_init__(self) -> None:
        if self.kind not in LINK_FAULT_KINDS:
            raise ValueError(
                f"unknown link fault kind {self.kind!r}; one of {LINK_FAULT_KINDS}"
            )
        if self.at_send < 0:
            raise ValueError("at_send must be >= 0")
        if self.delay < 0.0:
            raise ValueError("delay must be >= 0")
        if self.kind in (REORDER, LINK_DELAY) and self.delay <= 0.0:
            raise ValueError(f"a {self.kind} fault must actually delay (delay > 0)")
        if self.kind == DUPLICATE and self.copies < 2:
            raise ValueError("a DUPLICATE fault needs copies >= 2")


class LinkPlan:
    """A deterministic per-send fault schedule: at most one fault per
    send index. ``arrivals(i)`` maps send ``i`` to the list of extra
    one-way delays its copies arrive with (empty = dropped)."""

    def __init__(self, specs: Tuple[LinkFault, ...] = ()) -> None:
        self.by_send: Dict[int, LinkFault] = {}
        for spec in specs:
            if spec.at_send in self.by_send:
                raise ValueError(f"duplicate link fault at send index {spec.at_send}")
            self.by_send[spec.at_send] = spec

    @property
    def specs(self) -> List[LinkFault]:
        return [self.by_send[i] for i in sorted(self.by_send)]

    def for_send(self, index: int) -> Optional[LinkFault]:
        return self.by_send.get(index)

    def arrivals(self, index: int) -> List[float]:
        spec = self.by_send.get(index)
        if spec is None:
            return [0.0]
        if spec.kind == DROP:
            return []
        if spec.kind == DUPLICATE:
            return [0.0] * spec.copies
        return [spec.delay]  # REORDER / LINK_DELAY

    def __len__(self) -> int:
        return len(self.by_send)

    @classmethod
    def from_seed(
        cls,
        seed: int,
        n_sends: int,
        p_drop: float = 0.0,
        p_dup: float = 0.0,
        p_reorder: float = 0.0,
        p_delay: float = 0.0,
        delay_range: Tuple[float, float] = (0.005, 0.05),
        reorder_hold: Tuple[float, float] = (0.05, 0.2),
        copies: int = 2,
    ) -> "LinkPlan":
        """Draw an independent fault (or none) for each send index.

        Mirrors ``FaultPlan.from_seed``: the per-index draw count is
        branch-independent, so the plan for sends ``[0, k)`` is a prefix
        of the plan for ``[0, n)`` — same seed, same chaos.
        """
        if p_drop + p_dup + p_reorder + p_delay > 1.0:
            raise ValueError("link fault probabilities must sum to <= 1")
        rng = random.Random(seed)
        specs = []
        for i in range(n_sends):
            r = rng.random()
            d = rng.uniform(*delay_range)
            hold = rng.uniform(*reorder_hold)
            if r < p_drop:
                specs.append(LinkFault(DROP, i))
            elif r < p_drop + p_dup:
                specs.append(LinkFault(DUPLICATE, i, copies=copies))
            elif r < p_drop + p_dup + p_reorder:
                specs.append(LinkFault(REORDER, i, delay=hold))
            elif r < p_drop + p_dup + p_reorder + p_delay:
                specs.append(LinkFault(LINK_DELAY, i, delay=d))
        return cls(tuple(specs))


class SimLink:
    """The in-memory wire: ``send`` schedules each surviving copy of a
    datagram onto the loop at ``now + latency + fault delay``. Control
    traffic (HELLO/FIN/CREDIT) rides ``chaos=False`` — the handshake is
    assumed reliable, which keeps the chaos surface exactly the frame
    path the reorder machinery must survive."""

    def __init__(self, loop, deliver: Callable[[bytes], None],
                 plan: Optional[LinkPlan] = None, latency: float = 0.0):
        self.loop = loop
        self.deliver = deliver
        self.plan = plan
        self.latency = latency
        self.sends = 0          # chaos-eligible datagrams offered
        self.dropped = 0
        self.duplicated = 0
        self.reordered = 0
        self.delayed = 0

    def send(self, data: bytes, chaos: bool = True) -> None:
        prio = getattr(self.loop, "PRIO_ARRIVAL", 0)
        if not chaos or self.plan is None:
            arrivals = [0.0]
        else:
            index = self.sends
            self.sends += 1
            arrivals = self.plan.arrivals(index)
            spec = self.plan.for_send(index)
            if spec is not None:
                if spec.kind == DROP:
                    self.dropped += 1
                elif spec.kind == DUPLICATE:
                    self.duplicated += 1
                elif spec.kind == REORDER:
                    self.reordered += 1
                elif spec.kind == LINK_DELAY:
                    self.delayed += 1
        for extra in arrivals:
            self.loop.schedule(
                self.loop.now + self.latency + extra,
                lambda data=data: self.deliver(data),
                priority=prio,
            )


# ---------------------------------------------------------------------------
# Client: TransportSource
# ---------------------------------------------------------------------------

class TransportSource:
    """Client half of the transport: paces a ``FrameSource``'s plan onto
    the wire, keeps a bounded retransmit buffer, and obeys the server's
    credit messages.

    The pacing actuator is DUTY: the source's plan was generated at
    ``plan_duty`` (``BurstSource.duty``; 1.0 for other sources), and the
    client stretches inter-frame gaps by ``duty / plan_duty``. A credit
    downshift raises ``duty`` toward 1.0 — the stream spreads the same
    frame budget back toward its admitted rate, which is exactly the
    graceful degradation the server-side shedder could only approximate
    by dropping. ``flow_control=False`` ignores credit entirely (the
    benchmark's control arm)."""

    def __init__(
        self,
        source: FrameSource,
        category: Category,
        relative_deadline: float,
        link,
        flow_control: bool = True,
        retransmit_window: int = 256,
        hello_max_retries: int = 12,
        abort_after: Optional[int] = None,
    ):
        self.source = source
        self.category = category
        self.relative_deadline = relative_deadline
        self.link = link
        self.loop = link.loop
        self.flow_control = flow_control
        self.retransmit_window = retransmit_window
        self.hello_max_retries = hello_max_retries
        # Zombie-client knob (tests/benchmarks): stop sending after this
        # many frames, silently — no FIN, no further traffic. The
        # server's idle-timeout eviction is the only way the session
        # ever resolves.
        self.abort_after = abort_after
        self.plan = source.plan()
        self.plan_duty = float(getattr(source, "duty", 1.0))
        self.duty = self.plan_duty
        self.sid: Optional[int] = None
        self.state = "idle"  # idle | retrying | active | rejected | done | aborted
        self.frames_sent = 0
        self.retransmits = 0
        self.credits_seen = 0
        self.downshifts_applied = 0
        self.rehomes_seen = 0
        self.hello_retries = 0
        self._cursor = 0
        self._sent: Dict[int, np.ndarray] = {}  # seq -> payload (bounded)
        self._server: Optional["TransportServer"] = None
        self._start_in = 0.0

    # -- lifecycle ------------------------------------------------------
    def start(self, server: "TransportServer", start_in: float = 0.0) -> bool:
        """Open the session through the server's HELLO gate (reliable
        control path) and begin sending. Under churn gating the server
        may answer HELLO_RETRY: the client re-HELLOs after the signaled
        backoff (state ``retrying``) instead of failing admission, so a
        registration storm degrades to delayed admission. Returns False
        only on outright rejection (admission refused, or the retry
        budget exhausted)."""
        self._server = server
        self._start_in = start_in
        return self._hello()

    def _hello(self) -> bool:
        mtype, body = decode(
            self._server.hello(
                {
                    "model_id": self.category.model_id,
                    "shape_key": list(self.category.shape_key),
                    "realtime": self.category.realtime,
                    "period": self.source.period,
                    "n_frames": self.source.n_frames,
                    "relative_deadline": self.relative_deadline,
                    "duty": self.plan_duty,
                },
                control=self.control,
            )
        )
        if mtype == HELLO_RETRY:
            self.hello_retries += 1
            if self.hello_retries > self.hello_max_retries:
                self.state = "rejected"
                return False
            self.state = "retrying"
            self.loop.schedule(
                self.loop.now + max(1e-4, float(body.get("backoff", 0.05))),
                self._hello,
                priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
            )
            return True
        self.sid = int(body["sid"])
        if not bool(body.get("accepted")):
            self.state = "rejected"
            return False
        self.state = "active"
        self.loop.schedule(
            self.loop.now + self._start_in + self.plan[0].offset,
            self._send_next,
            priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
        )
        return True

    def start_remote(self, sid: int, start_in: float = 0.0) -> None:
        """Begin sending against a session opened out-of-band (the UDP
        binding's HELLO/HELLO_ACK handshake yields the sid)."""
        self.sid = sid
        self.state = "active"
        self.loop.schedule(
            self.loop.now + start_in + self.plan[0].offset,
            self._send_next,
            priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
        )

    # -- send path ------------------------------------------------------
    def _remember(self, seq: int, payload: np.ndarray) -> None:
        self._sent[seq] = payload
        while len(self._sent) > self.retransmit_window:
            self._sent.pop(min(self._sent))

    def _send_next(self) -> None:
        if self.state != "active":
            return
        k = self._cursor
        if self.abort_after is not None and k >= self.abort_after:
            # Zombie: vanish mid-stream without a FIN. The server must
            # eventually evict us or leak the session forever.
            self.state = "aborted"
            return
        payload = self.plan[k].payload
        self._remember(k, payload)
        self.frames_sent += 1
        self.link.send(encode_data(self.sid, k, self.loop.now, payload))
        self._cursor += 1
        if self._cursor < len(self.plan):
            gap = self.plan[self._cursor].offset - self.plan[k].offset
            pace = self.duty / self.plan_duty
            self.loop.schedule(
                self.loop.now + max(0.0, gap) * pace,
                self._send_next,
                priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
            )
            return
        self.state = "done"
        self.link.send(
            encode_control(FIN, {"sid": self.sid, "total": len(self.plan)}),
            chaos=False,
        )

    # -- control path (server -> client) --------------------------------
    def control(self, data: bytes) -> None:
        mtype, body = decode(data)
        if mtype == MALFORMED:
            return  # a chaotic wire can corrupt control datagrams too
        try:
            if mtype == CREDIT:
                self.credits_seen += 1
                if not self.flow_control:
                    return  # control arm: the client never downshifts
                new = min(1.0, max(self.plan_duty, float(body["duty"])))
                if new > self.duty:
                    self.downshifts_applied += 1
                self.duty = new
            elif mtype == REHOME:
                self.rehomes_seen += 1
                self._retransmit(int(body["from_seq"]))
        except (KeyError, TypeError, ValueError):
            return  # missing/mistyped body field: drop, don't crash

    def _retransmit(self, from_seq: int) -> None:
        """Replay the unresolved window from the retransmit buffer. The
        retries traverse the SAME chaotic wire — the link does not know
        they are retries, so a retransmit can itself be dropped (the
        bit-exactness property is over frames that survive)."""
        for seq in sorted(s for s in self._sent if s >= from_seq):
            self.retransmits += 1
            self.link.send(
                encode_data(self.sid, seq, self.loop.now, self._sent[seq])
            )


# ---------------------------------------------------------------------------
# Server: TransportServer
# ---------------------------------------------------------------------------

class _ShardedSessionTable:
    """Session table split over power-of-2 shards.

    Per-datagram dispatch is one hash either way; sharding buys bounded
    *background* work — the lifecycle sweep visits one shard per tick,
    so its per-tick cost is ``O(sessions / n_shards)`` instead of a
    full-table scan that would stall the rx path at thousands of
    sessions. The surface mimics ``dict`` so existing callers
    (``server.sessions[sid]``, ``.values()``, ``len``) keep working.
    """

    __slots__ = ("_shards", "_mask", "_len")

    def __init__(self, n_shards: int = 16) -> None:
        n = 1
        while n < max(1, n_shards):
            n <<= 1
        self._shards: List[Dict[int, "TransportSession"]] = [
            {} for _ in range(n)
        ]
        self._mask = n - 1
        self._len = 0

    @property
    def n_shards(self) -> int:
        return len(self._shards)

    def shard(self, index: int) -> Dict[int, "TransportSession"]:
        return self._shards[index & self._mask]

    def __getitem__(self, sid: int) -> "TransportSession":
        return self._shards[sid & self._mask][sid]

    def __setitem__(self, sid: int, ts: "TransportSession") -> None:
        shard = self._shards[sid & self._mask]
        if sid not in shard:
            self._len += 1
        shard[sid] = ts

    def __delitem__(self, sid: int) -> None:
        del self._shards[sid & self._mask][sid]
        self._len -= 1

    def __contains__(self, sid: int) -> bool:
        return sid in self._shards[sid & self._mask]

    def __len__(self) -> int:
        return self._len

    def __iter__(self):
        for shard in self._shards:
            yield from shard

    def get(self, sid: int, default=None):
        return self._shards[sid & self._mask].get(sid, default)

    def pop(self, sid: int, *default):
        shard = self._shards[sid & self._mask]
        if sid in shard:
            self._len -= 1
            return shard.pop(sid)
        if default:
            return default[0]
        raise KeyError(sid)

    def keys(self):
        return list(self)

    def values(self):
        for shard in self._shards:
            yield from shard.values()

    def items(self):
        for shard in self._shards:
            yield from shard.items()


@dataclass
class TransportSession:
    """Server-side wire state for one session; the admission/shedding
    state lives on the wrapped gateway ``StreamSession``."""

    sid: int
    session: StreamSession
    n_frames: int
    relative_deadline: float
    plan_duty: float
    duty: float
    control: Optional[Callable[[bytes], None]] = None
    next_seq: int = 0  # first seq not yet resolved in order
    buffer: Dict[int, Tuple[np.ndarray, float]] = field(default_factory=dict)
    seen: Set[int] = field(default_factory=set)  # resolved seqs
    # Wire accounting: every DATA datagram lands in exactly one bucket.
    wire_received: int = 0
    duplicates: int = 0
    late_rejected: int = 0
    net_lost: int = 0        # declared lost at a reorder-gap skip / finalize
    delivered: int = 0
    shed: int = 0
    lost_to_slice: int = 0   # delivered into a just-closed device
    refused: int = 0         # arrived for a closed/rejected session, or
                             # bounced off a reassembly byte budget
    evicted: int = 0         # buffered frames discarded by lifecycle
                             # eviction / expiry / FIN-truncation
    rehomes: int = 0
    fin_total: Optional[int] = None
    finalized: bool = False
    eviction_reason: Optional[str] = None
    last_credit_at: float = -math.inf
    cohort_downshifts: int = 0
    buffered_bytes: int = 0
    opened_at: float = 0.0
    last_activity: float = 0.0
    open_counted: bool = False
    delivered_log: List[int] = field(default_factory=list)
    delivered_payloads: Dict[int, np.ndarray] = field(default_factory=dict)

    def wire_conserved(self) -> bool:
        """Every datagram that reached the server is accounted: resolved
        (one way), suppressed as a duplicate, still buffered, refused,
        or evicted with its session."""
        resolved = (
            self.delivered + self.shed + self.late_rejected + self.lost_to_slice
        )
        return self.wire_received == (
            resolved
            + self.duplicates
            + len(self.buffer)
            + self.refused
            + self.evicted
        )


class TransportServer:
    """Receive half: reassembly, flow control, re-homing, observability.

    Sits in front of an :class:`IngestGateway` (over a single ``DeepRT``
    or a ``ClusterScheduler``). With a cluster target it registers
    itself as the rehome owner (``ClusterScheduler.set_rehome_owner``)
    and subscribes to the health monitor, so ``fail_slice`` re-admits
    transport-owned tails as EXTERNAL requests and hands them back here
    instead of streaming synthetic zeros.
    """

    def __init__(
        self,
        gateway: IngestGateway,
        flow_control: bool = True,
        reorder_window: int = 8,
        reorder_timeout: Optional[float] = None,
        late_reject_factor: float = 1.0,
        duty_step: float = 1.5,
        high_water: float = 1.0,
        low_water: float = 0.25,
        credit_min_interval: float = 0.0,
        record_payloads: bool = False,
        reassembly_budget_bytes: Optional[int] = None,
        session_buffer_bytes: Optional[int] = None,
        idle_timeout: Optional[float] = None,
        hello_rate: Optional[float] = None,
        hello_burst: float = 8.0,
        max_sessions: Optional[int] = None,
        retain_finalized: bool = True,
        shards: int = 16,
    ):
        self.gateway = gateway
        self.loop = gateway.loop
        self.flow_control = flow_control
        self.reorder_window = reorder_window
        self.reorder_timeout = reorder_timeout
        self.late_reject_factor = late_reject_factor
        self.duty_step = duty_step
        self.high_water = high_water
        self.low_water = low_water
        self.credit_min_interval = credit_min_interval
        self.record_payloads = record_payloads
        # Resource-lifecycle bounds. All default OFF (None) so the
        # pre-hardening behavior — unbounded buffers, immortal sessions,
        # ungated HELLO — is what small tests get without opting in.
        self.reassembly_budget_bytes = reassembly_budget_bytes
        self.session_buffer_bytes = session_buffer_bytes
        self.idle_timeout = idle_timeout
        self.hello_rate = hello_rate
        self.hello_burst = float(hello_burst)
        self.max_sessions = max_sessions
        self.retain_finalized = retain_finalized
        self.sessions = _ShardedSessionTable(shards)
        self._by_rid: Dict[int, TransportSession] = {}
        self._sids = itertools.count(1)
        self._cohort: Dict[str, Set[int]] = {}  # slice name -> open sids
        # HELLO token bucket (lazy refill against loop.now).
        self._hello_tokens = self.hello_burst
        self._hello_tokens_at = self.loop.now
        # Lifecycle counters (all surfaced via telemetry()).
        self.open_count = 0
        self.draining = False
        self.drained = False
        self.reassembly_bytes = 0
        self.reassembly_peak_bytes = 0
        self.budget_refusals = 0
        self.evictions = 0
        self.retired_sessions = 0
        self.retired_totals: Dict[str, int] = {
            "wire_received": 0, "delivered": 0, "shed": 0,
            "late_rejected": 0, "lost_to_slice": 0, "duplicates": 0,
            "refused": 0, "evicted": 0, "net_lost": 0,
        }
        self.malformed = 0
        self.malformed_by_reason: Dict[str, int] = {}
        self.hellos_seen = 0
        self.hellos_accepted = 0
        self.hellos_rejected = 0
        self.hello_retries_sent = 0
        self.hello_refused_draining = 0
        self.cohort_signals = 0
        self._sweep_armed = False
        self._sweep_shard = 0
        # Frame-lifecycle tracer (core/telemetry.py); None = off. The
        # transport is where wire receive / reassembly / wire-loss hops
        # are stamped (the only component that sees them).
        self.tracer = None
        self.health_log: List[Tuple[float, str, str, str]] = []
        target = gateway.target
        if hasattr(target, "set_rehome_owner"):
            target.set_rehome_owner(self)
        health = getattr(target, "health", None)
        if health is not None:
            health.subscribe(self._on_health)
        probes = getattr(target, "telemetry_probes", None)
        if probes is not None:
            probes["transport"] = self.telemetry

    # -- adversarial-wire accounting ------------------------------------
    def note_malformed(self, reason) -> None:
        """Count a datagram that failed to decode (or a control body
        that failed validation). Reasons come from :func:`decode`."""
        self.malformed += 1
        key = str(reason)
        self.malformed_by_reason[key] = (
            self.malformed_by_reason.get(key, 0) + 1
        )

    # -- HELLO gate ------------------------------------------------------
    def hello(
        self, body: Dict, control: Optional[Callable[[bytes], None]] = None
    ) -> bytes:
        """Admission front door for a HELLO body; returns the encoded
        reply datagram (HELLO_ACK, or HELLO_RETRY under churn gating).

        Order of the gates matters: draining wins over everything (a
        retry against a draining server would loop forever), then the
        token bucket and the open-session cap answer HELLO_RETRY —
        *transient* refusals a client can wait out — and only a HELLO
        that passes the gates spends a Phase-1 admission test."""
        self.hellos_seen += 1
        if self.draining:
            self.hello_refused_draining += 1
            return encode_control(
                HELLO_ACK, {"sid": 0, "accepted": False, "reason": "draining"}
            )
        try:
            category = Category(
                model_id=str(body["model_id"]),
                shape_key=tuple(int(x) for x in body["shape_key"]),
                realtime=bool(body.get("realtime", True)),
            )
            period = float(body["period"])
            n_frames = int(body["n_frames"])
            relative_deadline = float(body["relative_deadline"])
            duty = float(body.get("duty", 1.0))
            if period <= 0 or n_frames <= 0 or relative_deadline <= 0:
                raise ValueError("non-positive stream parameter")
        except Exception:
            self.note_malformed("bad_hello_body")
            return encode_control(
                HELLO_ACK, {"sid": 0, "accepted": False, "reason": "bad_body"}
            )
        if self.hello_rate is not None:
            now = self.loop.now
            self._hello_tokens = min(
                self.hello_burst,
                self._hello_tokens
                + (now - self._hello_tokens_at) * self.hello_rate,
            )
            self._hello_tokens_at = now
            if self._hello_tokens < 1.0:
                self.hello_retries_sent += 1
                backoff = (1.0 - self._hello_tokens) / self.hello_rate
                return encode_control(HELLO_RETRY, {"backoff": backoff})
            self._hello_tokens -= 1.0
        if self.max_sessions is not None and self.open_count >= self.max_sessions:
            self.hello_retries_sent += 1
            return encode_control(
                HELLO_RETRY,
                {"backoff": self.idle_timeout or 0.1, "reason": "at_capacity"},
            )
        sid, ok = self.open_session(
            category=category, period=period, n_frames=n_frames,
            relative_deadline=relative_deadline, duty=duty, control=control,
        )
        if ok:
            self.hellos_accepted += 1
        else:
            self.hellos_rejected += 1
        return encode_control(HELLO_ACK, {"sid": sid, "accepted": ok})

    # -- session lifecycle ----------------------------------------------
    def open_session(
        self,
        category: Category,
        period: float,
        n_frames: int,
        relative_deadline: float,
        duty: float = 1.0,
        control: Optional[Callable[[bytes], None]] = None,
        start_in: float = 0.0,
    ) -> Tuple[int, bool]:
        """Admission-test the declared stream through the gateway's
        normal placement/admission/lease path; the transport owns the
        frame path (``schedule_arrivals=False``)."""
        declared = PeriodicSource(period=period, n_frames=n_frames)
        session = self.gateway.register(
            declared, category, relative_deadline,
            start_in=start_in, schedule_arrivals=False,
        )
        sid = next(self._sids)
        now = self.loop.now
        ts = TransportSession(
            sid=sid, session=session, n_frames=n_frames,
            relative_deadline=relative_deadline,
            plan_duty=float(duty), duty=float(duty), control=control,
            opened_at=now, last_activity=now,
        )
        self.sessions[sid] = ts
        if session.state != "active":
            ts.finalized = True
            if not self.retain_finalized:
                self._retire(ts)
            return sid, False
        self._by_rid[session.request_id] = ts
        ts.open_counted = True
        self.open_count += 1
        if session.slice_name is not None:
            self._cohort.setdefault(session.slice_name, set()).add(sid)
        self._arm_sweep()
        return sid, True

    # -- datagram entry --------------------------------------------------
    def datagram(self, data: bytes) -> None:
        mtype, msg = decode(data)
        if mtype == MALFORMED:
            self.note_malformed(msg)
            return
        if mtype == DATA:
            self._on_data(msg)
        elif mtype == FIN:
            try:
                sid, total = int(msg["sid"]), int(msg["total"])
            except (KeyError, TypeError, ValueError):
                self.note_malformed("bad_fin_body")
                return
            self._on_fin(sid, total)
        # HELLO/STATUS are handled by the socket binding (control path).

    # -- bounded reassembly ----------------------------------------------
    @staticmethod
    def _nbytes(payload) -> int:
        return int(getattr(payload, "nbytes", 4))

    def _buffer_put(
        self, ts: TransportSession, seq: int, payload, at: float
    ) -> bool:
        """Admit a frame to the reorder buffer iff it fits both the
        per-session and the global byte budget; a refused frame is a
        counted ``refused`` (its gap resolves as net_lost later, so each
        datagram still lands in exactly one conservation leg)."""
        nb = self._nbytes(payload)
        if (
            self.session_buffer_bytes is not None
            and ts.buffered_bytes + nb > self.session_buffer_bytes
        ) or (
            self.reassembly_budget_bytes is not None
            and self.reassembly_bytes + nb > self.reassembly_budget_bytes
        ):
            ts.refused += 1
            self.budget_refusals += 1
            return False
        ts.buffer[seq] = (payload, at)
        ts.buffered_bytes += nb
        self.reassembly_bytes += nb
        if self.reassembly_bytes > self.reassembly_peak_bytes:
            self.reassembly_peak_bytes = self.reassembly_bytes
        return True

    def _buffer_pop(self, ts: TransportSession, seq: int):
        payload, at = ts.buffer.pop(seq)
        nb = self._nbytes(payload)
        ts.buffered_bytes -= nb
        self.reassembly_bytes -= nb
        return payload, at

    def _buffer_clear(self, ts: TransportSession) -> int:
        """Discard the whole reorder buffer; returns the frame count so
        the caller can pick the conservation leg (``evicted``)."""
        n = len(ts.buffer)
        ts.buffer.clear()
        self.reassembly_bytes -= ts.buffered_bytes
        ts.buffered_bytes = 0
        return n

    def _on_data(self, msg: DataMsg) -> None:
        ts = self.sessions.get(msg.session_id)
        if ts is None:
            return
        ts.wire_received += 1
        ts.last_activity = self.loop.now
        state = ts.session.state
        if ts.finalized or state in ("closed", "rejected"):
            ts.refused += 1
            return
        if msg.seq in ts.seen or msg.seq in ts.buffer:
            ts.duplicates += 1
            return
        now = self.loop.now
        if self.tracer is not None:
            # Stamps both the receive hop and (via meta["sent_at"]) the
            # sender-clock send hop for this frame's wire-stage delta.
            self.tracer.emit(
                T.WIRE_RECV, now, ts.session.request_id, msg.seq,
                where=ts.session.slice_name,
                cat=str(ts.session.request.category),
                meta={"sent_at": msg.sent_at})
        if now - msg.sent_at > self.late_reject_factor * ts.relative_deadline:
            # Older than its whole deadline budget: it would miss even if
            # the device were idle — reject at the door, resolved as a
            # gateway-style drop (counted in ``ingested`` via dropped).
            ts.seen.add(msg.seq)
            ts.late_rejected += 1
            self._account_drop(
                ts, reason=f"late: aged {now - msg.sent_at:.4f}s on the wire",
                seq=msg.seq,
            )
            return
        if state == "failover":
            # Slice died, tail not re-admitted yet (parked): hold the
            # real bytes — they are exactly what re-homing replays.
            self._buffer_put(ts, msg.seq, msg.payload, now)
            return
        if msg.seq == ts.next_seq:
            self._deliver(ts, msg.seq, msg.payload)
            self._drain(ts)
        elif msg.seq > ts.next_seq:
            self._buffer_put(ts, msg.seq, msg.payload, now)
            self._maybe_skip_gap(ts)
            if ts.buffer:
                self.loop.schedule_in(
                    self._timeout(ts),
                    lambda: self._gap_check(ts),
                    priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
                )
        else:
            # Below next_seq but not in ``seen``: the gap was already
            # resolved (declared lost); this copy is a straggler.
            ts.duplicates += 1

    # -- reorder window ---------------------------------------------------
    def _timeout(self, ts: TransportSession) -> float:
        if self.reorder_timeout is not None:
            return self.reorder_timeout
        return ts.relative_deadline

    def _gap_check(self, ts: TransportSession) -> None:
        if ts.finalized or ts.session.state != "active":
            return
        self._maybe_skip_gap(ts)

    def _maybe_skip_gap(self, ts: TransportSession) -> None:
        """Bounded reorder window: once the buffer exceeds the window or
        its oldest entry exceeds the timeout, the missing gap seqs are
        declared lost and the buffered tail drains in order."""
        now = self.loop.now
        while ts.buffer:
            oldest = min(at for _p, at in ts.buffer.values())
            if (len(ts.buffer) <= self.reorder_window
                    and now - oldest < self._timeout(ts)):
                return
            lo = min(ts.buffer)
            for seq in range(ts.next_seq, lo):
                self._account_lost(ts, seq)
            ts.next_seq = lo
            self._drain(ts)

    def _drain(self, ts: TransportSession) -> None:
        while ts.next_seq in ts.buffer:
            payload, _at = self._buffer_pop(ts, ts.next_seq)
            self._deliver(ts, ts.next_seq, payload)

    # -- resolution paths --------------------------------------------------
    def _deliver(self, ts: TransportSession, seq: int, payload) -> None:
        ts.seen.add(seq)
        ts.next_seq = max(ts.next_seq, seq + 1)
        if self.tracer is not None:
            self.tracer.emit(
                T.REASSEMBLY, self.loop.now, ts.session.request_id, seq,
                where=ts.session.slice_name,
                cat=str(ts.session.request.category))
        status = self.gateway.deliver(ts.session, seq, payload)
        if status == "delivered":
            ts.delivered += 1
            ts.delivered_log.append(seq)
            if self.record_payloads:
                ts.delivered_payloads[seq] = np.array(payload, copy=True)
        elif status == "shed":
            ts.shed += 1
        elif status == "lost":
            ts.lost_to_slice += 1
        else:  # refused: session flipped state under us
            ts.refused += 1
        if status in ("delivered", "shed"):
            self._flow_control(ts)

    def _account_drop(
        self, ts: TransportSession, reason: str, seq: int = -1
    ) -> None:
        """Resolve a wire frame as DROPPED at the gateway boundary (the
        bytes arrived; they are rejected, not vanished)."""
        session = ts.session
        session.frames_ingested += 1
        session.frames_dropped += 1
        session.last_shed_reason = reason
        sched = self.gateway._scheduler_of(session)
        sched.metrics.record_drop(session.request_id)
        sl = self.gateway._slice_of(session)
        if sl is not None:
            sl.note_dropped(session.request_id)
        if self.tracer is not None:
            self.tracer.emit(
                T.SHED, self.loop.now, session.request_id, seq,
                where=session.slice_name,
                cat=str(session.request.category),
                meta={"reason": reason})

    def _account_lost(self, ts: TransportSession, seq: int) -> None:
        """Resolve a wire frame the link destroyed as LOST: counted
        ingested AND lost (the closed-device convention), so the
        conservation identity covers frames that never arrived."""
        ts.seen.add(seq)
        ts.net_lost += 1
        session = ts.session
        session.frames_lost += 1
        sched = self.gateway._scheduler_of(session)
        sched.metrics.record_ingest()
        sched.metrics.record_lost()
        sl = self.gateway._slice_of(session)
        if sl is not None:
            sl.note_dropped(session.request_id)
        if self.tracer is not None:
            self.tracer.emit(
                T.LOST, self.loop.now, session.request_id, seq,
                where=session.slice_name,
                cat=str(session.request.category),
                meta={"reason": "wire"})

    # -- flow control ------------------------------------------------------
    def _flow_control(self, ts: TransportSession) -> None:
        if not self.flow_control or ts.control is None:
            return
        session = ts.session
        delay, budget = self.gateway.delay_estimate(session)
        now = self.loop.now
        if now - ts.last_credit_at < self.credit_min_interval:
            return
        new = ts.duty
        reason = None
        if (delay > self.high_water * budget or math.isinf(delay)) and ts.duty < 1.0:
            new = min(1.0, ts.duty * self.duty_step)
            reason = (
                f"over_budget: predicted {delay:.4f}s > "
                f"{self.high_water:.2f}x budget {budget:.4f}s"
            )
        elif delay < self.low_water * budget and ts.duty > ts.plan_duty:
            new = max(ts.plan_duty, ts.duty / self.duty_step)
        if new == ts.duty:
            return
        ts.duty = new
        ts.last_credit_at = now
        session.credit = ts.plan_duty / new
        if reason is not None:
            session.downshifts += 1
            session.last_downshift_reason = reason
        ts.control(
            encode_control(CREDIT, {"sid": ts.sid, "duty": new, "reason": reason})
        )

    # -- re-homing (ClusterScheduler rehome-owner protocol) ----------------
    def owns(self, request_id: int) -> bool:
        return request_id in self._by_rid

    def rehomed(self, origin_rid: int, tail, slice_name: str) -> None:
        """``fail_slice`` re-admitted this session's tail as an external
        request on ``slice_name``: rebind the session, drain the real
        buffered bytes into the new slice, ask the client to retransmit
        the unresolved window."""
        ts = self._by_rid.pop(origin_rid)
        session = ts.session
        session.request = tail
        old_slice = session.slice_name
        if old_slice is not None:
            self._cohort.get(old_slice, set()).discard(ts.sid)
        session.slice_name = slice_name
        self._cohort.setdefault(slice_name, set()).add(ts.sid)
        session.state = "active"
        session.rehomes += 1
        ts.rehomes += 1
        self._by_rid[tail.request_id] = ts
        self._drain(ts)
        if ts.control is not None:
            ts.control(
                encode_control(
                    REHOME,
                    {"sid": ts.sid, "from_seq": ts.next_seq,
                     "slice": slice_name},
                )
            )

    def expired(self, origin_rid: int) -> None:
        """The parked tail provably expired: the session is over; held
        bytes with nowhere to go are evicted with it."""
        ts = self._by_rid.pop(origin_rid, None)
        if ts is None:
            return
        ts.session.state = "closed"
        ts.finalized = True
        ts.eviction_reason = "tail_expired"
        ts.evicted += self._buffer_clear(ts)
        self._session_done(ts)

    def _on_health(self, name: str, old: str, new: str) -> None:
        self.health_log.append((self.loop.now, name, old, new))
        # Cohort credit aggregation: one degradation event fans ONE
        # CREDIT downshift to every open session homed on the slice,
        # instead of waiting for each session's own delay estimate to
        # trickle over the high-water mark.
        if new == "suspect":
            self._cohort_downshift(name)

    def _cohort_downshift(self, slice_name: str) -> None:
        for sid in sorted(self._cohort.get(slice_name, ())):
            ts = self.sessions.get(sid)
            if ts is None or ts.finalized or ts.control is None:
                continue
            new_duty = min(1.0, ts.duty * self.duty_step)
            if new_duty == ts.duty:
                continue  # already paced at full period
            ts.duty = new_duty
            ts.last_credit_at = self.loop.now
            ts.cohort_downshifts += 1
            session = ts.session
            session.credit = ts.plan_duty / new_duty
            session.downshifts += 1
            session.last_downshift_reason = (
                f"cohort: slice {slice_name} degraded"
            )
            self.cohort_signals += 1
            ts.control(
                encode_control(
                    CREDIT,
                    {"sid": ts.sid, "duty": new_duty,
                     "reason": session.last_downshift_reason},
                )
            )

    # -- session lifecycle enforcement ------------------------------------
    def _arm_sweep(self) -> None:
        """Idle/zombie sweep: visits ONE shard per tick (bounded work),
        cycling the whole table once per ``idle_timeout``. Self-disarms
        when no session is open so a virtual-time ``EventLoop.run()``
        still terminates."""
        if self.idle_timeout is None or self._sweep_armed:
            return
        if self.open_count <= 0:
            return
        self._sweep_armed = True
        interval = self.idle_timeout / self.sessions.n_shards
        self.loop.schedule_in(
            interval, self._lifecycle_tick,
            priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
        )

    def _lifecycle_tick(self) -> None:
        self._sweep_armed = False
        if self.idle_timeout is None:
            return
        shard = self.sessions.shard(self._sweep_shard)
        self._sweep_shard = (self._sweep_shard + 1) % self.sessions.n_shards
        now = self.loop.now
        for ts in list(shard.values()):
            if ts.finalized or ts.session.state == "failover":
                continue
            if now - ts.last_activity > self.idle_timeout:
                reason = (
                    "zombie_idle" if ts.fin_total is None else "fin_timeout"
                )
                self._evict(ts, reason)
        self._arm_sweep()

    def _evict(self, ts: TransportSession, reason: str) -> None:
        """Forcibly retire a session: discard its reorder buffer into
        the ``evicted`` leg and close the gateway session through the
        NORMAL close path, which releases the arena-row lease and
        retires the request from the DisBatcher — so the scheduler
        identity ``completed + dropped + lost == ingested`` holds no
        matter when the eviction lands."""
        if ts.finalized:
            return
        ts.finalized = True
        ts.eviction_reason = reason
        ts.evicted += self._buffer_clear(ts)
        self.evictions += 1
        self._by_rid.pop(ts.session.request_id, None)
        self.gateway.close(ts.session)
        self._session_done(ts)

    def _session_done(self, ts: TransportSession) -> None:
        """Bookkeeping shared by every terminal path (finalize, evict,
        expire): decrement the open count exactly once, leave the
        cohort, and — under ``retain_finalized=False`` — fold the
        session's wire legs into ``retired_totals`` and drop it."""
        if ts.open_counted:
            ts.open_counted = False
            self.open_count -= 1
        slice_name = ts.session.slice_name
        if slice_name is not None:
            self._cohort.get(slice_name, set()).discard(ts.sid)
        if not self.retain_finalized:
            self._retire(ts)

    def _retire(self, ts: TransportSession) -> None:
        if not ts.wire_conserved():
            raise AssertionError(
                f"session {ts.sid} retiring unconserved: "
                f"received={ts.wire_received} delivered={ts.delivered} "
                f"shed={ts.shed} late={ts.late_rejected} "
                f"lost_to_slice={ts.lost_to_slice} dup={ts.duplicates} "
                f"buffered={len(ts.buffer)} refused={ts.refused} "
                f"evicted={ts.evicted}"
            )
        t = self.retired_totals
        t["wire_received"] += ts.wire_received
        t["delivered"] += ts.delivered
        t["shed"] += ts.shed
        t["late_rejected"] += ts.late_rejected
        t["lost_to_slice"] += ts.lost_to_slice
        t["duplicates"] += ts.duplicates
        t["refused"] += ts.refused
        t["evicted"] += ts.evicted
        t["net_lost"] += ts.net_lost
        self.retired_sessions += 1
        self.sessions.pop(ts.sid, None)

    # -- stream completion -------------------------------------------------
    def _on_fin(self, sid: int, total: int) -> None:
        ts = self.sessions.get(sid)
        if ts is None or ts.finalized:
            return
        ts.fin_total = total
        self.loop.schedule_in(
            self._timeout(ts),
            lambda: self._finalize(ts),
            priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
        )

    def _finalize(self, ts: TransportSession) -> None:
        if ts.finalized:
            return
        if ts.session.state == "failover":
            # Tail still parked: re-homing or expiry resolves it in
            # bounded time; check again after another grace window.
            self.loop.schedule_in(
                self._timeout(ts),
                lambda: self._finalize(ts),
                priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
            )
            return
        ts.finalized = True
        session = ts.session
        total = ts.fin_total if ts.fin_total is not None else ts.n_frames
        if session.state == "active":
            for seq in range(ts.next_seq, total):
                if seq in ts.buffer:
                    payload, _at = self._buffer_pop(ts, seq)
                    self._deliver(ts, seq, payload)
                else:
                    self._account_lost(ts, seq)
        # Remnants past the FIN total (an adversarial FIN can understate
        # it) are evicted, not vanished — wire_conserved() must hold.
        ts.evicted += self._buffer_clear(ts)
        sl = self.gateway._slice_of(session)
        if sl is not None:
            # Period-arithmetic tails can leave a residual lease count;
            # the stream is over, so the arena row frees now.
            sl.release(session.request_id)
        if session.state == "active":
            sched = self.gateway._scheduler_of(session)
            sched.disbatcher.remove_request(session.request)
            session.state = "closed"
        self._session_done(ts)

    def finalize_all(self) -> None:
        """Resolve every open session's tail (benchmark/test epilogue for
        runs whose FIN was consumed by the chaos plan or never sent)."""
        for ts in list(self.sessions.values()):
            self._finalize(ts)

    # -- graceful drain ----------------------------------------------------
    def drain(self, grace: Optional[float] = None) -> None:
        """Stop taking new sessions and wind the server down: new HELLOs
        are refused immediately (``accepted: False, reason: draining``),
        in-flight frames keep flowing for one grace window (default: the
        longest reorder timeout any open session could still need), then
        every open session is finalized and conservation is asserted."""
        self.draining = True
        if grace is None:
            grace = 0.0
            for ts in self.sessions.values():
                if not ts.finalized:
                    grace = max(grace, self._timeout(ts))
        self.loop.schedule_in(
            grace, self._drain_finish,
            priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
        )

    def _drain_finish(self) -> None:
        self.finalize_all()
        for ts in self.sessions.values():
            if not ts.wire_conserved():
                raise AssertionError(
                    f"drain left session {ts.sid} unconserved"
                )
        self.drained = True

    def assert_conserved(self) -> None:
        """Prove both conservation identities at quiescence: every wire
        datagram in exactly one leg (live sessions + retired fold), and
        the scheduler identity ``completed + dropped + lost ==
        ingested`` on the target. Call after the loop has run dry."""
        for ts in self.sessions.values():
            if not ts.wire_conserved():
                raise AssertionError(f"session {ts.sid} unconserved")
        t = self.retired_totals
        resolved = (
            t["delivered"] + t["shed"] + t["late_rejected"]
            + t["lost_to_slice"] + t["duplicates"] + t["refused"]
            + t["evicted"]
        )
        if t["wire_received"] != resolved:
            raise AssertionError(
                f"retired fold unconserved: {t['wire_received']} received "
                f"vs {resolved} resolved"
            )
        target = self.gateway.target
        if hasattr(target, "aggregate_metrics"):
            agg = target.aggregate_metrics()
            lhs = (
                agg["completed_frames"] + agg["dropped_frames"]
                + agg["lost_frames"]
            )
            rhs = agg["ingested_frames"]
        else:
            m = target.metrics
            lhs = m.completed_frames + m.dropped_frames + m.lost_frames
            rhs = m.ingested_frames
        if lhs != rhs:
            raise AssertionError(
                f"scheduler identity broken: completed+dropped+lost={lhs} "
                f"!= ingested={rhs}"
            )

    # -- observability (scrapeable JSON snapshot) --------------------------
    def telemetry(self) -> Dict:
        """Bounded (O(1)-sized) lifecycle counter block. Registered as
        the cluster's ``transport`` telemetry probe, and embedded in
        every ``status()`` reply."""
        return {
            "sessions": len(self.sessions),
            "open_sessions": self.open_count,
            "retired_sessions": self.retired_sessions,
            "evictions": self.evictions,
            "draining": self.draining,
            "drained": self.drained,
            "reassembly_bytes": self.reassembly_bytes,
            "reassembly_peak_bytes": self.reassembly_peak_bytes,
            "reassembly_budget_bytes": self.reassembly_budget_bytes,
            "budget_refusals": self.budget_refusals,
            "malformed": self.malformed,
            "malformed_by_reason": dict(self.malformed_by_reason),
            "hellos_seen": self.hellos_seen,
            "hellos_accepted": self.hellos_accepted,
            "hellos_rejected": self.hellos_rejected,
            "hello_retries_sent": self.hello_retries_sent,
            "hello_refused_draining": self.hello_refused_draining,
            "cohort_signals": self.cohort_signals,
            "retired_totals": dict(self.retired_totals),
        }

    def _session_summary(self, top_k: int = 8) -> Dict:
        """Aggregate view that stays bounded at thousands of sessions:
        whole-table counter sums, a state histogram, and only the top-K
        worst sessions (by unresolved/penalty legs) in full detail."""
        agg = {
            "wire_received": 0, "delivered": 0, "shed": 0,
            "late_rejected": 0, "net_lost": 0, "lost_to_slice": 0,
            "duplicates": 0, "buffered": 0, "refused": 0, "evicted": 0,
        }
        states: Dict[str, int] = {}
        violations = 0
        scored: List[Tuple[int, int]] = []
        for sid, ts in self.sessions.items():
            agg["wire_received"] += ts.wire_received
            agg["delivered"] += ts.delivered
            agg["shed"] += ts.shed
            agg["late_rejected"] += ts.late_rejected
            agg["net_lost"] += ts.net_lost
            agg["lost_to_slice"] += ts.lost_to_slice
            agg["duplicates"] += ts.duplicates
            agg["buffered"] += len(ts.buffer)
            agg["refused"] += ts.refused
            agg["evicted"] += ts.evicted
            st = ts.session.state
            states[st] = states.get(st, 0) + 1
            if not ts.wire_conserved():
                violations += 1
            score = (
                ts.net_lost + ts.shed + ts.late_rejected + ts.refused
                + ts.evicted + ts.lost_to_slice
            )
            if score:
                scored.append((score, sid))
        scored.sort(reverse=True)
        worst = {}
        for score, sid in scored[:top_k]:
            ts = self.sessions[sid]
            worst[str(sid)] = {
                "score": score,
                "state": ts.session.state,
                "slice": ts.session.slice_name,
                "eviction_reason": ts.eviction_reason,
                "wire": {
                    "received": ts.wire_received,
                    "delivered": ts.delivered,
                    "shed": ts.shed,
                    "late_rejected": ts.late_rejected,
                    "net_lost": ts.net_lost,
                    "refused": ts.refused,
                    "evicted": ts.evicted,
                },
            }
        return {
            "count": len(self.sessions),
            "states": states,
            "wire_totals": agg,
            "conservation_violations": violations,
            "worst": worst,
        }

    def status(self, summary: bool = False, top_k: int = 8) -> Dict:
        target = self.gateway.target
        out: Dict = {
            "now": self.loop.now,
            "flow_control": self.flow_control,
            "transport": self.telemetry(),
            "health_transitions": [
                {"t": t, "slice": n, "old": o, "new": w}
                for t, n, o, w in self.health_log
            ],
        }
        if summary:
            out["session_summary"] = self._session_summary(top_k)
            return self._status_target(out, target)
        out["sessions"] = {}
        for sid, ts in self.sessions.items():
            s = ts.session
            out["sessions"][str(sid)] = {
                "state": s.state,
                "slice": s.slice_name,
                "request_id": s.request_id,
                "credit": s.credit,
                "duty": ts.duty,
                "rehomes": ts.rehomes,
                "downshifts": s.downshifts,
                "last_downshift_reason": s.last_downshift_reason,
                "last_shed_reason": s.last_shed_reason,
                "gateway": {
                    "ingested": s.frames_ingested,
                    "delivered": s.frames_delivered,
                    "dropped": s.frames_dropped,
                    "lost": s.frames_lost,
                },
                "wire": {
                    "received": ts.wire_received,
                    "delivered": ts.delivered,
                    "shed": ts.shed,
                    "duplicates": ts.duplicates,
                    "late_rejected": ts.late_rejected,
                    "net_lost": ts.net_lost,
                    "lost_to_slice": ts.lost_to_slice,
                    "buffered": len(ts.buffer),
                    "refused": ts.refused,
                    "evicted": ts.evicted,
                    "conserved": ts.wire_conserved(),
                },
            }
        return self._status_target(out, target)

    def _status_target(self, out: Dict, target) -> Dict:
        slices = getattr(target, "slices", None)
        if slices is not None:
            out["slices"] = {}
            for name, sl in slices.items():
                m = sl.scheduler.metrics
                out["slices"][name] = {
                    "health": sl.health,
                    "alive": sl.alive,
                    "utilization": sl.utilization(),
                    "slow_factor": sl.slow_factor,
                    "completed": m.completed_frames,
                    "missed": m.missed_frames,
                    "delivered": m.delivered_frames,
                    "dropped": m.dropped_frames,
                    "lost": m.lost_frames,
                    "duplicate_completions": m.duplicate_completions,
                }
        else:
            m = target.metrics
            out["scheduler"] = {
                "completed": m.completed_frames,
                "missed": m.missed_frames,
                "delivered": m.delivered_frames,
                "dropped": m.dropped_frames,
                "lost": m.lost_frames,
                "duplicate_completions": m.duplicate_completions,
            }
        # Unified telemetry: the cluster's full snapshot (slice health,
        # histograms, probes, miss attribution) rides the same STATUS
        # reply. The embedding is one-way — the snapshot never embeds
        # transport state, so there is no recursion.
        if hasattr(target, "telemetry_snapshot"):
            out["telemetry"] = target.telemetry_snapshot()
        elif self.tracer is not None:
            out["telemetry"] = {
                "tracer": self.tracer.snapshot(),
                "attribution": self.tracer.attribution(),
            }
        return out

    def status_json(self, summary: Optional[bool] = None) -> str:
        """JSON snapshot; ``summary=None`` auto-switches to the bounded
        summary form once the table is large enough that per-session
        detail would blow past a datagram-sized STATUS reply."""
        if summary is None:
            summary = len(self.sessions) > 64
        return json.dumps(self.status(summary=summary), sort_keys=True)


# ---------------------------------------------------------------------------
# Thin real-socket binding (live WallClock path)
# ---------------------------------------------------------------------------

class UdpServerBinding:
    """UDP front door over the same codec: a receive thread forwards
    datagrams onto the loop thread (``WallClock.post``), so the
    TransportServer's state is only ever touched on the loop thread —
    exactly the AsyncDevice completion convention. HELLO opens sessions
    (control replies go back to the sender's address) and a STATUS probe
    returns the scrapeable JSON snapshot."""

    def __init__(self, transport: TransportServer, host: str = "127.0.0.1",
                 port: int = 0):
        if not hasattr(transport.loop, "post"):
            raise ValueError(
                "UdpServerBinding needs a WallClock loop (thread-safe post); "
                "simulated runs use SimLink instead"
            )
        self.transport = transport
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self.sock.bind((host, port))
        self.sock.settimeout(0.1)
        self.addr = self.sock.getsockname()
        self.rx_errors = 0  # dispatch exceptions survived by the rx loop
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._rx, name="drt-udp-server", daemon=True
        )

    def start(self) -> "UdpServerBinding":
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)
        self.sock.close()

    def _reply_fn(self, addr) -> Callable[[bytes], None]:
        def _send(data: bytes) -> None:
            try:
                self.sock.sendto(data, addr)
            except OSError:
                pass  # client went away; control traffic is best-effort
        return _send

    def _rx(self) -> None:
        while not self._stop.is_set():
            try:
                data, addr = self.sock.recvfrom(65535)
            except socket.timeout:
                continue
            except OSError:
                return
            # The rx thread must be unkillable by wire content: ANY
            # dispatch failure is counted and the loop continues. (A
            # single garbage datagram used to terminate this thread.)
            try:
                self._dispatch(data, addr)
            except Exception:
                self.rx_errors += 1
                self.transport.loop.post(
                    lambda: self.transport.note_malformed("rx_dispatch_error"),
                    priority=getattr(self.transport.loop, "PRIO_ARRIVAL", 0),
                )

    def _dispatch(self, data: bytes, addr) -> None:
        mtype, body = decode(data)
        if mtype == MALFORMED:
            self.transport.loop.post(
                lambda body=body: self.transport.note_malformed(body),
                priority=getattr(self.transport.loop, "PRIO_ARRIVAL", 0),
            )
        elif mtype == HELLO:
            self.transport.loop.post(
                lambda body=body, addr=addr: self._hello(body, addr),
                priority=getattr(self.transport.loop, "PRIO_ARRIVAL", 0),
            )
        elif mtype == STATUS:
            blob = self.transport.status_json().encode()[:60000]
            self._reply_fn(addr)(_HEADER.pack(MAGIC, STATUS_REPLY) + blob)
        else:
            self.transport.loop.post(
                lambda data=data: self.transport.datagram(data),
                priority=getattr(self.transport.loop, "PRIO_ARRIVAL", 0),
            )

    def _hello(self, body: Dict, addr) -> None:
        # All body validation/gating lives in TransportServer.hello();
        # the binding only wires the reply path.
        reply = self._reply_fn(addr)
        reply(self.transport.hello(body, control=reply))


class UdpClientLink:
    """Client-side socket shim exposing the SimLink ``send`` interface
    (chaos is the real network's job here) plus a receive thread that
    forwards server control messages to the TransportSource."""

    def __init__(self, loop, server_addr: Tuple[str, int]):
        if not hasattr(loop, "post"):
            raise ValueError("UdpClientLink needs a WallClock loop")
        self.loop = loop
        self.server_addr = server_addr
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self.sock.bind(("127.0.0.1", 0))
        self.sock.settimeout(0.1)
        self._stop = threading.Event()
        self._source: Optional[TransportSource] = None
        self._hello_reply: Optional[Tuple[int, Dict]] = None  # (mtype, body)
        self._ack_event = threading.Event()
        self._thread = threading.Thread(
            target=self._rx, name="drt-udp-client", daemon=True
        )
        self._thread.start()

    def send(self, data: bytes, chaos: bool = True) -> None:
        try:
            self.sock.sendto(data, self.server_addr)
        except OSError:
            pass

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)
        self.sock.close()

    def handshake(self, source: TransportSource, timeout: float = 2.0,
                  retries: int = 3) -> Tuple[Optional[int], bool]:
        """HELLO/HELLO_ACK over the socket (retried: the live wire may
        genuinely drop the handshake)."""
        self._source = source
        body = {
            "model_id": source.category.model_id,
            "shape_key": list(source.category.shape_key),
            "realtime": source.category.realtime,
            "period": source.source.period,
            "n_frames": source.source.n_frames,
            "relative_deadline": source.relative_deadline,
            "duty": source.plan_duty,
        }
        for _ in range(retries):
            self._ack_event.clear()
            self.send(encode_control(HELLO, body), chaos=False)
            if not self._ack_event.wait(timeout):
                continue
            mtype, ack = self._hello_reply
            if mtype == HELLO_RETRY:
                # Gated, not refused: honor the signaled backoff and
                # spend another retry.
                time.sleep(min(float(ack.get("backoff", 0.05)), timeout))
                continue
            return int(ack["sid"]), bool(ack["accepted"])
        return None, False

    def _rx(self) -> None:
        while not self._stop.is_set():
            try:
                data, _addr = self.sock.recvfrom(65535)
            except socket.timeout:
                continue
            except OSError:
                return
            mtype, body = decode(data)
            if mtype == MALFORMED:
                continue
            if mtype in (HELLO_ACK, HELLO_RETRY):
                self._hello_reply = (mtype, body)
                self._ack_event.set()
            elif mtype in (CREDIT, REHOME) and self._source is not None:
                self.loop.post(
                    lambda data=data: self._source.control(data),
                    priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
                )
