"""Frame sources: where real payload bytes come from (paper §3.1).

DeepRT's clients are periodic soft-real-time streams — smartphone and
IoT cameras pushing frames at a nominal rate that reality never quite
honors. A ``FrameSource`` models one client stream as a DETERMINISTIC
plan: a finite sequence of ``(offset_seconds, payload)`` pairs, fully
determined by the source's seed. Determinism is the load-bearing
property — the gateway schedules the same plan onto a virtual
``EventLoop`` (simulation over ``SequentialDevice``) or a ``WallClock``
(live serving), and the two runs ingest bit-identical bytes at
bit-identical stream offsets. Sources hold no clock and no mutable
iteration state; ``plan()`` can be re-materialized any number of times.

Three shapes, matching the arrival patterns the paper's edge setting
actually sees:

- ``CameraSource``  — jittery periodic: frame i at ``i*period`` plus
  bounded uniform jitter (|jitter| <= jitter_frac * period / 2, so
  arrival order is preserved). The surveillance-camera workload.
- ``BurstSource``   — WebRTC-like on/off process: frames arrive in
  back-to-back bursts separated by silence. The DECLARED period (what
  admission is told, ``period``) still averages out over the whole
  stream when ``duty=1.0``; ``duty < 1`` compresses the same frame
  count into a fraction of the time — a stream whose instantaneous
  rate exceeds its admitted rate by 1/duty, which is exactly the
  overload the gateway's load shedding exists for.
- ``TraceSource``   — replay of a ``core.traces`` request: offsets at
  the trace's Gamma-sampled period, payloads from the trace seed. The
  bridge from the paper's synthetic trace experiments to real bytes.

Payloads are int32 token arrays for the LM categories this repo serves:
prefill frames carry ``(seq,)`` tokens, decode frames carry one token
(shape ``()``). ``payload_shape`` picks which.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Request
from repro.core.traces import TraceSpec, generate_trace

DEFAULT_VOCAB = 256  # payload token range; tiny() configs all exceed it


@dataclass(frozen=True)
class FramePlan:
    """One planned frame: stream offset (seconds from session start) and
    the payload bytes that 'arrive' at that instant."""

    offset: float
    payload: np.ndarray


class FrameSource:
    """Deterministic finite stream plan. Subclasses implement
    ``_offsets``; payload generation is shared (seeded per frame index,
    so payload i is independent of how offsets were produced)."""

    def __init__(
        self,
        period: float,
        n_frames: int,
        payload_shape: Sequence[int] = (),
        vocab: int = DEFAULT_VOCAB,
        seed: int = 0,
    ):
        if period <= 0:
            raise ValueError(f"period must be positive, got {period}")
        if n_frames <= 0:
            raise ValueError(f"n_frames must be positive, got {n_frames}")
        if vocab < 2:
            raise ValueError(f"vocab must be >= 2, got {vocab}")
        self.period = float(period)  # the DECLARED (admission-visible) rate
        self.n_frames = int(n_frames)
        self.payload_shape = tuple(int(d) for d in payload_shape)
        self.vocab = int(vocab)
        self.seed = int(seed)

    # -- plan -----------------------------------------------------------
    def _offsets(self) -> List[float]:
        raise NotImplementedError

    def payload(self, index: int) -> np.ndarray:
        """Frame ``index``'s payload bytes — pure function of (seed, index)."""
        rng = np.random.default_rng((self.seed, index))
        return rng.integers(
            0, self.vocab, size=self.payload_shape, dtype=np.int32
        )

    def plan(self) -> List[FramePlan]:
        """The full arrival plan, re-materializable and deterministic."""
        offsets = self._offsets()
        if len(offsets) != self.n_frames:
            raise AssertionError(
                f"{type(self).__name__} planned {len(offsets)} offsets "
                f"for n_frames={self.n_frames}"
            )
        if any(b < a for a, b in zip(offsets, offsets[1:])):
            raise AssertionError(f"{type(self).__name__} offsets not sorted")
        return [FramePlan(off, self.payload(i)) for i, off in enumerate(offsets)]

    def __iter__(self) -> Iterator[FramePlan]:
        return iter(self.plan())


class PeriodicSource(FrameSource):
    """Strict-periodic stream: frame i at exactly ``i * period``. The
    declared contract with zero jitter — the transport layer's baseline
    client and the stand-in the server builds from a HELLO's declared
    (period, n_frames) when admission-testing a remote stream."""

    def _offsets(self) -> List[float]:
        return [i * self.period for i in range(self.n_frames)]


class CameraSource(FrameSource):
    """Jittery periodic camera: frame i at ``i*period + U(-j, +j)`` with
    ``j = jitter_frac * period / 2`` — jitter never reorders frames and
    never moves frame 0 before the session start."""

    def __init__(self, *args, jitter_frac: float = 0.2, **kwargs):
        super().__init__(*args, **kwargs)
        if not 0.0 <= jitter_frac < 1.0:
            raise ValueError(
                f"jitter_frac must be in [0, 1), got {jitter_frac}"
            )
        self.jitter_frac = float(jitter_frac)

    def _offsets(self) -> List[float]:
        # str seeding is deterministic across processes (tuple seeding
        # would fall back to hash(), which PYTHONHASHSEED randomizes).
        rng = random.Random(f"camera-{self.seed}")
        half = self.jitter_frac * self.period / 2.0
        return [
            max(0.0, i * self.period + rng.uniform(-half, half))
            for i in range(self.n_frames)
        ]


class BurstSource(FrameSource):
    """On/off bursty stream (WebRTC-like network source).

    Frames come in groups of ``burst``; burst k starts at
    ``k * burst * period * duty``, so the stream delivers its declared
    mean rate 1/period when ``duty=1.0`` and compresses the SAME frame
    budget into a ``duty`` fraction of the time otherwise (mean rate
    ``1/(period*duty)``). ``duty=0.5`` is the benchmark's 2x overload
    replay: the whole admitted frame budget arrives in half the
    admitted time.
    """

    def __init__(
        self,
        *args,
        burst: int = 4,
        duty: float = 1.0,
        intra_frac: float = 0.25,
        **kwargs,
    ):
        super().__init__(*args, **kwargs)
        if burst < 1:
            raise ValueError(f"burst must be >= 1, got {burst}")
        if not 0.0 < duty <= 1.0:
            raise ValueError(f"duty must be in (0, 1], got {duty}")
        if not 0.0 < intra_frac <= 1.0:
            raise ValueError(f"intra_frac must be in (0, 1], got {intra_frac}")
        self.burst = int(burst)
        self.duty = float(duty)
        # Intra-burst spacing as a fraction of the EFFECTIVE period; must
        # stay below duty so a burst finishes before the next one starts.
        self.intra_frac = float(min(intra_frac, duty))

    def _offsets(self) -> List[float]:
        eff = self.period * self.duty  # mean spacing the stream really has
        burst_stride = self.burst * self.period  # declared-rate spacing of bursts
        intra = eff * self.intra_frac
        out: List[float] = []
        for i in range(self.n_frames):
            k, j = divmod(i, self.burst)
            out.append(k * burst_stride * self.duty + j * intra)
        return out


class TraceSource(FrameSource):
    """Replay one ``core.traces`` request as a payload-carrying stream:
    strict-periodic offsets at the trace's sampled period."""

    def __init__(
        self,
        request: Request,
        payload_shape: Sequence[int] = (),
        vocab: int = DEFAULT_VOCAB,
        seed: Optional[int] = None,
    ):
        super().__init__(
            period=request.period,
            n_frames=request.n_frames,
            payload_shape=payload_shape,
            vocab=vocab,
            seed=request.request_id if seed is None else seed,
        )
        self.request = request

    def _offsets(self) -> List[float]:
        return [i * self.period for i in range(self.n_frames)]

    @classmethod
    def from_trace(
        cls,
        spec: TraceSpec,
        payload_shape: Sequence[int] = (),
        vocab: int = DEFAULT_VOCAB,
    ) -> List[Tuple[Request, "TraceSource"]]:
        """One (request, source) pair per trace entry; the request keeps
        its trace start_time, the source's offsets are relative to it."""
        return [
            (req, cls(req, payload_shape=payload_shape, vocab=vocab, seed=i))
            for i, req in enumerate(generate_trace(spec))
        ]
