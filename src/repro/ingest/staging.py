"""Double-buffered host->device staging rings (the ingest gateway's
byte path into the engine).

Real ingestion means every dispatched step carries payload bytes that
arrived over the wire moments earlier. The naive implementation
allocates a fresh host array per step (allocator traffic on the hot
loop) or reuses ONE buffer (a data race the instant an upload is
asynchronous or zero-copy: the next job's fill would overwrite bytes
the in-flight program is still reading). A ``StagingRing`` fixes both:

- ``depth`` host scratch buffers are allocated ONCE and cycled
  round-robin — steady-state staging performs ZERO fresh host
  allocations (``host_allocs`` stays equal to ``depth`` forever; the
  bench smoke asserts it);
- fill and flight never share a buffer: job N fills (and uploads from)
  scratch ``N % depth``, job N+1 fills scratch ``(N+1) % depth`` — with
  the default ``depth=2`` that is exactly "fill buffer B while the
  in-flight program reads A". On backends where ``device_put`` copies
  synchronously (cpu today) the rotation is belt-and-braces; on
  backends with zero-copy or deferred host reads it is the correctness
  mechanism, so the ring never assumes the copy.

The ring bounds how many staged jobs may be simultaneously in flight at
``depth - 1`` (one buffer is always the fill target), and it ENFORCES
that bound: the caller attaches each staged buffer's consumer (the
dispatched step's ``wait``), and ``stage`` waits for a scratch's
previous consumer before refilling it. Zero-copy uploads make this
load-bearing — ``jax.device_put`` of an aligned numpy array on the cpu
backend BORROWS the host memory (observed on this container's jax:
whether it copies is alignment-dependent), so "the upload copied, reuse
is fine" is never a safe assumption. With the guard, a caller that
pipelines deeper than ``depth - 1`` degrades to a bounded wait instead
of silently corrupting an in-flight job's tokens. The EDF worker's
submit-only-when-idle discipline keeps at most one job in flight per
device, so ``depth=2`` serves the hot path with the guard never
blocking; pipelined callers size ``depth`` up at engine construction.

Byte accounting: ``fills`` / ``bytes_staged`` are the ring's lifetime
host->device traffic — ``benchmarks/ingest_serving.py`` reports the
steady-state bytes/step from them.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import numpy as np


def check_payload_dtype(arr: np.ndarray, dtype: np.dtype) -> None:
    """Reject payloads whose dtype would be silently mangled by the
    staging cast (e.g. raw float frame data handed to an int32 token
    ring): only same-kind casts (int -> int) are accepted, so a
    malformed payload fails at the gateway boundary, not as garbage
    tokens inside a compiled program."""
    if not np.can_cast(arr.dtype, dtype, casting="same_kind"):
        raise ValueError(
            f"payload dtype {arr.dtype} cannot safely stage as {dtype}"
        )


class StagingRing:
    """A fixed pool of host scratch buffers cycled round-robin.

    ``shape``/``dtype`` are the staged array's device shape — one ring
    per compiled program input (the engine keys rings by
    ``(kind, mid, seq, batch)``).
    """

    def __init__(
        self,
        shape: Sequence[int],
        dtype=np.int32,
        depth: int = 2,
    ):
        if depth < 2:
            raise ValueError(
                f"staging ring depth must be >= 2 (fill + in-flight), got {depth}"
            )
        self.shape: Tuple[int, ...] = tuple(int(d) for d in shape)
        self.dtype = np.dtype(dtype)
        self.depth = depth
        self._scratch = [np.zeros(self.shape, self.dtype) for _ in range(depth)]
        self._next = 0
        self._last_slot: Optional[int] = None
        # Per-scratch consumer guard: wait callables for the job that
        # last consumed each buffer (see ``attach_consumer``).
        self._consumers: list = [None] * depth
        # Lifetime counters (the reuse / traffic acceptance bars).
        self.host_allocs = depth  # never grows after construction
        self.fills = 0
        self.bytes_staged = 0
        self.consumer_waits = 0  # guard invocations before a refill

    @property
    def frame_nbytes(self) -> int:
        """Bytes uploaded per fill (one staged program input)."""
        return int(self._scratch[0].nbytes)

    @property
    def capacity(self) -> int:
        """Stages that may be in flight behind ONE consumer: depth - 1.

        A multi-step decode chunk stages one ring slot per step and
        attaches the SAME consumer (the chunk's completion) to each, so
        a k-step chunk needs ``k <= capacity`` — were k to reach depth,
        the k-th stage would wrap onto a slot whose guard is the chunk's
        own not-yet-dispatched wait and deadlock (or worse, overwrite a
        sibling step's bytes on a zero-copy backend). The engine sizes
        decode rings to ``max_chunk_depth + 1`` and validates against
        this property at dispatch.
        """
        return self.depth - 1

    def stage(self, fill_fn: Callable[[np.ndarray], None]) -> jax.Array:
        """Fill the next scratch buffer in place and upload it.

        If a consumer is attached to this scratch (a step dispatched
        ``depth`` fills ago), its ``wait`` runs FIRST — the refill never
        races a program still reading the buffer, even on zero-copy
        backends. ``fill_fn(scratch)`` must write the COMPLETE buffer
        contents it cares about (the scratch still holds the bytes from
        ``depth`` fills ago — the ring never zeroes for you, because
        blanket zeroing would hide partial-fill bugs AND cost a full
        extra pass per step). Returns the device array the compiled
        step consumes.
        """
        slot = self._next
        self._next = (slot + 1) % self.depth
        guard = self._consumers[slot]
        if guard is not None:
            self._consumers[slot] = None
            self.consumer_waits += 1
            guard()
        buf = self._scratch[slot]
        fill_fn(buf)
        self.fills += 1
        self.bytes_staged += buf.nbytes
        self._last_slot = slot
        return jax.device_put(buf)

    def attach_consumer(self, wait_fn: Callable[[], object]) -> None:
        """Register the consumer of the MOST RECENTLY staged buffer.

        ``wait_fn`` must block until the consuming step has finished
        reading the staged input (the engine passes the dispatched
        ``StepHandle.wait``, which blocks on the step's outputs — by
        then the inputs are consumed). The guard runs at most once, on
        the fill that wants the scratch back.
        """
        if self._last_slot is None:
            raise RuntimeError("attach_consumer before any stage()")
        self._consumers[self._last_slot] = wait_fn

    def stage_rows(
        self, rows: Optional[np.ndarray], n_rows: int
    ) -> jax.Array:
        """Stage ``rows`` into the leading ``n_rows`` slots, zero the rest.

        ``rows=None`` stages an all-zero buffer (the profiler's payload —
        WCET is payload-independent; this is the ONE staging path, not a
        synthetic side branch). Raises on shape/dtype mismatches so a
        malformed payload fails at the gateway boundary, not as silent
        garbage tokens inside a compiled program.
        """
        if n_rows < 0 or n_rows > self.shape[0]:
            raise ValueError(
                f"n_rows {n_rows} outside staged batch axis {self.shape[0]}"
            )
        arr: Optional[np.ndarray] = None
        if rows is not None:
            arr = np.asarray(rows)
            if arr.shape != (n_rows,) + self.shape[1:]:
                raise ValueError(
                    f"payload shape {arr.shape} != expected "
                    f"{(n_rows,) + self.shape[1:]} for ring {self.shape}"
                )
            check_payload_dtype(arr, self.dtype)

        def fill(buf: np.ndarray) -> None:
            if arr is None:
                buf[:] = 0
                return
            buf[:n_rows] = arr.astype(self.dtype, copy=False)
            buf[n_rows:] = 0

        return self.stage(fill)
