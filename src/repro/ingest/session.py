"""Streaming ingestion gateway: bytes-arrive -> staged device buffer.

The missing front half of the serving pipeline. PRs 1-3 built everything
from the DisBatcher down (windows, EDF, slot arenas, cluster slices) but
fed it synthetic frames conjured by the scheduler itself. This module
owns the REQUEST PATH:

  FrameSource --(payload, arrival)--> StreamSession --admission/lease-->
    DeepRT.ingest_frame --DisBatcher/EDF--> engine staging ring --> device

- ``register`` runs the full stream lifecycle entry: build the Request
  from the source's declared rate, place + admission-test it through the
  EXISTING path (``ClusterScheduler.submit_request`` with per-slice
  placement and arena-row leases, or a single ``DeepRT``), and schedule
  the source's deterministic arrival plan on the scheduler's loop — the
  same plan lands identically on a virtual ``EventLoop`` (simulation)
  and a ``WallClock`` (live serving).
- Each arriving frame is deadline-stamped AT ARRIVAL
  (``DeepRT.ingest_frame``), its payload riding the Frame into the
  engine's double-buffered staging ring at dispatch.
- BACKPRESSURE + LOAD SHEDDING: before delivering, the gateway estimates
  the frame's queueing delay (device tail + queued EDF work + window
  residue + its own batch WCET). If that exceeds the session's deadline
  budget — tightened by ``AdaptationModule.shed_scale`` while the
  category carries overrun penalty — the frame is shed per the
  category's ``ShedPolicy`` (drop, or keep-1-in-k subsampling: the
  paper's resolution shrink translated to the arrival axis). Every shed
  frame is accounted in ``Metrics`` (``record_drop``), reported to the
  adaptation module (``note_shed``), and counted against the stream's
  arena-row lease (``note_dropped``) so leases still release when
  truncated streams drain. Nothing silently vanishes:
  ``ingested == delivered + dropped`` per session, and
  ``metrics.completed + metrics.dropped == metrics.ingested`` for a
  drained run.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from repro.core import telemetry as T
from repro.core.cluster import QUARANTINED
from repro.core.request import Category, Request
from repro.ingest.sources import FrameSource


@dataclass(frozen=True)
class ShedPolicy:
    """Per-category arrival-side degradation policy.

    ``budget_fraction``: queueing-delay budget as a fraction of the
    stream's relative deadline — a frame predicted to wait longer than
    this before completing is already a deadline miss in the making, so
    it is degraded at the door instead of wasting device time.
    ``mode="drop"`` sheds every over-budget frame; ``mode="subsample"``
    keeps 1 in ``keep`` while over budget (a camera degrading to a lower
    frame rate rather than going dark).
    """

    budget_fraction: float = 1.0
    mode: str = "drop"
    keep: int = 2

    def __post_init__(self) -> None:
        if not 0.0 < self.budget_fraction:
            raise ValueError(
                f"budget_fraction must be positive, got {self.budget_fraction}"
            )
        if self.mode not in ("drop", "subsample"):
            raise ValueError(f"unknown shed mode {self.mode!r}")
        if self.keep < 2:
            raise ValueError(f"subsample keep must be >= 2, got {self.keep}")


@dataclass
class StreamSession:
    """One client stream's lifecycle: register -> stream -> close."""

    source: FrameSource
    request: Request
    # pending | active | rejected | closed | failover (slice quarantined:
    # deliveries stopped; the cluster re-admits the stream's tail as a
    # synthetic request on a surviving slice)
    state: str = "pending"
    slice_name: Optional[str] = None  # cluster placement (None: single)
    frames_ingested: int = 0  # bytes that arrived at the gateway
    frames_delivered: int = 0  # handed to the scheduler
    frames_dropped: int = 0  # shed at the gateway
    frames_lost: int = 0  # destroyed on the wire (transport-declared)
    # Observable backpressure state (why did a frame vanish?): the
    # transport's flow controller updates credit/downshifts, the shedder
    # stamps last_shed_reason, re-homing counts rehomes.
    credit: float = 1.0  # plan_duty / current duty (1.0 = full rate)
    downshifts: int = 0
    last_downshift_reason: Optional[str] = None
    last_shed_reason: Optional[str] = None
    # Per-term breakdown of the most recent delay estimate (device tail
    # incl. any in-flight chunk residue, queued WCET, window wait, batch
    # WCET) — stamped by ``IngestGateway.delay_estimate``.
    last_delay_breakdown: Optional[Dict[str, float]] = None
    rehomes: int = 0
    # PENDING arrival event ids only: each delivery prunes itself on
    # fire, so close() cancels exactly the undelivered tail (cancelling
    # fired ids would leak them into the loop's cancelled-set forever).
    _events: Set[int] = field(default_factory=set)
    _shed_phase: int = 0  # subsampling counter while over budget

    @property
    def request_id(self) -> int:
        return self.request.request_id

    def conserved(self) -> bool:
        """Arrival accounting invariant: nothing silently vanishes."""
        return self.frames_ingested == self.frames_delivered + self.frames_dropped


class IngestGateway:
    """Gateway over a single ``DeepRT`` or a ``ClusterScheduler``.

    ``policies`` maps ``Category`` -> ``ShedPolicy`` (``default_policy``
    otherwise); ``shedding=False`` disables the shedder entirely (the
    benchmark's no-shedding arm — frames then queue and miss instead).

    Slice health is surfaced to sessions: the gateway subscribes to the
    cluster's ``SliceHealthMonitor``. A QUARANTINED slice's sessions are
    moved to ``failover`` (deliveries stop — the slice is dead and its
    tails re-admitted elsewhere by the cluster), and a SUSPECT slice's
    sessions shed earlier because the health monitor holds that
    scheduler's adaptation module degraded
    (``AdaptationModule.DEGRADED_BUDGET_TIGHTEN`` flows through the
    ``shed_scale`` the budget already divides by).
    """

    def __init__(
        self,
        target,
        policies: Optional[Dict[Category, ShedPolicy]] = None,
        default_policy: ShedPolicy = ShedPolicy(),
        shedding: bool = True,
    ):
        self.target = target
        self.loop = target.loop
        self.policies = dict(policies or {})
        self.default_policy = default_policy
        self.shedding = shedding
        self.sessions: List[StreamSession] = []
        # Frame-lifecycle tracer (core/telemetry.py); None = off. Shed
        # verdicts are emitted here because the gateway is the only
        # component that knows WHY a frame never reached the scheduler.
        self.tracer = None
        self._is_cluster = hasattr(target, "slices")
        health = getattr(target, "health", None)
        if self._is_cluster and health is not None:
            health.subscribe(self._on_slice_health)

    # -- lifecycle --------------------------------------------------------
    def register(
        self,
        source: FrameSource,
        category: Category,
        relative_deadline: float,
        start_in: float = 0.0,
        schedule_arrivals: bool = True,
    ) -> StreamSession:
        """Admission-test and start one stream.

        The Request presented to placement/admission carries the
        source's DECLARED period — admission reasons about the admitted
        contract; the shedder reconciles the contract with the bytes
        that actually arrive (jitter, bursts, overload).
        """
        if not self._is_cluster:
            key = (category.model_id, tuple(category.shape_key))
            if key in getattr(self.target, "table").flat_entries:
                # Slot-arena decode streams need an arena-row lease so
                # their tokens land in THEIR resident row every step;
                # only the cluster path (build_live_cluster) leases.
                raise ValueError(
                    f"decode category {category} needs the cluster path "
                    f"(arena-row leases): register over build_live_cluster"
                )
        now = self.loop.now
        request = Request(
            category=category,
            period=source.period,
            relative_deadline=relative_deadline,
            n_frames=source.n_frames,
            start_time=now + start_in,
        )
        session = StreamSession(source=source, request=request)
        self.sessions.append(session)
        if self._is_cluster:
            ok = self.target.submit_request(request, external_arrivals=True)
            if ok:
                session.slice_name = self.target.placement[request.request_id]
        else:
            ok = self.target.submit_request(
                request, external_arrivals=True
            ).admitted
        if not ok:
            session.state = "rejected"
            return session
        session.state = "active"
        if not schedule_arrivals:
            # The caller (transport server) owns the frame path and
            # pushes wire arrivals through ``deliver`` itself.
            return session
        t0 = now + start_in
        prio = getattr(self.loop, "PRIO_ARRIVAL", 0)
        for index, plan in enumerate(source.plan()):
            box: Dict[str, int] = {}
            eid = self.loop.schedule(
                t0 + plan.offset,
                self._make_delivery(session, index, plan.payload, box),
                priority=prio,
            )
            box["eid"] = eid
            session._events.add(eid)
        return session

    def close(self, session: StreamSession) -> None:
        """End a stream early: cancel undelivered arrivals, release the
        arena-row lease, retire the request from its DisBatcher."""
        if session.state == "failover":
            # Evicted while its tail is parked awaiting re-admission:
            # cancel the parked retry so it can never resurrect the
            # stream, and release the dead slice's lease record.
            session.state = "closed"
            for eid in session._events:
                self.loop.cancel(eid)
            session._events.clear()
            sl = self._slice_of(session)
            if sl is not None:
                sl.release(session.request_id)
            cancel = getattr(self.target, "cancel_parked", None)
            if cancel is not None:
                cancel(session.request_id)
            return
        if session.state != "active":
            return
        session.state = "closed"
        for eid in session._events:
            self.loop.cancel(eid)
        session._events.clear()
        sched = self._scheduler_of(session)
        sl = self._slice_of(session)
        if sl is not None:
            sl.release(session.request_id)
        sched.disbatcher.remove_request(session.request)

    # -- slice health ------------------------------------------------------
    def _on_slice_health(self, name: str, old: str, new: str) -> None:
        """SliceHealthMonitor subscriber. Fires BEFORE a quarantined
        slice is failed, so undelivered arrivals are cancelled before
        ``fail_slice`` reconciles the dead pipeline's lost frames."""
        if new != QUARANTINED:
            return  # suspect tightening is read live in _over_budget
        for session in self.sessions:
            if session.slice_name == name and session.state == "active":
                self._abort(session)

    def _abort(self, session: StreamSession) -> None:
        """The session's slice died. Stop delivering: cancelled arrivals
        never count as ingested (the bytes were never presented), frames
        already in the dead pipeline are reconciled as ``lost`` by
        ``fail_slice``, and the stream's deliverable tail is re-admitted
        on a surviving slice by the cluster (as a synthetic request —
        re-homing the live byte stream itself is the transport
        follow-on). The dead slice's lease and DisBatcher entries are
        left untouched: its engine is frozen."""
        session.state = "failover"
        for eid in session._events:
            self.loop.cancel(eid)
        session._events.clear()

    # -- placement plumbing ----------------------------------------------
    def _slice_of(self, session: StreamSession):
        if not self._is_cluster or session.slice_name is None:
            return None
        return self.target.slices[session.slice_name]

    def _scheduler_of(self, session: StreamSession):
        sl = self._slice_of(session)
        return self.target if sl is None else sl.scheduler

    # -- frame path -------------------------------------------------------
    def _make_delivery(
        self, session: StreamSession, index: int, payload, box: Dict[str, int]
    ):
        def _deliver() -> None:
            session._events.discard(box.get("eid"))
            self._on_frame(session, index, payload)

        return _deliver

    def _on_frame(self, session: StreamSession, index: int, payload) -> None:
        self.deliver(session, index, payload)

    def deliver(self, session: StreamSession, index: int, payload) -> str:
        """Present one frame's bytes to the gateway; returns how the
        frame resolved: ``"delivered"`` (handed to the scheduler),
        ``"shed"`` (dropped at the door per the shed policy), ``"lost"``
        (accepted but the target device had just closed — counted
        ingested AND lost by the scheduler), or ``"refused"`` (the
        session is not active; the bytes were never presented and are
        NOT counted ingested — the caller owns their accounting)."""
        if session.state != "active":
            return "refused"
        session.frames_ingested += 1
        sched = self._scheduler_of(session)
        cat = session.request.category
        if self.shedding and self._over_budget(session, sched, cat):
            policy = self.policies.get(cat, self.default_policy)
            session._shed_phase += 1
            keep = (
                policy.mode == "subsample"
                and session._shed_phase % policy.keep == 0
            )
            if not keep:
                self._shed(session, sched, cat, index)
                return "shed"
        else:
            session._shed_phase = 0
        frame = sched.ingest_frame(
            session.request, index, payload=payload, ingest_time=self.loop.now
        )
        session.frames_delivered += 1
        return "delivered" if frame is not None else "lost"

    def _shed(
        self, session: StreamSession, sched, cat: Category, index: int = -1
    ) -> None:
        session.frames_dropped += 1
        est = getattr(session, "_last_estimate", None)
        session.last_shed_reason = (
            f"over_budget: predicted {est[0]:.4f}s > budget {est[1]:.4f}s"
            if est is not None
            else "over_budget"
        )
        sched.metrics.record_drop(session.request_id)
        sched.adaptation.note_shed(cat)
        sl = self._slice_of(session)
        if sl is not None:
            sl.note_dropped(session.request_id)
        if self.tracer is not None:
            self.tracer.emit(
                T.SHED, self.loop.now, session.request_id, index,
                where=session.slice_name, cat=str(cat),
                meta={"reason": session.last_shed_reason,
                      "breakdown": session.last_delay_breakdown})

    # -- backpressure estimate -------------------------------------------
    def delay_estimate(
        self, session: StreamSession, sched=None, cat: Optional[Category] = None
    ):
        """``(predicted_delay, budget)`` for the session's next frame —
        the quantity the shedder thresholds on, exposed so the transport
        flow controller can signal backpressure BEFORE frames shed.

        ``device_tail`` is the in-flight job's remaining occupancy from
        the device's ``busy_until``. When that job is a multi-step
        decode chunk, the EDF worker charged the chunk's FULL k-step
        WCET at submit, so the window residue of an in-flight chunk
        counts here automatically — without it, a deep chunk would look
        like a 1-step device tail and CREDIT downshifts would fire k
        steps late. The per-term breakdown of the most recent estimate
        is kept on ``session.last_delay_breakdown`` for observability
        (which term tripped a shed / downshift).
        """
        if sched is None:
            sched = self._scheduler_of(session)
        if cat is None:
            cat = session.request.category
        now = self.loop.now
        table = sched.table
        shape = sched.disbatcher.shape_override(cat) or cat.shape_key
        pending = len(sched.disbatcher.pending_frames(cat))
        device_tail = max(0.0, (sched.device.busy_until or now) - now)
        # O(1): the EDF worker maintains the queued-WCET total
        # incrementally — no per-frame walk of the deadline queue.
        queued = sched.worker.queued_wcet
        next_joint = sched.disbatcher.state_of(cat).next_joint
        window_wait = max(0.0, next_joint - now) if next_joint is not None else 0.0
        batch_wcet = table.wcet(cat.model_id, shape, pending + 1)
        delay = device_tail + queued + window_wait + batch_wcet
        session.last_delay_breakdown = {
            "device_tail": device_tail,
            "queued_wcet": queued,
            "window_wait": window_wait,
            "batch_wcet": batch_wcet,
        }
        policy = self.policies.get(cat, self.default_policy)
        # shed_scale already folds in device health: a suspect slice's
        # adaptation module is held degraded by the health monitor, so
        # every session on it sheds earlier without gateway special-casing.
        budget = (
            policy.budget_fraction
            * session.request.relative_deadline
            / sched.adaptation.shed_scale(cat)
        )
        return delay, budget

    def _over_budget(self, session: StreamSession, sched, cat: Category) -> bool:
        """Would this frame's predicted queueing delay blow its deadline
        budget? Conservative sum of everything ahead of it: the device's
        in-flight tail, all queued EDF jobs, the residue of the current
        DisBatcher window, and the WCET of the batch it would join."""
        delay, budget = self.delay_estimate(session, sched, cat)
        session._last_estimate = (delay, budget)
        return delay > budget or math.isinf(delay)
