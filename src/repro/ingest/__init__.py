"""Streaming ingestion gateway: real frame/token ingestion in front of
the DeepRT serving stack (sources -> sessions -> transport -> staging
rings)."""
from repro.ingest.session import IngestGateway, ShedPolicy, StreamSession
from repro.ingest.sources import (
    BurstSource,
    CameraSource,
    FramePlan,
    FrameSource,
    PeriodicSource,
    TraceSource,
)
from repro.ingest.staging import StagingRing
from repro.ingest.transport import (
    DROP,
    DUPLICATE,
    LINK_DELAY,
    LINK_FAULT_KINDS,
    REORDER,
    LinkFault,
    LinkPlan,
    SimLink,
    TransportServer,
    TransportSession,
    TransportSource,
    UdpClientLink,
    UdpServerBinding,
)

__all__ = [
    "IngestGateway",
    "ShedPolicy",
    "StreamSession",
    "BurstSource",
    "CameraSource",
    "FramePlan",
    "FrameSource",
    "PeriodicSource",
    "TraceSource",
    "StagingRing",
    "LinkFault",
    "LinkPlan",
    "SimLink",
    "TransportServer",
    "TransportSession",
    "TransportSource",
    "UdpClientLink",
    "UdpServerBinding",
    "DROP",
    "DUPLICATE",
    "REORDER",
    "LINK_DELAY",
    "LINK_FAULT_KINDS",
]
