"""Streaming ingestion gateway: real frame/token ingestion in front of
the DeepRT serving stack (sources -> sessions -> transport -> staging
rings)."""
from repro.ingest.session import IngestGateway, ShedPolicy, StreamSession
from repro.ingest.sources import (
    BurstSource,
    CameraSource,
    FramePlan,
    FrameSource,
    PeriodicSource,
    TraceSource,
)
from repro.ingest.staging import StagingRing
from repro.ingest.transport import (
    DROP,
    DUPLICATE,
    HELLO_RETRY,
    LINK_DELAY,
    LINK_FAULT_KINDS,
    MALFORMED,
    REORDER,
    LinkFault,
    LinkPlan,
    SimLink,
    TransportServer,
    TransportSession,
    TransportSource,
    UdpClientLink,
    UdpServerBinding,
)

__all__ = [
    "IngestGateway",
    "ShedPolicy",
    "StreamSession",
    "BurstSource",
    "CameraSource",
    "FramePlan",
    "FrameSource",
    "PeriodicSource",
    "TraceSource",
    "StagingRing",
    "LinkFault",
    "LinkPlan",
    "SimLink",
    "TransportServer",
    "TransportSession",
    "TransportSource",
    "UdpClientLink",
    "UdpServerBinding",
    "DROP",
    "DUPLICATE",
    "HELLO_RETRY",
    "MALFORMED",
    "REORDER",
    "LINK_DELAY",
    "LINK_FAULT_KINDS",
]
