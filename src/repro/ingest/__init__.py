"""Streaming ingestion gateway: real frame/token ingestion in front of
the DeepRT serving stack (sources -> sessions -> staging rings)."""
from repro.ingest.session import IngestGateway, ShedPolicy, StreamSession
from repro.ingest.sources import (
    BurstSource,
    CameraSource,
    FramePlan,
    FrameSource,
    TraceSource,
)
from repro.ingest.staging import StagingRing

__all__ = [
    "IngestGateway",
    "ShedPolicy",
    "StreamSession",
    "BurstSource",
    "CameraSource",
    "FramePlan",
    "FrameSource",
    "TraceSource",
    "StagingRing",
]
