"""Logical-axis sharding rules engine.

Every parameter/cache/activation dim carries a *logical* axis name
(assigned in the model zoo's Param specs and ``constrain`` calls). This
module maps logical axes to mesh axes with an ordered-candidate,
divisibility-aware assignment:

  for each array dim, in order:
      for each candidate mesh axis of its logical name, in order:
          accept if (a) the axis is unused so far in this array and
                    (b) the dim size divides by the accumulated product

The fallback behaviour this buys is what makes ONE rule set serve all
10 architectures and all 4 input shapes:

- GQA kv_heads=8 on a model=16 axis fails divisibility, so the kv cache
  falls through to sharding head_dim on model (contraction-dim sharding;
  GSPMD inserts the per-layer logits all-reduce);
- mixtral's 8 experts fail on model=16, so expert FFN weights fall
  through to TP inside each expert (d_ff on model);
- long_500k's batch=1 cannot shard, so the KV cache falls through to
  sequence sharding on data — context parallelism for free;
- whisper's 20 MHA heads fail on model=16 -> head_dim sharding.

Rule sets differ for params (FSDP: embed dims sharded over data/pod),
activations (batch over pod+data), and caches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

LogicalAxes = Tuple[Optional[str], ...]

# Candidate mesh axes per logical axis, in priority order.
PARAM_RULES: Dict[Optional[str], List[str]] = {
    "layer": [],
    "embed": ["data", "pod"],  # FSDP / ZeRO-3 style weight sharding
    "embed2": [],
    "vocab": ["model"],
    "heads": ["model"],
    "kv_heads": ["model"],
    "head_dim": ["model"],
    "mlp": ["model"],
    "mlp2": [],
    "expert": ["model"],
    "heads_flat": ["model"],
    "capacity": [],
    None: [],
}

ACT_RULES: Dict[Optional[str], List[str]] = {
    "batch": ["pod", "data"],
    "seq": [],
    "embed": [],
    "expert": ["model"],
    "heads": ["model"],
    "capacity": [],
    None: [],
}

CACHE_RULES: Dict[Optional[str], List[str]] = {
    "layer": [],
    "batch": ["pod", "data"],
    "seq": ["data", "pod"],  # context parallelism when batch can't shard
    "kv_heads": ["model"],
    "head_dim": ["model"],
    "heads": ["model"],
    "embed": ["model"],
    None: [],
}


def spec_for_shape(
    shape: Sequence[int],
    axes: LogicalAxes,
    mesh: Mesh,
    rules: Dict[Optional[str], List[str]],
) -> PartitionSpec:
    """Assign mesh axes to dims (ordered candidates + divisibility)."""
    used: set = set()
    out: List[Any] = []
    for dim, name in zip(shape, axes):
        chosen: List[str] = []
        prod = 1
        for cand in rules.get(name, []):
            if cand in used or cand not in mesh.shape:
                continue
            size = mesh.shape[cand]
            if dim % (prod * size) == 0:
                chosen.append(cand)
                used.add(cand)
                prod *= size
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(tuple(chosen))
    # Trim trailing Nones (canonical PartitionSpec form).
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_shardings(
    shape_tree: Any,
    axes_tree: Any,
    mesh: Mesh,
    rules: Dict[Optional[str], List[str]] = PARAM_RULES,
) -> Any:
    """NamedSharding tree for a tree of arrays/ShapeDtypeStructs given the
    matching tree of logical-axes tuples."""

    def one(leaf, axes):
        return NamedSharding(
            mesh, spec_for_shape(leaf.shape, axes, mesh, rules)
        )

    return jax.tree.map(
        one, shape_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


# Cache trees don't carry Param specs; derive logical axes from shapes by
# kind (see models/kvcache.py layouts).
def cache_axes(cfg, stacked: bool) -> Dict[str, LogicalAxes]:
    lead: LogicalAxes = ("layer",) if stacked else ()
    return {
        "k": lead + ("batch", "seq", "kv_heads", "head_dim"),
        "v": lead + ("batch", "seq", "kv_heads", "head_dim"),
        "pos": lead + ("batch", "seq"),
        "h": lead + ("batch", "mlp"),
        "conv": lead + ("batch", None, "mlp"),
        "shift": lead + ("batch", "embed"),
        "wkv": lead + ("batch", "heads", None, None),
        "channel": lead + ("batch", "embed"),
        "self_k": lead + ("batch", "seq", "kv_heads", "head_dim"),
        "self_v": lead + ("batch", "seq", "kv_heads", "head_dim"),
        "cross_k": lead + ("batch", "seq", "kv_heads", "head_dim"),
        "cross_v": lead + ("batch", "seq", "kv_heads", "head_dim"),
    }


def cache_shardings(cache_tree: Any, cfg, mesh: Mesh) -> Any:
    """Shardings for a decode cache pytree (dict-of-lists-of-dicts)."""

    def walk(node, stacked):
        if isinstance(node, dict) and any(
            k in node for k in ("k", "h", "shift", "self_k")
        ):
            table = cache_axes(cfg, stacked)
            out = {}
            for name, leaf in node.items():
                axes = table[name][: len(leaf.shape)]
                # wkv state rank differs (B,H,K,V); clip handled above.
                if name == "wkv":
                    axes = (("layer",) if stacked else ()) + (
                        "batch", "heads", None, None,
                    )
                out[name] = NamedSharding(
                    mesh, spec_for_shape(leaf.shape, axes, mesh, CACHE_RULES)
                )
            return out
        if isinstance(node, dict):
            return {k: walk(v, stacked) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, stacked) for v in node]
        raise TypeError(type(node))

    if "self_k" in cache_tree:  # encdec cache: flat dict, layer-stacked
        return walk(cache_tree, stacked=True)
    out = {}
    for key, sub in cache_tree.items():
        out[key] = walk(sub, stacked=(key == "super"))
    return out


import contextlib


@contextlib.contextmanager
def rule_overrides(param=None, act=None, cache=None):
    """Temporarily override logical-axis rule entries — the mechanism
    behind the dry-run's named optimization variants (EXPERIMENTS.md
    §Perf). Example: rule_overrides(act={"seq": ["model"]}) turns on
    sequence parallelism for activations."""
    saved = []
    for rules, upd in ((PARAM_RULES, param), (ACT_RULES, act), (CACHE_RULES, cache)):
        if not upd:
            continue
        for k, v in upd.items():
            saved.append((rules, k, rules.get(k, None), k in rules))
            rules[k] = v
    try:
        yield
    finally:
        for rules, k, old, existed in reversed(saved):
            if existed:
                rules[k] = old
            else:
                rules.pop(k, None)


def install_activation_resolver(mesh: Mesh) -> None:
    """Route models.sharding_hooks.constrain through this mesh."""
    from repro.models import sharding_hooks

    def resolver(x, axes):
        spec = spec_for_shape(x.shape, axes, mesh, ACT_RULES)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    sharding_hooks.set_resolver(resolver)


def clear_activation_resolver() -> None:
    from repro.models import sharding_hooks

    sharding_hooks.clear_resolver()
