"""Attention variants: GQA/MQA, causal, sliding-window, cross, decode.

Three execution paths, selected by shape and config:

- ``dense_attention``  — materialized-logits einsum attention. Used for
  short sequences and as the numerical oracle everywhere.
- ``flash_attention_xla`` — blocked online-softmax attention (q-chunk scan
  over kv-chunk scan), pure jnp/lax. This is the long-context reference
  path: it lowers with O(S·chunk) live memory instead of O(S²), so the
  32k/500k dry-runs are compilable, and its HLO FLOPs reflect a real
  flash-style schedule for the roofline. The Pallas TPU kernel
  (repro.kernels.flash_attention) implements the same schedule with
  explicit VMEM tiling; ``impl='pallas'`` dispatches to it.
- ``swa_attention_xla`` — banded sliding-window attention: each query
  chunk attends to a dynamically sliced KV band, giving true O(S·window)
  compute (mixtral/gemma3-local/recurrentgemma-local layers).

All paths share one mask convention: explicit integer positions for
queries and keys, so prefill, decode-with-cache, and ring-buffer caches
use the same code.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Param, apply_mrope, apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attention_spec(
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    bias: bool = False,
) -> Dict[str, Param]:
    spec = {
        "wq": Param((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": Param((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": Param((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": Param((n_heads, head_dim, d_model), ("heads", "head_dim", "embed")),
    }
    if bias:
        spec["bq"] = Param((n_heads, head_dim), ("heads", "head_dim"), init="zeros")
        spec["bv"] = Param((n_kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
        spec["bo"] = Param((d_model,), ("embed",), init="zeros")
    return spec


# ---------------------------------------------------------------------------
# Mask helper
# ---------------------------------------------------------------------------


def build_mask(
    q_pos: jax.Array,  # (B, Sq)
    kv_pos: jax.Array,  # (B, Skv)
    kv_valid: Optional[jax.Array],  # (B, Skv) bool
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    """(B, Sq, Skv) boolean mask — True = attend."""
    q = q_pos[:, :, None]
    k = kv_pos[:, None, :]
    mask = jnp.ones(q.shape[:2] + (kv_pos.shape[1],), bool)
    if causal:
        mask &= k <= q
    if window is not None:
        mask &= k > q - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    return mask


# ---------------------------------------------------------------------------
# Dense (oracle) path
# ---------------------------------------------------------------------------


def dense_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KV, D)
    v: jax.Array,  # (B, Skv, KV, D)
    mask: jax.Array,  # (B, Sq, Skv) bool
) -> jax.Array:
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(d)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", probs, v, preferred_element_type=jnp.float32
    )
    return out.reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash-style XLA path (blocked online softmax)
# ---------------------------------------------------------------------------


def flash_attention_xla(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KV, D)
    v: jax.Array,
    q_pos: jax.Array,  # (B, Sq)
    kv_pos: jax.Array,  # (B, Skv)
    causal: bool = True,
    window: Optional[int] = None,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Blocked online-softmax attention, scanning over KV chunks ONLY.

    The query sequence stays a full tensor dim — crucial for GSPMD: a
    scan axis cannot be sharded, so chunking q would lock out sequence
    parallelism (the earlier two-level-scan design measurably prevented
    seq sharding — EXPERIMENTS.md §Perf iteration 2). Per-chunk live
    memory is O(Sq * kv_chunk) logits + the (Sq, D) f32 accumulator,
    sharded along Sq/batch by whatever GSPMD decides for the layer.
    """
    b, sq, h, d = q.shape
    skv, kv_h = k.shape[1], k.shape[2]
    g = h // kv_h
    kv_chunk = min(kv_chunk, skv)
    nk = math.ceil(skv / kv_chunk)
    skv_pad = nk * kv_chunk
    scale = 1.0 / math.sqrt(d)

    kf = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
    kp = jnp.pad(kv_pos, ((0, 0), (0, skv_pad - skv)), constant_values=2**30)

    # Operands stay in model dtype (bf16 on TPU); contractions accumulate
    # in f32 via preferred_element_type (MXU-native).
    qg = q.reshape(b, sq, kv_h, g, d)
    kf = kf.reshape(b, nk, kv_chunk, kv_h, d)
    vf = vf.reshape(b, nk, kv_chunk, kv_h, d)
    kp = kp.reshape(b, nk, kv_chunk)

    def kv_step(carry, ki):
        m, l, acc = carry  # (B, KV, G, Sq), ..., (B, KV, G, Sq, D)
        kc, vc, kpc = ki  # (B, kvc, KV, D), ..., (B, kvc)
        logits = (
            jnp.einsum(
                "bqkgd,bskd->bkgqs", qg, kc,
                preferred_element_type=jnp.float32,
            )
            * scale
        )  # (B, KV, G, Sq, kvc) f32
        mask = jnp.ones((b, sq, kv_chunk), bool)
        if causal:
            mask &= kpc[:, None, :] <= q_pos[:, :, None]
        if window is not None:
            mask &= kpc[:, None, :] > q_pos[:, :, None] - window
        mask &= kpc[:, None, :] < 2**30  # padding
        logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vc, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv_h, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv_h, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv_h, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        kv_step,
        (m0, l0, a0),
        (
            kf.transpose(1, 0, 2, 3, 4),
            vf.transpose(1, 0, 2, 3, 4),
            kp.transpose(1, 0, 2),
        ),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, Sq, D)
    return (
        out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)
    )


# ---------------------------------------------------------------------------
# Banded sliding-window path: O(S * window)
# ---------------------------------------------------------------------------


def swa_attention_xla(
    q: jax.Array,  # (B, S, H, D) — self-attention over aligned positions
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    q_pos: jax.Array,  # (B, S)
    window: int,
    q_chunk: int = 512,
) -> jax.Array:
    """Causal sliding-window self-attention: each query chunk attends to
    its static KV band, gathered up front — compute is O(S * (window +
    q_chunk)) instead of O(S^2), and the chunk index stays a TENSOR dim
    (not a scan axis) so GSPMD can shard the sequence (the earlier
    scan-over-q-chunks version measurably blocked sequence parallelism —
    EXPERIMENTS.md §Perf iteration 6)."""
    b, s, h, d = q.shape
    kv_h = k.shape[2]
    g = h // kv_h
    q_chunk = min(q_chunk, s)
    nq = math.ceil(s / q_chunk)
    s_pad = nq * q_chunk
    band = min(
        (math.ceil(window / q_chunk)) * q_chunk + q_chunk, s_pad
    )  # static KV span per q chunk
    lpad = band - q_chunk
    scale = 1.0 / math.sqrt(d)

    qf = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    # Left-pad KV by (band - q_chunk) so band windows never reach before 0;
    # padded slots carry sentinel positions.
    kf = jnp.pad(k, ((0, 0), (lpad, s_pad - s), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (lpad, s_pad - s), (0, 0), (0, 0)))
    qp = jnp.pad(q_pos, ((0, 0), (0, s_pad - s)))
    kp = jnp.pad(q_pos, ((0, 0), (lpad, s_pad - s)), constant_values=2**30)
    kp = kp.at[:, :lpad].set(-(2**30))

    # Banded gather: (nq, band) indices into the padded kv axis.
    idx = (
        jnp.arange(nq)[:, None] * q_chunk + jnp.arange(band)[None, :]
    )  # chunk i covers padded kv slots [i*qc, i*qc + band)
    kb = jnp.take(kf, idx, axis=1)  # (B, nq, band, KV, D)
    vb = jnp.take(vf, idx, axis=1)
    kpb = jnp.take(kp, idx, axis=1)  # (B, nq, band)
    qg = qf.reshape(b, nq, q_chunk, kv_h, g, d)
    qpb = qp.reshape(b, nq, q_chunk)

    logits = (
        jnp.einsum(
            "bnqkgd,bnskd->bnkgqs", qg, kb, preferred_element_type=jnp.float32
        )
        * scale
    )  # (B, nq, KV, G, qc, band) f32
    mask = (kpb[:, :, None, :] <= qpb[:, :, :, None]) & (
        kpb[:, :, None, :] > qpb[:, :, :, None] - window
    )  # (B, nq, qc, band)
    logits = jnp.where(mask[:, :, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bnkgqs,bnskd->bnqkgd", probs, vb, preferred_element_type=jnp.float32
    )
    out = out.reshape(b, s_pad, h, d)
    return out[:, :s].astype(q.dtype)


# ---------------------------------------------------------------------------
# Full multi-head attention layer (projections + rope + cache handling)
# ---------------------------------------------------------------------------


def project_qkv(
    p: Dict[str, jax.Array], x: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        v = v + p["bv"]
    return q, k, v


def project_out(p: Dict[str, jax.Array], o: jax.Array) -> jax.Array:
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y


def mha(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S) or (3, B, S) for mrope
    *,
    causal: bool = True,
    window: Optional[int] = None,
    rope_theta: Optional[float] = 10000.0,
    rope_kind: str = "rope",  # rope | mrope | none
    impl: str = "xla",  # xla | dense | pallas
    dense_threshold: int = 2048,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
) -> jax.Array:
    """Self- (or cross-) attention over a full sequence (training/prefill)."""
    q, k, v = project_qkv(p, x)
    if kv_override is not None:
        k, v = kv_override
    pos1d = positions if positions.ndim == 2 else positions[0]
    if rope_kind == "rope" and rope_theta is not None:
        q = apply_rope(q, pos1d, rope_theta)
        if kv_override is None:
            k = apply_rope(k, pos1d, rope_theta)
    elif rope_kind == "mrope":
        q = apply_mrope(q, positions, rope_theta)
        if kv_override is None:
            k = apply_mrope(k, positions, rope_theta)

    s = x.shape[1]
    skv = k.shape[1]
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops

        o = kernel_ops.flash_attention(
            q, k, v, pos1d, causal=causal, window=window
        )
    elif impl == "dense" or (s <= dense_threshold and skv <= dense_threshold):
        kv_pos = pos1d if kv_override is None else (
            jnp.broadcast_to(jnp.arange(skv)[None, :], (x.shape[0], skv))
        )
        mask = build_mask(pos1d, kv_pos, None, causal and kv_override is None, window)
        o = dense_attention(q, k, v, mask)
    elif window is not None and kv_override is None:
        o = swa_attention_xla(q, k, v, pos1d, window)
    else:
        kv_pos = pos1d if kv_override is None else (
            jnp.broadcast_to(jnp.arange(skv)[None, :], (x.shape[0], skv))
        )
        o = flash_attention_xla(
            q, k, v, pos1d, kv_pos, causal=causal and kv_override is None,
            window=window,
        )
    return project_out(p, o)


def mha_decode(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, 1, D)
    position: jax.Array,  # (B,) int32 — current absolute position
    cache_k: jax.Array,  # (B, S_cache, KV, D) (already includes this token)
    cache_v: jax.Array,
    kv_positions: jax.Array,  # (B, S_cache) — absolute pos per slot
    kv_valid: jax.Array,  # (B, S_cache) bool
    *,
    causal: bool = True,  # False for cross-attention
    window: Optional[int] = None,
    rope_theta: Optional[float] = 10000.0,
    rope_kind: str = "rope",
    mrope_position: Optional[jax.Array] = None,  # (3, B, 1)
    impl: str = "xla",
    active: Optional[jax.Array] = None,  # (B,) live-slot bitmap (arena)
) -> jax.Array:
    """One-token attention against a (possibly ring) KV cache. The caller
    has already written this token's K/V into the cache (see kvcache.py);
    q is projected and rotated here. ``active`` marks live slot-arena
    rows: dead rows are fully masked (the Pallas kernel then skips all
    their KV blocks), so batch size is data, not shape."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if rope_kind == "rope" and rope_theta is not None:
        q = apply_rope(q, position[:, None], rope_theta)
    elif rope_kind == "mrope":
        q = apply_mrope(q, mrope_position, rope_theta)
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops

        o = kernel_ops.decode_attention(
            q, cache_k, cache_v, position, kv_positions, kv_valid, active,
            window=window,
        )
    else:
        if active is not None:
            kv_valid = kv_valid & active[:, None]
        mask = build_mask(position[:, None], kv_positions, kv_valid, causal, window)
        o = dense_attention(q, cache_k, cache_v, mask)
    return project_out(p, o)


def project_kv(p: Dict[str, jax.Array], x: jax.Array, positions, rope_theta,
               rope_kind="rope") -> Tuple[jax.Array, jax.Array]:
    """K/V for cache insertion (decode) — same rotation as prefill."""
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bv" in p:
        v = v + p["bv"]
    if rope_kind == "rope" and rope_theta is not None:
        k = apply_rope(k, positions, rope_theta)
    elif rope_kind == "mrope":
        k = apply_mrope(k, positions, rope_theta)
    return k, v
