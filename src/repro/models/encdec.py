"""Whisper-style encoder-decoder backbone (conv frontend STUBBED).

Per the assignment, the modality frontend is a stub: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, d_model) — the two
strided convolutions of real Whisper are out of scope. Everything after
that is the real architecture: sinusoidal positions + bidirectional
encoder; learned positions + causal self-attention + cross-attention
decoder; LayerNorm / GELU / attention biases per Whisper.

Decode caches: per decoder layer a full self-attention KV cache plus the
cross-attention K/V, which are computed ONCE from the encoder output at
prefill (``encode_for_decode``) and read-only afterwards.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import kvcache
from repro.models.attention import (
    attention_spec,
    mha,
    mha_decode,
    project_kv,
)
from repro.models.layers import (
    Param,
    abstract_params,
    apply_mlp,
    apply_norm,
    build_axes,
    build_params,
    embed_lookup,
    embed_spec,
    mlp_spec,
    norm_spec,
    sinusoidal_positions,
    unembed,
)
from repro.models.sharding_hooks import constrain


def _enc_block_spec(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    return {
        "norm1": norm_spec(d, cfg.norm),
        "attn": attention_spec(
            d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, bias=True
        ),
        "norm2": norm_spec(d, cfg.norm),
        "ffn": mlp_spec(d, cfg.d_ff, cfg.activation),
    }


def _dec_block_spec(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    return {
        "norm1": norm_spec(d, cfg.norm),
        "self_attn": attention_spec(
            d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, bias=True
        ),
        "norm_cross": norm_spec(d, cfg.norm),
        "cross_attn": attention_spec(
            d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, bias=True
        ),
        "norm2": norm_spec(d, cfg.norm),
        "ffn": mlp_spec(d, cfg.d_ff, cfg.activation),
    }


def _stack(spec: Any, n: int) -> Any:
    return jax.tree.map(
        lambda p: Param((n,) + p.shape, ("layer",) + p.axes, p.init, p.scale),
        spec,
        is_leaf=lambda x: isinstance(x, Param),
    )


class EncDecTransformer:
    """Whisper-family model. cfg.n_layers = decoder layers,
    cfg.n_encoder_layers = encoder layers."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.MAX_DEC_POSITIONS = cfg.max_dec_positions
        self._spec = self._model_spec()

    def _model_spec(self) -> Dict:
        cfg = self.cfg
        return {
            "embed": embed_spec(cfg.vocab_size, cfg.d_model),
            "dec_pos": Param(
                (self.MAX_DEC_POSITIONS, cfg.d_model), (None, "embed"), scale=0.02
            ),
            "encoder": _stack(_enc_block_spec(cfg), cfg.n_encoder_layers),
            "enc_final_norm": norm_spec(cfg.d_model, cfg.norm),
            "decoder": _stack(_dec_block_spec(cfg), cfg.n_layers),
            "dec_final_norm": norm_spec(cfg.d_model, cfg.norm),
        }

    # ----- params -------------------------------------------------------
    def spec(self):
        return self._spec

    def init(self, key, dtype=None):
        return build_params(self._spec, key, dtype or self.cfg.dtype)

    def abstract_params(self, dtype=None):
        return abstract_params(self._spec, dtype or self.cfg.dtype)

    def axes(self):
        return build_axes(self._spec)

    # ----- encoder --------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, T, d_model) precomputed embeddings (frontend stub)."""
        cfg = self.cfg
        b, t, d = frames.shape
        x = frames + sinusoidal_positions(t, d).astype(frames.dtype)[None]
        x = constrain(x, ("batch", "seq", "embed"))
        positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

        def block(x, p):
            h = apply_norm(x, p["norm1"], cfg.norm)
            y = mha(
                p["attn"], h, positions, causal=False, rope_theta=None,
                rope_kind="none", impl=cfg.impl,
            )
            x = x + y
            h2 = apply_norm(x, p["norm2"], cfg.norm)
            x = x + apply_mlp(h2, p["ffn"], cfg.activation)
            return constrain(x, ("batch", "seq", "embed")), None

        body = block
        if cfg.remat:
            body = jax.checkpoint(block, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return apply_norm(x, params["enc_final_norm"], cfg.norm)

    # ----- decoder, full sequence (training) ------------------------------
    def _dec_block_full(self, p, x, positions, enc_out, enc_positions):
        cfg = self.cfg
        h = apply_norm(x, p["norm1"], cfg.norm)
        y = mha(
            p["self_attn"], h, positions, causal=True, rope_theta=None,
            rope_kind="none", impl=cfg.impl,
        )
        x = x + y
        hc = apply_norm(x, p["norm_cross"], cfg.norm)
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wv"])
        if "bv" in p["cross_attn"]:
            v = v + p["cross_attn"]["bv"]
        y = mha(
            p["cross_attn"], hc, positions, causal=False, rope_theta=None,
            rope_kind="none", impl=cfg.impl, kv_override=(k, v),
        )
        x = x + y
        h2 = apply_norm(x, p["norm2"], cfg.norm)
        x = x + apply_mlp(h2, p["ffn"], cfg.activation)
        return constrain(x, ("batch", "seq", "embed"))

    def forward(
        self, params, frames: jax.Array, dec_tokens: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        """Training forward: returns (decoder logits f32, aux=0)."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        b, s = dec_tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        enc_positions = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1])[None, :], (b, enc_out.shape[1])
        )
        x = embed_lookup(params["embed"], dec_tokens)
        x = x + params["dec_pos"][:s][None].astype(x.dtype)

        def block(x, p):
            return (
                self._dec_block_full(p, x, positions, enc_out, enc_positions),
                None,
            )

        body = block
        if cfg.remat:
            body = jax.checkpoint(block, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["decoder"])
        x = apply_norm(x, params["dec_final_norm"], cfg.norm)
        return unembed(x, params["embed"]), jnp.zeros((), jnp.float32)

    def loss(self, params, frames, dec_tokens, aux_weight: float = 0.0):
        logits, _ = self.forward(params, frames, dec_tokens)
        targets = dec_tokens[:, 1:]
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    # ----- decode ----------------------------------------------------------
    def init_cache(
        self, batch: int, max_len: int, enc_len: int, abstract: bool = False
    ):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        n = cfg.n_layers

        def stacked(shape, dtype):
            if abstract:
                return jax.ShapeDtypeStruct((n,) + shape, dtype)
            return jnp.zeros((n,) + shape, dtype)

        return {
            "self_k": stacked((batch, max_len, cfg.n_kv_heads, hd), cfg.dtype),
            "self_v": stacked((batch, max_len, cfg.n_kv_heads, hd), cfg.dtype),
            "cross_k": stacked((batch, enc_len, cfg.n_kv_heads, hd), cfg.dtype),
            "cross_v": stacked((batch, enc_len, cfg.n_kv_heads, hd), cfg.dtype),
        }

    def encode_for_decode(self, params, frames, cache):
        """Run the encoder and populate the cross K/V cache."""
        enc_out = self.encode(params, frames)

        def per_layer(p):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wv"])
            if "bv" in p["cross_attn"]:
                v = v + p["cross_attn"]["bv"]
            return k.astype(self.cfg.dtype), v.astype(self.cfg.dtype)

        ks, vs = jax.vmap(per_layer)(params["decoder"])
        return dict(cache, cross_k=ks, cross_v=vs)

    def decode_step(
        self, params, cache, token: jax.Array, cursor: jax.Array,
        active: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Any]:
        """One decoder token against self+cross caches.
        token: (B,), cursor: (B,); ``active``: (B,) live-slot bitmap
        (slot arena) — dead rows are masked out of both attentions."""
        cfg = self.cfg
        b = token.shape[0]
        x = embed_lookup(params["embed"], token[:, None])
        x = x + jnp.take(params["dec_pos"], cursor, axis=0)[:, None].astype(x.dtype)
        enc_len = cache["cross_k"].shape[2]
        enc_pos = jnp.broadcast_to(jnp.arange(enc_len)[None, :], (b, enc_len))
        enc_valid = jnp.ones((b, enc_len), bool)
        if active is not None:
            enc_valid = enc_valid & active[:, None]

        def block(x, scanned):
            p, sk, sv, ck, cv = scanned
            h = apply_norm(x, p["norm1"], cfg.norm)
            k, v = project_kv(p["self_attn"], h, cursor[:, None], None, "none")
            updated = kvcache.attn_cache_write({"k": sk, "v": sv}, k, v, cursor)
            cache_k, cache_v, kv_pos, valid = kvcache.attn_cache_views(
                updated, cursor
            )
            y = mha_decode(
                p["self_attn"], h, cursor, cache_k, cache_v, kv_pos, valid,
                rope_theta=None, rope_kind="none", impl=cfg.impl,
                active=active,
            )
            x = x + y
            hc = apply_norm(x, p["norm_cross"], cfg.norm)
            y = mha_decode(
                p["cross_attn"], hc, cursor, ck, cv, enc_pos, enc_valid,
                causal=False, rope_theta=None, rope_kind="none", impl=cfg.impl,
            )
            x = x + y
            h2 = apply_norm(x, p["norm2"], cfg.norm)
            x = x + apply_mlp(h2, p["ffn"], cfg.activation)
            return x, (updated["k"], updated["v"])

        x, (new_k, new_v) = jax.lax.scan(
            block,
            x,
            (
                params["decoder"],
                cache["self_k"],
                cache["self_v"],
                cache["cross_k"],
                cache["cross_v"],
            ),
        )
        x = apply_norm(x, params["dec_final_norm"], cfg.norm)
        logits = unembed(x, params["embed"])
        new_cache = dict(cache, self_k=new_k, self_v=new_v)
        return logits[:, 0], new_cache
