"""Decoder-only transformer assembly for the architecture pool.

Layers are grouped into *superblocks* — one repetition of
``cfg.block_pattern`` — and scanned with stacked parameters, so a
126-layer model lowers as one scan over 126 bodies (compile time and HLO
size stay flat in depth). Non-divisible tail layers run unscanned.

Modes:
- ``forward``      — full-sequence logits (training / prefill shapes)
- ``loss``         — next-token cross entropy (+ MoE aux)
- ``prefill``      — forward + populate decode caches
- ``decode_step``  — one token against caches (serve_step for the
                     decode_32k / long_500k dry-run shapes)

Encoder-decoder (whisper) lives in encdec.py; ``repro.models.model_for``
dispatches.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import kvcache
from repro.models.attention import (
    attention_spec,
    mha,
    mha_decode,
    project_kv,
    project_qkv,
)
from repro.models.layers import (
    Param,
    abstract_params,
    apply_mlp,
    apply_norm,
    build_axes,
    build_params,
    embed_lookup,
    embed_spec,
    mlp_spec,
    norm_spec,
    unembed,
)
from repro.models.moe import apply_moe, moe_spec
from repro.models.recurrent import (
    CONV_WIDTH,
    griffin_block,
    griffin_block_spec,
    rwkv6_channelmix,
    rwkv6_channelmix_spec,
    rwkv6_timemix,
    rwkv6_timemix_spec,
)
from repro.models.sharding_hooks import constrain


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, kind: str) -> Dict:
    d = cfg.d_model
    spec: Dict[str, Any] = {"norm1": norm_spec(d, cfg.norm)}
    if kind in ("attn", "swa"):
        spec["mixer"] = attention_spec(
            d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, cfg.attn_bias
        )
    elif kind == "rglru":
        spec["mixer"] = griffin_block_spec(d, cfg.d_rnn or d)
    elif kind == "rwkv":
        spec["mixer"] = rwkv6_timemix_spec(d, cfg.n_heads)
    else:
        raise ValueError(f"unknown block kind {kind}")
    spec["norm2"] = norm_spec(d, cfg.norm)
    if kind == "rwkv":
        spec["ffn"] = rwkv6_channelmix_spec(d, cfg.d_ff)
    elif cfg.is_moe and kind in ("attn", "swa"):
        spec["ffn"] = moe_spec(
            d, cfg.d_ff, cfg.n_experts, cfg.activation, cfg.shared_expert
        )
    else:
        spec["ffn"] = mlp_spec(d, cfg.d_ff, cfg.activation)
    return spec


def _stack_spec(spec: Any, n: int) -> Any:
    return jax.tree.map(
        lambda p: Param((n,) + p.shape, ("layer",) + p.axes, p.init, p.scale),
        spec,
        is_leaf=lambda x: isinstance(x, Param),
    )


def model_spec(cfg: ModelConfig) -> Dict:
    spec: Dict[str, Any] = {"embed": embed_spec(cfg.vocab_size, cfg.d_model)}
    if cfg.n_super > 0:
        spec["super"] = [
            _stack_spec(block_spec(cfg, kind), cfg.n_super)
            for kind in cfg.block_pattern
        ]
    spec["tail"] = [block_spec(cfg, kind) for kind in cfg.tail_kinds]
    spec["final_norm"] = norm_spec(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        spec["lm_head"] = Param(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), scale=0.02
        )
    return spec


# ---------------------------------------------------------------------------
# Full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------


def _apply_block_full(
    cfg: ModelConfig,
    kind: str,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    collect: bool,
) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """Returns (x_out, moe_aux, cache_contrib or None)."""
    h = apply_norm(x, p["norm1"], cfg.norm)
    contrib = None
    if kind in ("attn", "swa"):
        window = cfg.sliding_window if kind == "swa" else None
        y = mha(
            p["mixer"],
            h,
            positions,
            causal=True,
            window=window,
            rope_theta=cfg.rope_theta,
            rope_kind=cfg.rope_kind,
            impl=cfg.impl,
        )
        if collect:
            pos1d = positions if positions.ndim == 2 else positions[0]
            k, v = project_kv(
                p["mixer"], h, pos1d if cfg.rope_kind != "mrope" else positions,
                cfg.rope_theta, cfg.rope_kind,
            )
            contrib = {"k": k, "v": v}
    elif kind == "rglru":
        y, state = griffin_block(p["mixer"], h, impl=cfg.impl)
        contrib = state if collect else None
    else:  # rwkv
        y, state = rwkv6_timemix(p["mixer"], h, cfg.n_heads, impl=cfg.impl)
        contrib = state if collect else None
    x = x + y
    h2 = apply_norm(x, p["norm2"], cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        f, chan_state = rwkv6_channelmix(p["ffn"], h2)
        if collect:
            contrib = dict(contrib or {}, channel=chan_state)
    elif cfg.is_moe and kind in ("attn", "swa"):
        if cfg.moe_dense:
            from repro.models.moe import apply_moe_dense_reference

            f = apply_moe_dense_reference(
                p["ffn"], h2, top_k=cfg.top_k, activation=cfg.activation
            )
        else:
            f, aux = apply_moe(
                p["ffn"],
                h2,
                top_k=cfg.top_k,
                activation=cfg.activation,
                capacity_factor=cfg.moe_capacity_factor,
            )
    else:
        f = apply_mlp(h2, p["ffn"], cfg.activation)
    x = x + f
    x = constrain(x, ("batch", "seq", "embed"))
    return x, aux, contrib


# ---------------------------------------------------------------------------
# Decode-step block application
# ---------------------------------------------------------------------------


def _apply_block_decode(
    cfg: ModelConfig,
    kind: str,
    p: Dict,
    x: jax.Array,  # (B, 1, D)
    cursor: jax.Array,  # (B,) absolute position of this token
    cache: Dict,
    mrope_position: Optional[jax.Array] = None,
    active: Optional[jax.Array] = None,  # (B,) live-slot bitmap (arena)
) -> Tuple[jax.Array, Dict]:
    h = apply_norm(x, p["norm1"], cfg.norm)
    if kind in ("attn", "swa"):
        window = cfg.sliding_window if kind == "swa" else None
        pos_for_kv = (
            cursor[:, None] if cfg.rope_kind != "mrope" else mrope_position
        )
        k, v = project_kv(p["mixer"], h, pos_for_kv, cfg.rope_theta, cfg.rope_kind)
        if kind == "attn":
            cache = kvcache.attn_cache_write(cache, k, v, cursor)
            ck, cv, kv_pos, valid = kvcache.attn_cache_views(cache, cursor)
        else:
            cache = kvcache.ring_cache_write(cache, k, v, cursor)
            ck, cv, kv_pos, valid = kvcache.ring_cache_views(cache, cursor)
        y = mha_decode(
            p["mixer"],
            h,
            cursor,
            ck,
            cv,
            kv_pos,
            valid,
            window=window,
            rope_theta=cfg.rope_theta,
            rope_kind=cfg.rope_kind,
            mrope_position=mrope_position,
            impl=cfg.impl,
            active=active,
        )
    elif kind == "rglru":
        y2d, state = griffin_block(
            p["mixer"], h, state={"h": cache["h"], "conv": cache["conv"]},
            impl=cfg.impl,
        )
        y = y2d
        cache = dict(cache, h=state["h"], conv=state["conv"])
    else:  # rwkv
        y, tstate = rwkv6_timemix(
            p["mixer"],
            h,
            cfg.n_heads,
            state={"shift": cache["shift"], "wkv": cache["wkv"]},
            impl=cfg.impl,
        )
        cache = dict(cache, shift=tstate["shift"], wkv=tstate["wkv"])
    x = x + y
    h2 = apply_norm(x, p["norm2"], cfg.norm)
    if kind == "rwkv":
        f, chan = rwkv6_channelmix(p["ffn"], h2, state=cache["channel"])
        cache = dict(cache, channel=chan)
    elif cfg.is_moe and kind in ("attn", "swa"):
        f, _ = apply_moe(
            p["ffn"],
            h2,
            top_k=cfg.top_k,
            activation=cfg.activation,
            capacity_factor=2.0,  # decode: tiny token count, avoid drops
        )
    else:
        f = apply_mlp(h2, p["ffn"], cfg.activation)
    x = x + f
    return x, cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, abstract: bool):
    dtype = cfg.dtype
    hd = cfg.resolved_head_dim
    if kind == "attn":
        fn = kvcache.attn_cache_abstract if abstract else kvcache.attn_cache_init
        return fn(batch, max_len, cfg.n_kv_heads, hd, dtype)
    if kind == "swa":
        window = min(cfg.sliding_window, max_len)
        fn = kvcache.ring_cache_abstract if abstract else kvcache.ring_cache_init
        return fn(batch, window, cfg.n_kv_heads, hd, dtype)
    if kind == "rglru":
        dr = cfg.d_rnn or cfg.d_model
        shapes = {
            "h": (batch, dr),
            "conv": (batch, CONV_WIDTH - 1, dr),
        }
    else:  # rwkv
        hd6 = cfg.d_model // cfg.n_heads
        shapes = {
            "shift": (batch, cfg.d_model),
            "wkv": (batch, cfg.n_heads, hd6, hd6),
            "channel": (batch, cfg.d_model),
        }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, jnp.float32) for k, s in shapes.items()}
    return {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, abstract: bool = False):
    def stack(tree, n):
        return jax.tree.map(
            lambda l: (
                jax.ShapeDtypeStruct((n,) + l.shape, l.dtype)
                if abstract
                else jnp.broadcast_to(l, (n,) + l.shape)
            ),
            tree,
        )

    cache = {}
    if cfg.n_super > 0:
        cache["super"] = [
            stack(_layer_cache(cfg, kind, batch, max_len, abstract), cfg.n_super)
            for kind in cfg.block_pattern
        ]
    cache["tail"] = [
        _layer_cache(cfg, kind, batch, max_len, abstract) for kind in cfg.tail_kinds
    ]
    return cache


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------


class Transformer:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._spec = model_spec(cfg)

    # ----- params -----------------------------------------------------
    def spec(self):
        return self._spec

    def init(self, key, dtype=None):
        return build_params(self._spec, key, dtype or self.cfg.dtype)

    def abstract_params(self, dtype=None):
        return abstract_params(self._spec, dtype or self.cfg.dtype)

    def axes(self):
        return build_axes(self._spec)

    # ----- forward ------------------------------------------------------
    def _embed(self, params, tokens):
        x = embed_lookup(params["embed"], tokens)
        if self.cfg.embed_scale:
            x = x * math.sqrt(self.cfg.d_model)
        return constrain(x, ("batch", "seq", "embed"))

    def forward(
        self, params, tokens: jax.Array, positions: Optional[jax.Array] = None
    ) -> Tuple[jax.Array, jax.Array]:
        """tokens: (B, S) int32; positions: (B, S) or (3, B, S) for mrope.
        Returns (logits f32, moe_aux)."""
        cfg = self.cfg
        b, s = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = self._embed(params, tokens)
        aux_total = jnp.zeros((), jnp.float32)

        if cfg.n_super > 0:

            def superblock(carry, layer_params):
                x, aux = carry
                for j, kind in enumerate(cfg.block_pattern):
                    x, a, _ = _apply_block_full(
                        cfg, kind, layer_params[j], x, positions, collect=False
                    )
                    aux = aux + a
                return (x, aux), None

            body = superblock
            if cfg.remat:
                body = jax.checkpoint(superblock, prevent_cse=False)
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), params["super"]
            )
        for p_layer, kind in zip(params["tail"], cfg.tail_kinds):
            x, a, _ = _apply_block_full(cfg, kind, p_layer, x, positions, False)
            aux_total = aux_total + a
        x = apply_norm(x, params["final_norm"], cfg.norm)
        if cfg.tie_embeddings:
            logits = unembed(x, params["embed"])
        else:
            logits = jnp.einsum(
                "bsd,dv->bsv", x.astype(jnp.float32),
                params["lm_head"].astype(jnp.float32),
            )
        return logits, aux_total

    # ----- loss -----------------------------------------------------------
    def loss(self, params, tokens, positions=None, aux_weight: float = 0.01):
        logits, aux = self.forward(params, tokens, positions)
        targets = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(nll) + aux_weight * aux

    # ----- decode ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, abstract: bool = False):
        return init_cache(self.cfg, batch, max_len, abstract)

    def decode_step(
        self,
        params,
        cache,
        token: jax.Array,  # (B,) int32
        cursor: jax.Array,  # (B,) absolute position of this token
        mrope_position: Optional[jax.Array] = None,  # (3, B, 1)
        active: Optional[jax.Array] = None,  # (B,) bool live-slot bitmap
    ) -> Tuple[jax.Array, Any]:
        """One-token decode: returns (logits (B, V) f32, new cache).

        ``active`` marks live slot-arena rows (serving/engine.py): dead
        rows are fully masked out of attention (the Pallas kernel skips
        all their KV blocks) and their logits are unspecified — the
        engine never reads them. ``None`` means every row is live.
        """
        cfg = self.cfg
        x = self._embed(params, token[:, None])
        if cfg.rope_kind == "mrope" and mrope_position is None:
            mrope_position = jnp.broadcast_to(
                cursor[None, :, None], (3,) + cursor.shape + (1,)
            )
        new_cache = dict(cache)
        if cfg.n_super > 0:

            def superblock(x, scanned):
                layer_params, layer_cache = scanned
                new_layer_cache = []
                for j, kind in enumerate(cfg.block_pattern):
                    x, c = _apply_block_decode(
                        cfg, kind, layer_params[j], x, cursor,
                        layer_cache[j], mrope_position, active,
                    )
                    new_layer_cache.append(c)
                return x, new_layer_cache

            x, new_super = jax.lax.scan(
                superblock, x, (params["super"], cache["super"])
            )
            new_cache["super"] = new_super
        new_tail = []
        for p_layer, kind, c in zip(params["tail"], cfg.tail_kinds, cache["tail"]):
            x, c2 = _apply_block_decode(
                cfg, kind, p_layer, x, cursor, c, mrope_position, active
            )
            new_tail.append(c2)
        new_cache["tail"] = new_tail
        x = apply_norm(x, params["final_norm"], cfg.norm)
        if cfg.tie_embeddings:
            logits = unembed(x, params["embed"])
        else:
            logits = jnp.einsum(
                "bsd,dv->bsv", x.astype(jnp.float32),
                params["lm_head"].astype(jnp.float32),
            )
        return logits[:, 0], new_cache

    # ----- prefill (forward + cache population) ----------------------------
    def prefill(
        self, params, cache, tokens: jax.Array, positions=None
    ) -> Tuple[jax.Array, Any]:
        """Left-aligned prefill: fills caches for positions [0, S) and
        returns (last-token logits (B, V), cache). Used by the serving
        engine; tail/super handled like forward but collecting KV."""
        cfg = self.cfg
        b, s = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        pos1d = positions if positions.ndim == 2 else positions[0]
        x = self._embed(params, tokens)
        new_cache = dict(cache)

        def fill_attn(layer_cache, contrib, kind):
            if kind == "attn":
                k = jax.lax.dynamic_update_slice(
                    layer_cache["k"], contrib["k"].astype(layer_cache["k"].dtype),
                    (0, 0, 0, 0),
                )
                v = jax.lax.dynamic_update_slice(
                    layer_cache["v"], contrib["v"].astype(layer_cache["v"].dtype),
                    (0, 0, 0, 0),
                )
                return {"k": k, "v": v}
            return kvcache.ring_cache_fill_from_prefill(
                layer_cache, contrib["k"], contrib["v"], pos1d
            )

        def merge(kind, layer_cache, contrib):
            if kind in ("attn", "swa"):
                return fill_attn(layer_cache, contrib, kind)
            merged = dict(layer_cache)
            for key, val in contrib.items():
                merged[key] = val
            return merged

        if cfg.n_super > 0:

            def superblock(x, scanned):
                layer_params, layer_cache = scanned
                out_caches = []
                for j, kind in enumerate(cfg.block_pattern):
                    x, _, contrib = _apply_block_full(
                        cfg, kind, layer_params[j], x, positions, collect=True
                    )
                    out_caches.append(merge(kind, layer_cache[j], contrib))
                return x, out_caches

            x, new_super = jax.lax.scan(
                superblock, x, (params["super"], cache["super"])
            )
            new_cache["super"] = new_super
        new_tail = []
        for p_layer, kind, c in zip(params["tail"], cfg.tail_kinds, cache["tail"]):
            x, _, contrib = _apply_block_full(cfg, kind, p_layer, x, positions, True)
            new_tail.append(merge(kind, c, contrib))
        new_cache["tail"] = new_tail
        x = apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
        if cfg.tie_embeddings:
            logits = unembed(x, params["embed"])
        else:
            logits = jnp.einsum(
                "bsd,dv->bsv", x.astype(jnp.float32),
                params["lm_head"].astype(jnp.float32),
            )
        return logits[:, 0], new_cache
