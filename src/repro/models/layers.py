"""Shared building blocks for the LM-family model zoo.

Parameters are declared as ``Param`` specs (shape + logical sharding axes
+ initializer); a single spec tree is the source of truth for

  * materialized parameters   (``build_params`` — real arrays),
  * abstract parameters       (``abstract_params`` — ShapeDtypeStructs for
                               the dry-run; 405B is never allocated),
  * logical sharding axes     (``build_axes`` — consumed by
                               repro.distributed.sharding).

All model code is purely functional: ``f(params, inputs) -> outputs``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None  # override stddev

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, p: Param, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "embed":
        std = p.scale if p.scale is not None else 1.0
        return (std * jax.random.normal(key, p.shape)).astype(dtype)
    # fan-in scaled normal
    fan_in = p.shape[0] if len(p.shape) > 1 else max(p.shape[0], 1)
    if len(p.shape) == 3:  # stacked experts / stacked layers: fan-in is dim 1
        fan_in = p.shape[1]
    std = p.scale if p.scale is not None else 1.0 / math.sqrt(fan_in)
    return (std * jax.random.normal(key, p.shape)).astype(dtype)


def build_params(spec: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """Materialize a spec tree into real parameter arrays."""
    leaves, treedef = jax.tree.flatten(
        spec, is_leaf=lambda x: isinstance(x, Param)
    )
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(k, p, dtype) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec: Any, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree (dry-run stand-ins; no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
        spec,
        is_leaf=lambda x: isinstance(x, Param),
    )


def build_axes(spec: Any) -> Any:
    """Tree of logical-axis tuples matching the param tree structure."""
    return jax.tree.map(
        lambda p: p.axes, spec, is_leaf=lambda x: isinstance(x, Param)
    )


def param_count(spec: Any) -> int:
    leaves = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, Param))
    return sum(int(math.prod(p.shape)) for p in leaves)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layernorm(
    x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def norm_spec(d: int, kind: str) -> Dict[str, Param]:
    if kind == "rmsnorm":
        return {"scale": Param((d,), ("embed",), init="zeros")}
    return {
        "scale": Param((d,), ("embed",), init="ones"),
        "bias": Param((d,), ("embed",), init="zeros"),
    }


def apply_norm(x: jax.Array, p: Dict[str, jax.Array], kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and multimodal M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for half the head dim."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim//2,)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    sin = jnp.sin(angles)[..., None, :]  # (B, S, 1, D/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float,
    sections: Tuple[int, int, int] = (2, 1, 1),
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the head dim's frequency bands are
    partitioned into temporal/height/width sections, each rotated by its
    own position stream. positions: (3, B, S). ``sections`` are relative
    weights over the head_dim//2 frequency bands (t:h:w = 2:1:1 here)."""
    half = x.shape[-1] // 2
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections[:-1]:
        acc += (half * s) // total
        bounds.append(acc)
    freqs = rope_frequencies(x.shape[-1], theta)  # (half,)
    # Select which position stream drives each frequency band.
    band = jnp.zeros((half,), jnp.int32)
    band = band.at[bounds[0]:].set(1)
    band = band.at[bounds[1]:].set(2)
    # positions: (3, B, S) -> per-band positions (B, S, half)
    pos = jnp.take_along_axis(
        positions.transpose(1, 2, 0).astype(jnp.float32),  # (B, S, 3)
        jnp.broadcast_to(band, positions.shape[1:3] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # (B, S, half)
    angles = pos * freqs  # (B, S, half)
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal positional embedding (T, D)."""
    log_timescale = math.log(10000.0) / max(dim // 2 - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_spec(d_model: int, d_ff: int, activation: str) -> Dict[str, Param]:
    if activation in ("swiglu", "geglu"):
        return {
            "gate": Param((d_model, d_ff), ("embed", "mlp")),
            "up": Param((d_model, d_ff), ("embed", "mlp")),
            "down": Param((d_ff, d_model), ("mlp", "embed")),
        }
    return {
        "up": Param((d_model, d_ff), ("embed", "mlp")),
        "up_bias": Param((d_ff,), ("mlp",), init="zeros"),
        "down": Param((d_ff, d_model), ("mlp", "embed")),
        "down_bias": Param((d_model,), ("embed",), init="zeros"),
    }


def apply_mlp(x: jax.Array, p: Dict[str, jax.Array], activation: str) -> jax.Array:
    if activation == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
        return h @ p["down"]
    if activation == "geglu":
        h = jax.nn.gelu(x @ p["gate"], approximate=True) * (x @ p["up"])
        return h @ p["down"]
    h = jax.nn.gelu(x @ p["up"] + p["up_bias"], approximate=True)
    return h @ p["down"] + p["down_bias"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d_model: int) -> Param:
    return Param((vocab, d_model), ("vocab", "embed"), init="embed", scale=0.02)


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Tied unembedding: bf16 operands, f32 accumulation (MXU-native) —
    avoids materializing an f32 copy of the (sharded) vocab table."""
    return jnp.einsum(
        "bsd,vd->bsv", x, table, preferred_element_type=jnp.float32
    )
