"""Decode-time caches: full KV, sliding-window ring KV, recurrent states.

Cache layout mirrors the transformer's scan structure: for each position
``j`` in the repeating block pattern there is one stacked entry with a
leading ``n_super`` axis, plus unstacked entries for tail layers. All
writes use per-batch positions (continuous batching: every sequence in
the batch owns its own write cursor).

Cache kinds per block type:
- attn  : full cache (B, S_max, KV, D) x2 + positions implied by cursor
- swa   : ring cache (B, window, KV, D) x2 + explicit slot positions
- rglru : Griffin state {h: (B, d_rnn), conv: (B, 3, d_rnn)}
- rwkv  : {shift: (B, D), wkv: (B, H, hd, hd), channel: (B, D)}
- cross : encoder K/V, written once at encode time (whisper)

Donation: every write helper is expressed as ``cache.at[...].set`` /
``dynamic_update_slice`` on the *input* cache, so a step jitted with the
cache in ``donate_argnums`` updates the buffers IN PLACE — the serving
engine's decode loop allocates O(batch) per step instead of copying the
whole cache (see serving/engine.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def cache_nbytes(cache) -> int:
    """Total on-device bytes of a cache pytree (resident-memory metrics)."""
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree.leaves(cache)
        if hasattr(x, "dtype")
    )


def cache_reset_rows(cache, rows: jax.Array):
    """Reset the given batch rows of a cache pytree to their initial state.

    ``rows``: (B,) bool — True rows are wiped, False rows untouched. Every
    cache leaf in this module is batch-major EXCEPT the stacked superblock
    entries, which carry a leading ``n_super`` axis before batch; leaves
    are matched by which axis equals ``B``. Ring-cache ``pos`` slots reset
    to -1 (the "never written" sentinel ``ring_cache_views`` checks),
    everything else to zero.

    This is the slot arena's row recycle: jitted with the cache donated
    (the engine's tpu/gpu default) it rewrites rows IN PLACE; without
    donation XLA materializes a fresh buffer, but either way the arena
    stays ONE pytree — no per-bucket cache objects are created or
    destroyed when slots turn over.
    """
    b = rows.shape[0]

    def key_names(path):
        return [getattr(k, "key", getattr(k, "name", None)) for k in path]

    def reset(path, x):
        if not hasattr(x, "dtype"):
            return x
        names = key_names(path)
        # Stacked superblock leaves are (n_super, B, ...); everything else
        # is batch-major. Dispatch on the path, not on shape coincidences.
        axis = 1 if names and names[0] == "super" else 0
        if x.ndim <= axis or x.shape[axis] != b:
            raise ValueError(
                f"cache leaf {names} has no batch axis {axis} of size {b}: "
                f"{x.shape}"
            )
        shape = [1] * x.ndim
        shape[axis] = b
        mask = rows.reshape(shape)
        fill = jnp.array(-1 if "pos" in names else 0, x.dtype)
        return jnp.where(mask, fill, x)

    return jax.tree_util.tree_map_with_path(reset, cache)


def attn_cache_init(batch: int, max_len: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
    }


def attn_cache_abstract(batch: int, max_len: int, n_kv: int, head_dim: int, dtype):
    s = jax.ShapeDtypeStruct((batch, max_len, n_kv, head_dim), dtype)
    return {"k": s, "v": s}


def attn_cache_write(
    cache: Dict, k: jax.Array, v: jax.Array, pos: jax.Array
) -> Dict:
    """k, v: (B, 1, KV, D); pos: (B,) absolute positions (cursor)."""
    b = k.shape[0]
    idx = jnp.arange(b)
    return {
        "k": cache["k"].at[idx, pos].set(k[:, 0].astype(cache["k"].dtype)),
        "v": cache["v"].at[idx, pos].set(v[:, 0].astype(cache["v"].dtype)),
    }


def attn_cache_views(cache: Dict, pos: jax.Array) -> Tuple:
    """(k, v, kv_positions, kv_valid) for full caches. pos: (B,) cursor =
    position of the newest token (already written)."""
    b, s = cache["k"].shape[:2]
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    valid = kv_pos <= pos[:, None]
    return cache["k"], cache["v"], kv_pos, valid


def ring_cache_init(batch: int, window: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "pos": jnp.full((batch, window), -1, jnp.int32),
    }


def ring_cache_abstract(batch: int, window: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jax.ShapeDtypeStruct((batch, window, n_kv, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((batch, window, n_kv, head_dim), dtype),
        "pos": jax.ShapeDtypeStruct((batch, window), jnp.int32),
    }


def ring_cache_write(cache: Dict, k: jax.Array, v: jax.Array, pos: jax.Array) -> Dict:
    b, window = cache["pos"].shape
    slot = pos % window
    idx = jnp.arange(b)
    return {
        "k": cache["k"].at[idx, slot].set(k[:, 0].astype(cache["k"].dtype)),
        "v": cache["v"].at[idx, slot].set(v[:, 0].astype(cache["v"].dtype)),
        "pos": cache["pos"].at[idx, slot].set(pos),
    }


def ring_cache_views(cache: Dict, pos: jax.Array) -> Tuple:
    kv_pos = cache["pos"]
    valid = kv_pos >= 0
    return cache["k"], cache["v"], kv_pos, valid


def ring_cache_fill_from_prefill(
    cache: Dict, k: jax.Array, v: jax.Array, positions: jax.Array
) -> Dict:
    """Bulk-populate a ring from a prefill's last ``window`` tokens.
    k, v: (B, S, KV, D); positions: (B, S)."""
    window = cache["pos"].shape[1]
    s = k.shape[1]
    take = min(window, s)
    k_tail, v_tail = k[:, -take:], v[:, -take:]
    p_tail = positions[:, -take:]
    slots = p_tail % window  # (B, take)
    bidx = jnp.arange(k.shape[0])[:, None]
    return {
        "k": cache["k"].at[bidx, slots].set(k_tail.astype(cache["k"].dtype)),
        "v": cache["v"].at[bidx, slots].set(v_tail.astype(cache["v"].dtype)),
        "pos": cache["pos"].at[bidx, slots].set(p_tail),
    }
