"""Recurrent sequence mixers: RG-LRU (RecurrentGemma/Griffin) and RWKV-6.

Both are linear recurrences with data-dependent, element-wise decay —
the attention-free long-context citizens of the architecture pool. The
prefill paths here are the pure-JAX references; the Pallas kernels
(repro.kernels.rglru / repro.kernels.wkv6) implement the same recurrences
with chunked VMEM tiling and are validated against these.

RG-LRU (arXiv:2402.19427 §2.4):
    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)  (data-dependent decay, c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Prefill uses jax.lax.associative_scan (the recurrence is affine in h, so
the (a, b) pairs compose associatively) — O(log S) depth on TPU.
The enclosing Griffin recurrent block: dual linear branches, a width-4
causal depthwise conv on the recurrent branch, GeLU gating on the other.

RWKV-6 "Finch" (arXiv:2404.05892): token-shift with data-dependent
interpolation (LoRA adapters), per-head matrix-valued state
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w0 + lora_w(x~_t))). Prefill is a lax.scan over time.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Param

RGLRU_C = 8.0
CONV_WIDTH = 4


# ===========================================================================
# RG-LRU / Griffin recurrent block
# ===========================================================================


def rglru_spec(d_rnn: int) -> Dict[str, Param]:
    return {
        "w_a": Param((d_rnn, d_rnn), ("mlp", "mlp2")),
        "b_a": Param((d_rnn,), ("mlp",), init="zeros"),
        "w_x": Param((d_rnn, d_rnn), ("mlp", "mlp2")),
        "b_x": Param((d_rnn,), ("mlp",), init="zeros"),
        # Lambda parameterized so softplus(Lambda) spans useful decays.
        "lam": Param((d_rnn,), ("mlp",), init="ones"),
    }


def rglru_gates(p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(decay a_t, input contribution b_t) for x: (..., S, D)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via log-space: 1 - exp(2 log_a)
    gate = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = gate * (i * xf)
    return a, b


def rglru_prefill(
    p: Dict, x: jax.Array, h0: Optional[jax.Array] = None,
    use_associative_scan: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (outputs (B, S, D), final state (B, D))."""
    a, b = rglru_gates(p, x)
    if h0 is not None:
        # Fold the carried state into the first step: h_1 = a_1 h0 + b_1.
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    if use_associative_scan:
        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, a2 * b1 + b2

        aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = hh
    else:
        def step(carry, ab):
            at, bt = ab
            h = at * carry + bt
            return h, h

        _, h = jax.lax.scan(
            step,
            jnp.zeros(x.shape[:1] + x.shape[2:], jnp.float32),
            (a.transpose(1, 0, 2), b.transpose(1, 0, 2)),
        )
        h = h.transpose(1, 0, 2)
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)


def rglru_step(
    p: Dict, x: jax.Array, h: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Decode: x (B, D), h (B, D) -> (out (B, D), h')."""
    a, b = rglru_gates(p, x[:, None, :])
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(x.dtype), h_new


def conv1d_spec(d: int) -> Dict[str, Param]:
    return {
        "w": Param((CONV_WIDTH, d), (None, "mlp")),
        "b": Param((d,), ("mlp",), init="zeros"),
    }


def causal_conv1d(p: Dict, x: jax.Array) -> jax.Array:
    """Depthwise causal conv, width 4. x: (B, S, D)."""
    pad = jnp.pad(x, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1]] * p["w"][i] for i in range(CONV_WIDTH)
    )
    return out + p["b"]


def causal_conv1d_step(
    p: Dict, x: jax.Array, conv_state: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Decode: x (B, D), conv_state (B, W-1, D) = previous inputs."""
    window = jnp.concatenate([conv_state, x[:, None, :]], axis=1)  # (B, W, D)
    out = jnp.einsum("bwd,wd->bd", window, p["w"]) + p["b"]
    return out, window[:, 1:]


def griffin_block_spec(d_model: int, d_rnn: int) -> Dict:
    return {
        "in_x": Param((d_model, d_rnn), ("embed", "mlp")),
        "in_gate": Param((d_model, d_rnn), ("embed", "mlp")),
        "conv": conv1d_spec(d_rnn),
        "rglru": rglru_spec(d_rnn),
        "out": Param((d_rnn, d_model), ("mlp", "embed")),
    }


def griffin_block(
    p: Dict, x: jax.Array, state: Optional[Dict] = None, impl: str = "xla"
) -> Tuple[jax.Array, Optional[Dict]]:
    """Griffin recurrent block, full-sequence form. x: (B, S, D).
    Returns (y, new_state) — state carries (h, conv window) for decode."""
    branch = x @ p["in_x"]
    gate = jax.nn.gelu(x @ p["in_gate"], approximate=True)
    h0 = None if state is None else state["h"]
    if state is None:
        conv_out = causal_conv1d(p["conv"], branch)
        hist = jnp.pad(branch, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    else:
        # Sequence continuation with conv history (chunked prefill/decode);
        # compute directly on the window including history (causal_conv1d
        # would re-pad with zeros and lose the carried inputs):
        hist = jnp.concatenate([state["conv"].astype(branch.dtype), branch], axis=1)
        conv_out = sum(
            hist[:, i : i + branch.shape[1]] * p["conv"]["w"][i]
            for i in range(CONV_WIDTH)
        ) + p["conv"]["b"]
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops

        a, bb = rglru_gates(p["rglru"], conv_out)
        if h0 is not None:
            bb = bb.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        rec, h_last = kernel_ops.rglru_scan(a, bb)
        rec = rec.astype(x.dtype)
    else:
        rec, h_last = rglru_prefill(p["rglru"], conv_out, h0)
    y = (rec * gate) @ p["out"]
    new_state = {
        "h": h_last,
        # The TRUE last W-1 raw inputs, including carried history when the
        # new chunk is shorter than the conv window (decode: S=1).
        # f32 for cache dtype stability across steps.
        "conv": hist[:, -(CONV_WIDTH - 1):].astype(jnp.float32),
    }
    return y, new_state


def griffin_block_step(
    p: Dict, x: jax.Array, state: Dict
) -> Tuple[jax.Array, Dict]:
    """Decode step. x: (B, D)."""
    branch = x @ p["in_x"]
    gate = jax.nn.gelu(x @ p["in_gate"], approximate=True)
    conv_out, conv_state = causal_conv1d_step(p["conv"], branch, state["conv"])
    rec, h = rglru_step(p["rglru"], conv_out, state["h"])
    y = (rec * gate) @ p["out"]
    return y, {"h": h, "conv": conv_state}


def griffin_init_state(batch: int, d_rnn: int) -> Dict:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, d_rnn), jnp.float32),
    }


# ===========================================================================
# RWKV-6 (Finch)
# ===========================================================================

LORA_RANK = 32


def _lora(d_in: int, d_out: int) -> Dict[str, Param]:
    return {
        "a": Param((d_in, LORA_RANK), ("embed", None), scale=0.02),
        "b": Param((LORA_RANK, d_out), (None, "embed"), scale=0.02),
    }


def _apply_lora(p: Dict, x: jax.Array) -> jax.Array:
    return jnp.tanh(x @ p["a"]) @ p["b"]


def rwkv6_timemix_spec(d_model: int, n_heads: int) -> Dict:
    head_dim = d_model // n_heads
    return {
        "mu": Param((5, d_model), (None, "embed"), scale=0.02),  # r,k,v,g,w
        "mu_x": Param((d_model,), ("embed",), scale=0.02),
        "lora_rkvgw": _lora(d_model, 5 * d_model),
        "w_r": Param((d_model, d_model), ("embed", "heads_flat")),
        "w_k": Param((d_model, d_model), ("embed", "heads_flat")),
        "w_v": Param((d_model, d_model), ("embed", "heads_flat")),
        "w_g": Param((d_model, d_model), ("embed", "heads_flat")),
        "w_o": Param((d_model, d_model), ("heads_flat", "embed")),
        "decay_base": Param((d_model,), ("embed",), init="zeros"),
        "lora_w": _lora(d_model, d_model),
        "bonus_u": Param((n_heads, head_dim), ("heads", "head_dim"), scale=0.02),
        "ln_scale": Param((d_model,), ("embed",), init="ones"),
        "ln_bias": Param((d_model,), ("embed",), init="zeros"),
    }


def _rwkv6_inputs(p: Dict, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift interpolation (Finch ddlerp) and
    per-channel decay. x, x_prev: (B, S, D)."""
    d = x.shape[-1]
    delta = x_prev - x
    x_base = x + delta * p["mu_x"]
    mods = _apply_lora(p["lora_rkvgw"], x_base).reshape(
        x.shape[:-1] + (5, d)
    )  # (B, S, 5, D)
    mix = p["mu"][None, None] + mods  # (B, S, 5, D)
    xr, xk, xv, xg, xw = [
        x + delta * mix[..., i, :] for i in range(5)
    ]
    r = xr @ p["w_r"]
    k = xk @ p["w_k"]
    v = xv @ p["w_v"]
    g = jax.nn.silu(xg @ p["w_g"])
    log_neg_w = p["decay_base"] + _apply_lora(p["lora_w"], xw)
    w = jnp.exp(-jnp.exp(log_neg_w.astype(jnp.float32)))  # (B, S, D) in (0,1)
    return r, k, v, g, w


def rwkv6_wkv_scan(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, S, H, V)
    w: jax.Array,  # (B, S, H, K) decay in (0,1)
    u: jax.Array,  # (H, K) bonus
    state: Optional[jax.Array] = None,  # (B, H, K, V)
) -> Tuple[jax.Array, jax.Array]:
    """The WKV-6 recurrence (pure scan reference). Returns (out, state')."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(S, inputs):
        rt, kt, vt, wt = inputs  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, out

    xs = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    state, outs = jax.lax.scan(step, state, xs)
    return outs.transpose(1, 0, 2, 3), state  # (B, S, H, V), (B,H,K,V)


def rwkv6_timemix(
    p: Dict,
    x: jax.Array,  # (B, S, D)
    n_heads: int,
    state: Optional[Dict] = None,
    impl: str = "xla",
) -> Tuple[jax.Array, Dict]:
    b, s, d = x.shape
    hd = d // n_heads
    if state is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        wkv_state = None
    else:
        x_prev = jnp.concatenate(
            [state["shift"][:, None, :].astype(x.dtype), x[:, :-1]], axis=1
        )
        wkv_state = state["wkv"]
    r, k, v, g, w = _rwkv6_inputs(p, x, x_prev)
    rh = r.reshape(b, s, n_heads, hd)
    kh = k.reshape(b, s, n_heads, hd)
    vh = v.reshape(b, s, n_heads, hd)
    wh = w.reshape(b, s, n_heads, hd)
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops

        out, wkv_new = kernel_ops.wkv6(rh, kh, vh, wh, p["bonus_u"], wkv_state)
    else:
        out, wkv_new = rwkv6_wkv_scan(rh, kh, vh, wh, p["bonus_u"], wkv_state)
    out = out.reshape(b, s, d)
    # Per-head group norm, then gate and output projection.
    oh = out.reshape(b, s, n_heads, hd)
    mu = jnp.mean(oh, -1, keepdims=True)
    var = jnp.var(oh, -1, keepdims=True)
    oh = (oh - mu) * jax.lax.rsqrt(var + 1e-5)
    out = oh.reshape(b, s, d) * p["ln_scale"] + p["ln_bias"]
    y = (out.astype(x.dtype) * g) @ p["w_o"]
    # States are kept f32 across steps (cache dtype stability).
    return y, {"shift": x[:, -1].astype(jnp.float32), "wkv": wkv_new}


def rwkv6_channelmix_spec(d_model: int, d_ff: int) -> Dict:
    return {
        "mu_k": Param((d_model,), ("embed",), scale=0.02),
        "mu_r": Param((d_model,), ("embed",), scale=0.02),
        "w_k": Param((d_model, d_ff), ("embed", "mlp")),
        "w_v": Param((d_ff, d_model), ("mlp", "embed")),
        "w_r": Param((d_model, d_model), ("embed", "embed2")),
    }


def rwkv6_channelmix(
    p: Dict, x: jax.Array, state: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """state: (B, D) last token (None = zero-shift prefill)."""
    if state is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        x_prev = jnp.concatenate(
            [state[:, None, :].astype(x.dtype), x[:, :-1]], axis=1
        )
    delta = x_prev - x
    xk = x + delta * p["mu_k"]
    xr = x + delta * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"])
    return out, x[:, -1].astype(jnp.float32)


def rwkv6_init_state(batch: int, d_model: int, n_heads: int) -> Dict:
    hd = d_model // n_heads
    return {
        "time": {
            "shift": jnp.zeros((batch, d_model), jnp.float32),
            "wkv": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        },
        "channel": jnp.zeros((batch, d_model), jnp.float32),
    }
