"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Routing is top-k softmax (mixtral: k=2 over 8 experts; llama4-maverick:
k=1 over 128 experts + a shared expert). Dispatch is the TPU-friendly
sort-based scheme (MaxText-style): tokens are ranked within their expert
group and dropped beyond capacity, giving static shapes and active-FLOPs
proportional to tokens*k — NOT the dense all-experts einsum, whose HLO
FLOPs would be E/k times too large and would poison the roofline numbers.

Expert weights are stacked (E, d, f) and logically sharded on the
"expert" axis; the (E, C, d) dispatch buffer is annotated so GSPMD
inserts the token all-to-all of expert parallelism.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Param, apply_mlp, mlp_spec
from repro.models.sharding_hooks import constrain


def moe_spec(
    d_model: int,
    d_ff: int,
    n_experts: int,
    activation: str,
    shared_expert: bool,
) -> Dict:
    spec = {
        "router": Param((d_model, n_experts), ("embed", "expert"), scale=0.02),
        "gate": Param((n_experts, d_model, d_ff), ("expert", "embed", "mlp")),
        "up": Param((n_experts, d_model, d_ff), ("expert", "embed", "mlp")),
        "down": Param((n_experts, d_ff, d_model), ("expert", "mlp", "embed")),
    }
    if shared_expert:
        spec["shared"] = mlp_spec(d_model, d_ff, activation)
    return spec


def apply_moe(
    p: Dict,
    x: jax.Array,  # (B, S, D)
    *,
    top_k: int,
    activation: str,
    capacity_factor: float = 1.25,
    min_capacity: int = 4,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss). aux_loss is the standard load-balancing
    loss (mean over experts of fraction_tokens * fraction_probs * E).

    When a mesh is installed (sharding_hooks.set_moe_mesh) and the batch
    divides the data axes, dispatch runs in the shard_map local path —
    GSPMD cannot shard data-dependent sort/scatter and falls back to
    replication-by-all-reduce, which measured 18.7 TB/device of
    all-reduce on llama4 prefill (EXPERIMENTS.md §Perf iteration 3)."""
    from repro.models.sharding_hooks import moe_mesh

    mesh = moe_mesh()
    if mesh is not None:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        n_shards = 1
        for a in data_axes:
            n_shards *= mesh.shape[a]
        if data_axes and x.shape[0] % n_shards == 0 and x.shape[0] >= n_shards:
            return _apply_moe_local(
                p, x, mesh, data_axes,
                top_k=top_k, activation=activation,
                capacity_factor=capacity_factor, min_capacity=min_capacity,
            )
    return _apply_moe_global(
        p, x, top_k=top_k, activation=activation,
        capacity_factor=capacity_factor, min_capacity=min_capacity,
    )


def _apply_moe_local(
    p: Dict,
    x: jax.Array,
    mesh,
    data_axes,
    *,
    top_k: int,
    activation: str,
    capacity_factor: float,
    min_capacity: int,
) -> Tuple[jax.Array, jax.Array]:
    """shard_map over the data axes (model axis stays automatic):
    - token routing/sort/scatter: LOCAL per data shard (no collectives);
    - FSDP'd weight dims: explicit all_gather over the data axes (the
      gather GSPMD would otherwise insert implicitly, with reduce-scatter
      as its transpose in the backward pass);
    - expert (or in-expert TP) sharding over 'model': automatic GSPMD,
      including the single per-layer output all-reduce."""
    import jax as _jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shd

    def param_manual_spec(leaf, axes):
        full = shd.spec_for_shape(leaf.shape, axes, mesh, shd.PARAM_RULES)
        manual = []
        for entry in full:
            if entry is None:
                manual.append(None)
            elif isinstance(entry, tuple):
                kept = tuple(a for a in entry if a in data_axes)
                manual.append(kept if kept else None)
            else:
                manual.append(entry if entry in data_axes else None)
        return P(*manual)

    axes_map = {
        "router": ("embed", "expert"),
        "gate": ("expert", "embed", "mlp"),
        "up": ("expert", "embed", "mlp"),
        "down": ("expert", "mlp", "embed"),
    }
    shared_axes = {
        "gate": ("embed", "mlp"), "up": ("embed", "mlp"),
        "down": ("mlp", "embed"),
        "up_bias": ("mlp",), "down_bias": ("embed",),
    }

    in_specs_p = {}
    for name in axes_map:
        in_specs_p[name] = param_manual_spec(p[name], axes_map[name])
    if "shared" in p:
        in_specs_p["shared"] = {
            k: param_manual_spec(p["shared"][k], shared_axes[k])
            for k in p["shared"]
        }
    x_spec = P(data_axes if len(data_axes) > 1 else data_axes[0], None, None)

    def gather_full(w, spec):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for a in names:
                w = _jax.lax.all_gather(w, a, axis=dim, tiled=True)
        return w

    def body(x_loc, p_loc):
        full = {
            name: gather_full(p_loc[name], in_specs_p[name])
            for name in axes_map
        }
        if "shared" in p_loc:
            full["shared"] = {
                k: gather_full(p_loc["shared"][k], in_specs_p["shared"][k])
                for k in p_loc["shared"]
            }
        out, aux = _apply_moe_global(
            full, x_loc, top_k=top_k, activation=activation,
            capacity_factor=capacity_factor, min_capacity=min_capacity,
            # No logical-axis hints inside the partial-auto manual region:
            # with_sharding_constraint on auto axes inside shard_map grad
            # triggers an XLA partitioner check failure (jax 0.8 / XLA).
            use_constraints=False,
        )
        # aux is a per-shard mean; average across data shards.
        for a in data_axes:
            aux = _jax.lax.pmean(aux, a)
        return out, aux

    p_in = {k: p[k] for k in axes_map}
    if "shared" in p:
        p_in["shared"] = p["shared"]
    specs_in = {k: in_specs_p[k] for k in axes_map}
    if "shared" in p:
        specs_in["shared"] = in_specs_p["shared"]
    return _jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, specs_in),
        out_specs=(x_spec, P()),
        axis_names=frozenset(data_axes),
        check_vma=False,
    )(x, p_in)


def _apply_moe_global(
    p: Dict,
    x: jax.Array,  # (B, S, D)
    *,
    top_k: int,
    activation: str,
    capacity_factor: float = 1.25,
    min_capacity: int = 4,
    use_constraints: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    e = p["router"].shape[1]
    t = b * s
    xf = x.reshape(t, d)

    router_logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    top_w, top_e = jax.lax.top_k(probs, top_k)  # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch/Mixtral convention).
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce)

    capacity = max(
        min_capacity, int(math.ceil(t * top_k / e * capacity_factor))
    )

    # ---- sort-based dispatch ------------------------------------------
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_w = top_w.reshape(-1).astype(x.dtype)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_w = flat_w[order]
    # Rank within expert group: arange minus the group's start offset.
    counts = jnp.bincount(sorted_e, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * top_k) - starts[sorted_e]
    keep = rank < capacity
    slot = jnp.where(keep, sorted_e * capacity + rank, e * capacity)  # drop slot

    dispatched = jnp.zeros((e * capacity + 1, d), x.dtype)
    dispatched = dispatched.at[slot].set(xf[sorted_tok])
    xe = dispatched[:-1].reshape(e, capacity, d)
    if use_constraints:
        xe = constrain(xe, ("expert", None, "embed"))

    # ---- expert FFN (stacked einsum) -----------------------------------
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else (
            lambda z: jax.nn.gelu(z, approximate=True)
        )
        h = act(jnp.einsum("ecd,edf->ecf", xe, p["gate"])) * jnp.einsum(
            "ecd,edf->ecf", xe, p["up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["up"]), approximate=True)
    ye = jnp.einsum("ecf,efd->ecd", h, p["down"])
    if use_constraints:
        ye = constrain(ye, ("expert", None, "embed"))

    # ---- combine ---------------------------------------------------------
    yflat = ye.reshape(e * capacity, d)
    contrib = jnp.where(keep[:, None], yflat[jnp.minimum(slot, e * capacity - 1)], 0.0)
    out = jnp.zeros((t, d), x.dtype)
    out = out.at[sorted_tok].add(contrib * sorted_w[:, None])

    if "shared" in p:
        out = out + apply_mlp(xf, p["shared"], activation)
    return out.reshape(b, s, d), aux


def apply_moe_dense_reference(
    p: Dict, x: jax.Array, *, top_k: int, activation: str
) -> jax.Array:
    """Oracle: every token through every expert, weighted by the top-k
    router weights (no capacity drops). Used only in tests."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    xf = x.reshape(-1, d)
    probs = jax.nn.softmax(xf.astype(jnp.float32) @ p["router"].astype(jnp.float32), -1)
    top_w, top_e = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    weights = jnp.zeros((xf.shape[0], e), jnp.float32).at[
        jnp.arange(xf.shape[0])[:, None], top_e
    ].set(top_w)
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else (
            lambda z: jax.nn.gelu(z, approximate=True)
        )
        h = act(jnp.einsum("td,edf->tef", xf, p["gate"])) * jnp.einsum(
            "td,edf->tef", xf, p["up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("td,edf->tef", xf, p["up"]), approximate=True)
    ye = jnp.einsum("tef,efd->ted", h, p["down"])
    out = jnp.einsum("ted,te->td", ye.astype(jnp.float32), weights).astype(x.dtype)
    if "shared" in p:
        out = out + apply_mlp(xf, p["shared"], activation)
    return out.reshape(b, s, d)
