"""Pluggable activation-sharding hook.

Model code annotates activations with *logical* axis names via
``constrain(x, ("batch", "seq", "embed"))``. Outside any mesh this is the
identity; the distributed layer (repro.distributed.sharding) installs a
resolver that maps logical axes to mesh axes and applies
``jax.lax.with_sharding_constraint``. Keeping the hook here avoids a
models -> distributed import cycle and keeps the model zoo runnable on a
single device with zero distribution machinery.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax

_RESOLVER: Optional[Callable[[jax.Array, Tuple[Optional[str], ...]], jax.Array]] = None


def set_resolver(fn) -> None:
    global _RESOLVER
    _RESOLVER = fn


def clear_resolver() -> None:
    set_resolver(None)


def constrain(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    if _RESOLVER is None:
        return x
    return _RESOLVER(x, axes)


# --- MoE shard_map context ---------------------------------------------------
# When a mesh is installed, moe.apply_moe switches to the local-dispatch
# shard_map path: token routing (sort/scatter) runs per data shard with
# ZERO collectives, expert/TP sharding stays automatic on the model axis.
_MOE_MESH = None


def set_moe_mesh(mesh) -> None:
    global _MOE_MESH
    _MOE_MESH = mesh


def clear_moe_mesh() -> None:
    set_moe_mesh(None)


def moe_mesh():
    return _MOE_MESH
