"""LM-family model zoo (pure functional JAX)."""
from repro.configs.base import ModelConfig
from repro.models.encdec import EncDecTransformer
from repro.models.transformer import Transformer


def model_for(cfg: ModelConfig):
    """Instantiate the right model class for a config."""
    if cfg.encdec:
        return EncDecTransformer(cfg)
    return Transformer(cfg)


__all__ = ["ModelConfig", "Transformer", "EncDecTransformer", "model_for"]
