"""AsyncDevice: the live-serving side of the shared device contract.

``SequentialDevice`` (core/simulator.py) models a one-program-at-a-time
accelerator in virtual time: ``submit`` returns immediately and the
completion fires as a future loop event, so host-side scheduling overlaps
device execution. This class gives the LIVE wall-clock path the exact
same shape:

- ``submit`` launches the job through JAX async dispatch (``dispatch_fn``
  returns a ``StepHandle`` without blocking) and returns to the event
  loop immediately — DisBatcher window joints, admission tests, and
  adaptation all run while XLA executes;
- a single lightweight waiter thread blocks on ``handle.wait()``
  (``block_until_ready`` underneath) and posts the completion back onto
  the loop thread via ``WallClock.post`` — callbacks never run off-loop;
- ``busy_until`` is the profiled *estimate* (the submit-time
  ``exec_time``), which is what the admission snapshot reads; the actual
  completion instant is whatever the hardware delivers.

The EDF worker's submit-only-when-idle discipline is unchanged, so the
non-preemptive EDF semantics (and the Phase-2 imitator's model of them)
are identical to simulation — the only difference is that the loop no
longer stalls for the duration of each job.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional


class AsyncDevice:
    """Wall-clock sequential device with non-blocking dispatch.

    Parameters
    ----------
    loop:
        A ``WallClock`` (needs ``post``/``hold``/``release``).
    dispatch_fn:
        job -> handle. Must launch the job without blocking and return a
        handle whose ``wait()`` blocks until device completion (see
        ``serving.engine.StepHandle``).
    """

    def __init__(
        self,
        loop,
        dispatch_fn: Callable[[object], object],
        on_idle: Optional[Callable[[], None]] = None,
    ):
        self.loop = loop
        self.dispatch_fn = dispatch_fn
        self.on_idle = on_idle
        self._busy_until: Optional[float] = None
        self._closed = False
        self.last_error: Optional[Exception] = None
        self.busy_time = 0.0  # total measured seconds executing
        self.resident_bytes = 0.0
        self.peak_bytes = 0.0
        self._inbox: "queue.Queue" = queue.Queue()
        self._waiter = threading.Thread(
            target=self._wait_loop, name="asyncdevice-waiter", daemon=True
        )
        self._waiter.start()

    @property
    def idle(self) -> bool:
        # A closed device (its slice failed) is never idle: the EDF
        # worker's submit-only-when-idle discipline then guarantees no
        # further dispatch without any scheduler-side special-casing —
        # the dead slice's queued jobs simply never start.
        return not self._closed and self._busy_until is None

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def busy_until(self) -> Optional[float]:
        return self._busy_until

    def submit(
        self,
        job: object,
        exec_time: float,
        on_complete: Callable[[object, float], None],
        job_bytes: float = 0.0,
    ) -> None:
        """Non-blocking: async-dispatch the job, hand the handle to the
        waiter, return to the loop. ``exec_time`` is the estimate used
        for ``busy_until`` only (contract: simulator.SequentialDevice)."""
        if self._closed:
            raise RuntimeError("AsyncDevice is closed (slice failed)")
        if not self.idle:
            raise RuntimeError("AsyncDevice is busy; EDF worker bug")
        start = self.loop.now
        self._busy_until = start + exec_time
        self.resident_bytes += job_bytes
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)
        handle = self.dispatch_fn(job)  # returns immediately (JAX async)
        self.loop.hold()  # keep run() alive while the heap may be empty
        self._inbox.put((job, handle, on_complete, job_bytes, start))

    # ----- waiter thread --------------------------------------------------
    def _wait_loop(self) -> None:
        while True:
            item = self._inbox.get()
            if item is None:
                return
            job, handle, on_complete, job_bytes, start = item
            err = None
            try:
                handle.wait()
            except Exception as e:  # re-raised on the loop thread
                err = self.last_error = e
            self.loop.post(
                lambda j=job, cb=on_complete, bts=job_bytes, s=start, x=err: (
                    self._complete(j, cb, bts, s, x)
                ),
                priority=getattr(self.loop, "PRIO_COMPLETE", 1),
            )
            self.loop.release()

    # ----- loop-thread completion ----------------------------------------
    def _complete(
        self, job, on_complete, job_bytes: float, start: float,
        err: Optional[Exception] = None,
    ) -> None:
        now = self.loop.now
        self.busy_time += now - start
        self._busy_until = None
        self.resident_bytes -= job_bytes
        if self._closed:
            # The slice died while this job was in flight: its frames are
            # lost with the slice (the cluster re-admits the request's
            # remaining tail elsewhere). Reporting the completion would
            # count dead frames as served and re-enter EDF dispatch on a
            # device that can no longer execute.
            return
        if err is not None:
            # A failed execution must NOT be reported as a completed job
            # (frames would count as deadline-met with no output). Device
            # state is released, then the failure propagates out of
            # loop.run() to the caller.
            raise RuntimeError(f"device execution failed for {job!r}") from err
        on_complete(job, now)
        if self.on_idle is not None:
            self.on_idle()

    def close(self) -> None:
        """Fail-stop the device (idempotent): refuse new submissions,
        report not-idle forever, swallow the in-flight completion if any,
        and stop the waiter thread once it drains. The live cluster's
        ``fail_slice`` calls this before re-admitting the slice's
        requests elsewhere."""
        if self._closed:
            return
        self._closed = True
        self._inbox.put(None)
