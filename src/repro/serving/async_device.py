"""AsyncDevice: the live-serving side of the shared device contract.

``SequentialDevice`` (core/simulator.py) models a one-program-at-a-time
accelerator in virtual time: ``submit`` returns immediately and the
completion fires as a future loop event, so host-side scheduling overlaps
device execution. This class gives the LIVE wall-clock path the exact
same shape:

- ``submit`` launches the job through JAX async dispatch (``dispatch_fn``
  returns a ``StepHandle`` without blocking) and returns to the event
  loop immediately — DisBatcher window joints, admission tests, and
  adaptation all run while XLA executes;
- a single lightweight waiter thread blocks on ``handle.wait()``
  (``block_until_ready`` underneath) and posts the completion back onto
  the loop thread via ``WallClock.post`` — callbacks never run off-loop;
- ``busy_until`` is the profiled *estimate* (the submit-time
  ``exec_time``), which is what the admission snapshot reads; the actual
  completion instant is whatever the hardware delivers.

Health hooks: when a ``watchdog`` (core/faults.CompletionWatchdog) is
attached, every submit arms a completion deadline on the loop thread and
every completion disarms it — a hung ``block_until_ready`` therefore
becomes a *visible* overdue signal instead of a silent wedge.  When
``on_measured`` is set, each completion reports ``(expected, actual)``
seconds to it, which is what feeds live WCET re-profiling.

The EDF worker's submit-only-when-idle discipline is unchanged, so the
non-preemptive EDF semantics (and the Phase-2 imitator's model of them)
are identical to simulation — the only difference is that the loop no
longer stalls for the duration of each job.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

from repro.core import telemetry as T


class _Inflight:
    """One submitted job travelling from the loop to the waiter and back."""

    __slots__ = ("job", "handle", "on_complete", "job_bytes", "start", "exec_time", "released")

    def __init__(self, job, handle, on_complete, job_bytes, start, exec_time):
        self.job = job
        self.handle = handle
        self.on_complete = on_complete
        self.job_bytes = job_bytes
        self.start = start
        self.exec_time = exec_time
        self.released = False


class AsyncDevice:
    """Wall-clock sequential device with non-blocking dispatch.

    Parameters
    ----------
    loop:
        A ``WallClock`` (needs ``post``/``hold``/``release``).
    dispatch_fn:
        job -> handle. Must launch the job without blocking and return a
        handle whose ``wait()`` blocks until device completion (see
        ``serving.engine.StepHandle``).
    """

    #: Seconds ``close()`` waits for the waiter thread before declaring
    #: it wedged and abandoning it (a hung ``block_until_ready`` never
    #: returns; shutdown must not inherit the hang).
    JOIN_TIMEOUT = 0.5

    def __init__(
        self,
        loop,
        dispatch_fn: Callable[[object], object],
        on_idle: Optional[Callable[[], None]] = None,
        join_timeout: Optional[float] = None,
    ):
        self.loop = loop
        self.dispatch_fn = dispatch_fn
        self.on_idle = on_idle
        self.join_timeout = self.JOIN_TIMEOUT if join_timeout is None else join_timeout
        self._busy_until: Optional[float] = None
        self._closed = False
        self.wedged = False  # close() timed out joining a stuck waiter
        self.last_error: Optional[Exception] = None
        self.busy_time = 0.0  # total measured seconds executing
        self.resident_bytes = 0.0
        self.peak_bytes = 0.0
        # Health hooks (both optional; attached by the live cluster
        # factory). ``watchdog.started/completed`` run on the loop
        # thread; ``on_measured(expected, actual)`` fires per completion.
        self.watchdog = None
        self.on_measured: Optional[Callable[[float, float], None]] = None
        # Frame-lifecycle tracer (core/telemetry.py); None = off. This
        # is the live-only expected-vs-measured lane — simulation has no
        # hardware clock to disagree with.
        self.tracer = None
        self.tracer_tag: Optional[str] = None
        self._lock = threading.Lock()
        self._inflight: Optional[_Inflight] = None
        self._inbox: "queue.Queue" = queue.Queue()
        self._waiter = threading.Thread(
            target=self._wait_loop, name="asyncdevice-waiter", daemon=True
        )
        self._waiter.start()

    @property
    def idle(self) -> bool:
        # A closed device (its slice failed) is never idle: the EDF
        # worker's submit-only-when-idle discipline then guarantees no
        # further dispatch without any scheduler-side special-casing —
        # the dead slice's queued jobs simply never start.
        return not self._closed and self._busy_until is None

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def busy_until(self) -> Optional[float]:
        return self._busy_until

    def submit(
        self,
        job: object,
        exec_time: float,
        on_complete: Callable[[object, float], None],
        job_bytes: float = 0.0,
    ) -> None:
        """Non-blocking: async-dispatch the job, hand the handle to the
        waiter, return to the loop. ``exec_time`` is the estimate used
        for ``busy_until`` only (contract: simulator.SequentialDevice)."""
        if self._closed:
            raise RuntimeError("AsyncDevice is closed (slice failed)")
        if not self.idle:
            raise RuntimeError("AsyncDevice is busy; EDF worker bug")
        start = self.loop.now
        self._busy_until = start + exec_time
        self.resident_bytes += job_bytes
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)
        handle = self.dispatch_fn(job)  # returns immediately (JAX async)
        if self.watchdog is not None:
            self.watchdog.started(job, exec_time)
        self.loop.hold()  # keep run() alive while the heap may be empty
        item = _Inflight(job, handle, on_complete, job_bytes, start, exec_time)
        with self._lock:
            self._inflight = item
        self._inbox.put(item)

    # ----- waiter thread --------------------------------------------------
    def _wait_loop(self) -> None:
        while True:
            item = self._inbox.get()
            if item is None:
                return
            err = None
            try:
                item.handle.wait()
            except Exception as e:  # re-raised on the loop thread
                err = self.last_error = e
            self.loop.post(
                lambda it=item, x=err: self._complete(it, x),
                priority=getattr(self.loop, "PRIO_COMPLETE", 1),
            )
            self._release_once(item)

    def _release_once(self, item: _Inflight) -> None:
        """Release the loop hold for ``item`` exactly once — called by the
        waiter on completion AND by ``close()`` when it abandons a wedged
        waiter; whichever runs second is a no-op, so ``WallClock``'s
        hold/release pairing survives the race."""
        with self._lock:
            if item.released:
                return
            item.released = True
            if self._inflight is item:
                self._inflight = None
        self.loop.release()

    # ----- loop-thread completion ----------------------------------------
    def _complete(self, item: _Inflight, err: Optional[Exception] = None) -> None:
        now = self.loop.now
        actual = now - item.start
        self.busy_time += actual
        self._busy_until = None
        self.resident_bytes -= item.job_bytes
        if self.watchdog is not None:
            self.watchdog.completed()
        if self.tracer is not None:
            self.tracer.emit(
                T.DEVICE_MEASURED, now, where=self.tracer_tag,
                meta={"expected": item.exec_time, "actual": actual})
        if self._closed:
            # The slice died while this job was in flight: its frames are
            # lost with the slice (the cluster re-admits the request's
            # remaining tail elsewhere). Reporting the completion would
            # count dead frames as served and re-enter EDF dispatch on a
            # device that can no longer execute.
            return
        if err is not None:
            # A failed execution must NOT be reported as a completed job
            # (frames would count as deadline-met with no output). Device
            # state is released, then the failure propagates out of
            # loop.run() to the caller.
            raise RuntimeError(f"device execution failed for {item.job!r}") from err
        if self.on_measured is not None:
            self.on_measured(item.exec_time, actual)
            if self._closed:
                # This very measurement was the late signal that
                # quarantined the slice (note_complete -> fail_slice ->
                # close): the job's frames are already reconciled as
                # lost — reporting the completion would double-count.
                return
        item.on_complete(item.job, now)
        if self.on_idle is not None:
            self.on_idle()

    def close(self) -> None:
        """Fail-stop the device (idempotent): refuse new submissions,
        report not-idle forever, swallow the in-flight completion if any,
        and join the waiter thread with a timeout. If an in-flight step
        is wedged inside ``block_until_ready`` the join times out, the
        device marks itself ``wedged``, abandons the daemon waiter with
        its hung handle, and releases the in-flight hold on the loop so
        ``run()`` can terminate — shutdown never inherits the hang. The
        live cluster's ``fail_slice`` calls this before re-admitting the
        slice's requests elsewhere."""
        if self._closed:
            return
        self._closed = True
        if self.watchdog is not None:
            self.watchdog.close()
        self._inbox.put(None)
        self._waiter.join(timeout=self.join_timeout)
        if self._waiter.is_alive():
            self.wedged = True
            with self._lock:
                item = self._inflight
            if item is not None:
                self._release_once(item)
