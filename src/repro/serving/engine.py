"""Inference engine: compiled batched steps for DeepRT categories.

Two execution regimes, matching the two step kinds of the shape pool:

- PREFILL (full forward over (b, seq) tokens -> last-token logits) is
  bucketed: one XLA program per (model, seq, batch bucket), batch sizes
  padded up to the next power of two via the SHARED
  ``repro.core.bucketing.bucket`` (the same rounding the profiler grid
  and the admission WCET lookup use), so the compile count stays
  logarithmic while the table stays consistent with what actually runs.

- DECODE (one token against a KV cache) runs on a SLOT ARENA: each
  (model, seq) owns ONE resident KV arena of ``max_slots`` rows — a
  single donated buffer that lives across steps — and ONE compiled
  program that always executes all ``max_slots`` rows. The live batch
  size is carried as DATA (a per-row active bitmap + per-row cursors),
  not as a shape:

    * zero decode recompiles at runtime: any batch 1..max_slots hits the
      same program, so a DisBatcher job crossing an old bucket boundary
      can no longer land on a cold program (the lazy-compile stall that
      could blow a deadline on its own);
    * zero cache churn: there is no per-bucket cache to re-create when
      the batch size changes — rows are assigned/freed by the slot
      allocator (``alloc_slots``/``free_slots``) and recycled with an
      in-place row reset (``kvcache.cache_reset_rows``), never by
      re-allocating the arena;
    * flat per-step cost: dead rows carry ``active=0`` so the decode
      attention path (Pallas kernel block-skip, or the dense mask) does
      no KV work for them — admission's flat decode WCET
      (``ProfileTable.record_flat``) is the cost of the program that
      really runs, at every batch size.

Hot-path design (the zero-stall serving pipeline):

- ``dispatch`` launches a step WITHOUT blocking: JAX async dispatch
  returns futures, the host thread goes straight back to scheduling, and
  the ``AsyncDevice`` waiter observes completion via ``StepHandle.wait``.
  ``execute`` (= dispatch + wait) remains the synchronous path for the
  offline profiler and the benchmarks.
- KV arenas are DONATED (``jax.jit(..., donate_argnums=...)``) where the
  backend profits from it: each decode step updates the arena in place
  (buffer identity is preserved across steps), so per-step allocation is
  O(batch) instead of O(cache). ``donate_cache=None`` resolves by
  backend: True on tpu/gpu, False on cpu — CPU XLA honors the aliasing
  but charges a fixed per-dispatch donation bookkeeping cost (~50µs+ per
  step, growing with the number of donated leaves) that swamps the
  avoided copy at small model sizes; see BENCH_serving_hotpath.json.
- Inputs are REAL ingested bytes, staged through double-buffered
  host->device rings (``repro.ingest.staging.StagingRing``, one ring
  per compiled program input, keyed (kind, mid, seq, batch)): the ring
  cycles a fixed pool of host scratch buffers — fill buffer B while the
  in-flight program reads A — so steady-state staging performs ZERO
  fresh host allocations and job N's output can never observe job
  N+1's payload. ``dispatch(payload=...)`` carries the frames' token
  bytes; ``payload=None`` stages a zero frame through the SAME ring
  (the offline profiler's input — WCET is payload-independent). The
  old preallocated synthetic-zeros buffer (`_stage`) is gone.

``max_slots`` sizing: use ``repro.core.bucketing.arena_slots`` over the
largest batch admission can produce — Phase 1 bounds the mean frames per
DisBatcher window at ``n_g = floor(sum_m W_g / p_m)``, so
``arena_slots(n_g_max + 1)`` rows suffice for every admissible job (the
ROADMAP "device contract" note records the rule). Decode dispatches
larger than ``max_slots`` are rejected loudly rather than re-shaped.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bucketing import bucket
from repro.ingest.staging import StagingRing, check_payload_dtype
from repro.models import model_for
from repro.models.kvcache import cache_nbytes, cache_reset_rows


@dataclass
class StepHandle:
    """One in-flight dispatched step (outputs may still be computing)."""

    outputs: Any  # jax array(s): prefill -> next tokens; decode -> logits
    mid: str
    kind: str
    true_batch: int
    bucket_batch: int  # prefill: the pow2 bucket; decode: max_slots
    steps: int = 1  # decode steps this dispatch executed (chunk depth)

    def wait(self) -> Any:
        """Block until the device finishes; returns the ready outputs."""
        jax.block_until_ready(self.outputs)
        return self.outputs


@dataclass
class SlotArena:
    """One model's resident decode state for one seq length.

    ``cache`` is the single KV buffer (batch axis = max_slots) that
    lives across steps — donated (in-place, tpu/gpu default) or
    functionally replaced (cpu default; see the donate gate in the
    module docstring). ``cur``/``active`` are DEVICE-resident
    per-row cursors and the live-slot bitmap: the compiled step consumes
    them directly and returns the advanced cursors, so steady-state
    slot-mode decode does ZERO host->device transfers — membership
    changes (alloc/free) are the only time the bitmap is re-uploaded.
    ``free`` are the unassigned row ids; ``allocs``/``resets`` count
    allocator traffic for the churn metrics.
    """

    cache: Any
    max_slots: int
    cur: jax.Array = None
    active: jax.Array = None
    free: List[int] = field(default_factory=list)
    allocs: int = 0
    resets: int = 0

    @property
    def live(self) -> Tuple[int, ...]:
        free = set(self.free)
        return tuple(i for i in range(self.max_slots) if i not in free)


class InferenceEngine:
    def __init__(
        self,
        configs: Dict[str, ModelConfig],
        seed: int = 0,
        donate_cache: Optional[bool] = None,
        masked_decode: bool = True,
        max_slots: int = 8,
        staging_depth: int = 2,
        chunk_depth: int = 1,
    ):
        """``donate_cache``: None resolves by backend (module docstring);
        explicit True/False force it — the benchmark A/Bs both arms.
        ``masked_decode=False`` recreates blind padding (every arena row
        does full attention work) — kept ONLY for the padding-waste A/B.
        ``max_slots``: decode arena rows per (model, seq); see the
        module docstring for the sizing rule.
        ``staging_depth``: host scratch buffers per staging ring; depth-1
        bounds concurrently in-flight staged jobs (the EDF worker keeps
        at most one in flight, so 2 = classic double buffering).
        ``chunk_depth``: deepest multi-step decode chunk this engine will
        serve (``decode_chunk``). A k-step chunk stages one DECODE ring
        slot per step behind a single consumer, so decode rings are
        sized ``max(staging_depth, chunk_depth + 1)`` — the depth must
        be fixed before a ring's first use, hence a construction-time
        parameter. 1 = chunking off (rings stay at ``staging_depth``).
        """
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if chunk_depth < 1:
            raise ValueError(f"chunk_depth must be >= 1, got {chunk_depth}")
        self.configs = dict(configs)
        self.models = {mid: model_for(cfg) for mid, cfg in configs.items()}
        if donate_cache is None:
            donate_cache = jax.default_backend() != "cpu"
        self.donate_cache = donate_cache
        self.masked_decode = masked_decode
        self.max_slots = max_slots
        key = jax.random.PRNGKey(seed)
        self.params = {}
        for i, (mid, model) in enumerate(self.models.items()):
            self.params[mid] = model.init(jax.random.fold_in(key, i))
        self._compiled: Dict[Tuple, Any] = {}
        self._arenas: Dict[Tuple[str, int], SlotArena] = {}
        self.staging_depth = staging_depth
        self.max_chunk_depth = chunk_depth
        self._rings: Dict[Tuple, StagingRing] = {}
        # All-active step masks per (k, max_slots): resident, the common
        # profiler/benchmark chunk input (no per-chunk host upload).
        self._full_masks: Dict[int, jax.Array] = {}
        # Prefix-mode decode inputs per (mid, seq, live-count): tiny
        # (max_slots,) arrays, cached so the steady-state hot loop does
        # zero host->device transfers.
        self._decode_inputs: Dict[Tuple, Tuple[jax.Array, jax.Array]] = {}
        self._reset_fn = jax.jit(
            cache_reset_rows, donate_argnums=(0,) if donate_cache else ()
        )
        # Set by ``freeze`` when the slice owning this engine fails: the
        # cluster layer re-admits the slice's requests elsewhere, and
        # nothing may touch this engine's arenas again.
        self.frozen = False
        # Measured padding/compile accounting.
        self.stats: Dict[str, int] = {}
        self.reset_stats()

    def reset_stats(self) -> None:
        """Zero the padding/dispatch/compile counters. build_live_scheduler
        calls this after the offline profiling pass so ``stats`` reflects
        only served traffic — in particular ``decode_compiles`` counts
        programs built AFTER warm-up, which the slot arena holds at 0."""
        self.stats.update(
            real_rows=0, bucket_rows=0, real_slots=0, total_slots=0,
            dispatches=0, decode_compiles=0, prefill_compiles=0,
            chunk_steps=0,
        )

    def freeze(self) -> None:
        """Permanently disable dispatch and slot traffic (idempotent).

        Called when the slice owning this engine fails: its in-flight
        requests re-admit onto OTHER slices' arenas, so any further
        dispatch/alloc/free here is a failover bug — raise instead of
        silently mutating a dead arena. The resident buffers are left in
        place (the cluster's fault-injection tests assert they are never
        touched again); process teardown reclaims them.
        """
        self.frozen = True

    def _check_not_frozen(self, op: str) -> None:
        if self.frozen:
            raise RuntimeError(
                f"engine is frozen (its slice failed); {op} must target a "
                f"surviving slice's engine"
            )

    # ----- compiled step factories ----------------------------------------
    def _prefill_fn(self, mid: str, seq: int, batch: int):
        key = ("prefill", mid, seq, batch)
        if key not in self._compiled:
            self.stats["prefill_compiles"] += 1
            model = self.models[mid]

            def run(params, tokens):
                logits, _ = model.forward(params, tokens)
                return logits[:, -1].argmax(-1)

            self._compiled[key] = jax.jit(run)
        return self._compiled[key]

    def _decode_fn(self, mid: str, seq: int):
        """THE decode program for (mid, seq): every live batch <=
        max_slots executes this one compile — batch size is data. The
        program also advances the live rows' cursors on-device (clamped
        at the cache edge; a real system would evict), so the slot-mode
        hot loop never round-trips cursors through the host."""
        key = ("decode", mid, seq)
        if key not in self._compiled:
            self.stats["decode_compiles"] += 1
            model = self.models[mid]

            def run(params, cache, tok, cur, active):
                logits, new_cache = model.decode_step(
                    params, cache, tok, cur, active=active
                )
                new_cur = jnp.where(
                    active, jnp.minimum(cur + 1, seq - 1), cur
                )
                return logits, new_cache, new_cur

            donate = (1,) if self.donate_cache else ()
            self._compiled[key] = jax.jit(run, donate_argnums=donate)
        return self._compiled[key]

    def _decode_chunk_fn(self, mid: str, seq: int, k: int):
        """THE k-step chunked decode program for (mid, seq, k): a
        ``jax.lax.scan`` over the exact single-step body. Cursors and the
        active bitmap are already device-resident, so the whole chunk
        runs with no host round-trip — one dispatch amortizes the host
        overhead of k steps. ``masks[i]`` gates which rows carry a REAL
        token at step i (``active & masks[i]`` is the step's live set):
        idle leased rows are masked per step exactly like single-step
        ``step_rows``, so their cursors stay frozen across the chunk.

        Bit-identity with k sequential single-step dispatches is a
        CONTRACT (tests/test_decode_chunking.py): scan compiles the
        identical step subgraph per iteration — no cross-step fusion can
        change the math — so the chunked schedule is a pure latency
        optimization, never a numerics fork.
        """
        key = ("decode_chunk", mid, seq, k)
        if key not in self._compiled:
            self.stats["decode_compiles"] += 1
            model = self.models[mid]

            def run(params, cache, toks, cur, active, masks):
                def body(carry, xs):
                    cache, cur = carry
                    tok, mask = xs
                    act = active & mask
                    logits, new_cache = model.decode_step(
                        params, cache, tok, cur, active=act
                    )
                    new_cur = jnp.where(
                        act, jnp.minimum(cur + 1, seq - 1), cur
                    )
                    return (new_cache, new_cur), logits

                (new_cache, new_cur), logits = jax.lax.scan(
                    body, (cache, cur), (toks, masks)
                )
                return logits, new_cache, new_cur

            donate = (1,) if self.donate_cache else ()
            self._compiled[key] = jax.jit(run, donate_argnums=donate)
        return self._compiled[key]

    # ----- slot arena ------------------------------------------------------
    def arena(self, mid: str, seq: int) -> SlotArena:
        """The resident decode arena for (mid, seq), created on first use."""
        key = (mid, seq)
        if key not in self._arenas:
            self._arenas[key] = SlotArena(
                cache=self.models[mid].init_cache(self.max_slots, seq),
                max_slots=self.max_slots,
                cur=jnp.zeros((self.max_slots,), jnp.int32),
                active=jnp.zeros((self.max_slots,), bool),
                free=list(range(self.max_slots)),
            )
        return self._arenas[key]

    def alloc_slots(
        self, mid: str, seq: int, n: int, start_pos: int = 0
    ) -> Tuple[int, ...]:
        """Assign ``n`` arena rows to an admitted request.

        Recycled rows are wiped by ``cache_reset_rows`` — with donation
        (the tpu/gpu default) that is a true in-place write with no
        O(arena) copy; without donation (the cpu default) XLA produces a
        fresh arena-sized buffer, the copy cost the backend gate traded
        for lower per-dispatch overhead. Either way no per-bucket cache
        objects are created or destroyed — the churn that used to happen
        on every batch-bucket change. Raises when the arena is full;
        admission sized ``max_slots`` (and the flat WCET table charges
        inf beyond it) so a full arena means an admission bug, not a
        capacity surprise.
        """
        self._check_not_frozen("alloc_slots")
        arena = self.arena(mid, seq)
        if n < 1:
            raise ValueError(f"need >= 1 slot, got {n}")
        if n > len(arena.free):
            raise RuntimeError(
                f"arena {mid}/seq={seq} exhausted: want {n}, "
                f"free {len(arena.free)}/{arena.max_slots} — admission "
                f"must bound live batches by max_slots"
            )
        slots = tuple(sorted(arena.free)[:n])
        arena.free = [s for s in arena.free if s not in slots]
        rows = jnp.zeros((arena.max_slots,), bool).at[jnp.array(slots)].set(True)
        arena.cache = self._reset_fn(arena.cache, rows)
        arena.cur = jnp.where(rows, jnp.int32(start_pos), arena.cur)
        arena.active = arena.active | rows
        arena.allocs += n
        arena.resets += n
        return slots

    def free_slots(self, mid: str, seq: int, slots: Sequence[int]) -> None:
        """Return rows to the allocator (wiped lazily on next alloc)."""
        self._check_not_frozen("free_slots")
        arena = self.arena(mid, seq)
        ids = [int(s) for s in slots]
        if not ids:
            return  # freeing nothing is a no-op, not an indexing error
        bad = [s for s in ids if not 0 <= s < arena.max_slots]
        if bad:
            raise ValueError(f"slot ids out of range: {bad}")
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate slot ids in free: {sorted(ids)}")
        not_live = sorted(set(ids) - set(arena.live))
        if not_live:
            raise ValueError(f"double free / never-allocated slots {not_live}")
        arena.free.extend(ids)
        rows = jnp.zeros((arena.max_slots,), bool).at[jnp.array(ids)].set(True)
        arena.active = arena.active & ~rows

    def arena_nbytes(self, mid: str, seq: int) -> int:
        """Resident bytes of the (mid, seq) decode arena."""
        return cache_nbytes(self.arena(mid, seq).cache)

    # ----- double-buffered input staging ----------------------------------
    def staging_ring(self, kind: str, mid: str, seq: int, batch: int) -> StagingRing:
        """The host->device staging ring for one compiled program input
        (prefill: (bucket, seq) token rows; decode: (max_slots,) tokens).
        Created on first use, then a fixed scratch pool forever — the
        steady-state hot loop performs zero fresh host allocations
        (``host_allocs`` stays at the ring's construction depth; the
        ingest bench smoke asserts it)."""
        key = (kind, mid, seq, batch)
        ring = self._rings.get(key)
        if ring is None:
            shape = (batch, seq) if kind == "prefill" else (batch,)
            # Decode rings must hold a full chunk's per-step stages (one
            # slot per step, all behind the chunk's single consumer)
            # plus the fill target — ring depth is fixed at creation, so
            # it is sized here, before any decode dispatch.
            depth = self.staging_depth
            if kind == "decode":
                depth = max(depth, self.max_chunk_depth + 1)
            ring = StagingRing(shape, np.int32, depth=depth)
            self._rings[key] = ring
        return ring

    def _stage_prefill_tokens(
        self, ring: StagingRing, payload, n_rows: int
    ) -> jax.Array:
        """Stage one prefill's token rows. ``payload``: None (zero
        frame), a dense (n_rows, seq) array, or a per-frame list of
        Optional row arrays — the bridge's form, written straight into
        the ring scratch (no intermediate stack allocation on the hot
        loop). Rows longer than the running seq are CROPPED — the
        adaptation module's shape shrink applied to real bytes (the
        paper's resolution shrink at the token level) — shorter rows
        zero-pad.
        """
        if payload is None or isinstance(payload, np.ndarray):
            return ring.stage_rows(payload, n_rows)
        rows = list(payload)
        if len(rows) != n_rows:
            raise ValueError(
                f"prefill payload carries {len(rows)} rows for batch {n_rows}"
            )
        seq_run = ring.shape[1]

        arrs = []
        for r in rows:
            if r is None:
                arrs.append(None)
                continue
            arr = np.asarray(r).ravel()
            check_payload_dtype(arr, ring.dtype)
            arrs.append(arr)

        def fill(buf: np.ndarray) -> None:
            for i, arr in enumerate(arrs):
                if arr is None:
                    buf[i] = 0
                    continue
                n = min(arr.size, seq_run)
                buf[i, :n] = arr[:n]
                buf[i, n:] = 0
            buf[n_rows:] = 0

        return ring.stage(fill)

    def _stage_decode_tokens(
        self, ring: StagingRing, payload, prefix_rows: Optional[int]
    ) -> jax.Array:
        """Stage one decode step's token vector (all ``max_slots`` rows).

        ``prefix_rows`` set (prefix-mode dispatch): ``payload`` is None,
        a (prefix_rows,) token array, or a per-frame list of Optional
        scalars for the leading rows. Otherwise (slot mode): ``payload``
        is None, a full (max_slots,) slot-aligned array, or a
        {slot_id: token} dict — the bridge builds the dict from each
        frame's arena lease, so every stream's token lands in its own
        resident row.
        """
        if payload is None:
            return ring.stage_rows(None, 0)
        if prefix_rows is not None:
            if isinstance(payload, np.ndarray):
                return ring.stage_rows(payload, prefix_rows)
            toks = list(payload)
            if len(toks) != prefix_rows:
                raise ValueError(
                    f"decode payload carries {len(toks)} tokens for "
                    f"batch {prefix_rows}"
                )

            def fill_prefix(buf: np.ndarray) -> None:
                buf[:] = 0
                for i, t in enumerate(toks):
                    if t is not None:
                        buf[i] = int(np.asarray(t))

            return ring.stage(fill_prefix)
        if isinstance(payload, dict):
            m = ring.shape[0]
            bad = [s for s in payload if not 0 <= int(s) < m]
            if bad:
                raise ValueError(f"decode payload slot ids out of range: {bad}")

            def fill(buf: np.ndarray) -> None:
                buf[:] = 0
                for s, tok in payload.items():
                    buf[int(s)] = tok

            return ring.stage(fill)
        return ring.stage_rows(payload, ring.shape[0])

    def _prefix_inputs(
        self, mid: str, seq: int, k: int
    ) -> Tuple[jax.Array, jax.Array]:
        """(cursors, active) for a job occupying the first ``k`` arena
        rows: live rows sit at position seq-1, dead rows carry active=0
        so the attention path skips ALL their KV blocks. Cached per
        (mid, seq, k) — the hot loop re-sends resident device arrays."""
        if not self.masked_decode:
            k = self.max_slots  # blind padding: every row does full work
        key = (mid, seq, k)
        if key not in self._decode_inputs:
            m = self.max_slots
            cur = jnp.concatenate(
                [
                    jnp.full((k,), seq - 1, jnp.int32),
                    jnp.zeros((m - k,), jnp.int32),
                ]
            )
            active = (jnp.arange(m) < k)
            self._decode_inputs[key] = (cur, active)
        return self._decode_inputs[key]

    # ----- execution ---------------------------------------------------------
    def warmup(self, mid: str, shape_key: Tuple[int, ...], batch_sizes,
               kind: str = "prefill") -> None:
        for b in batch_sizes:
            self.execute(mid, shape_key, b, kind)

    def dispatch(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int,
        kind: str = "prefill", slots: Optional[Sequence[int]] = None,
        payload=None, step_rows: Optional[Sequence[int]] = None,
    ) -> StepHandle:
        """Launch one batched job WITHOUT waiting for the device.

        Returns immediately after JAX async dispatch; the returned
        handle's ``wait()`` blocks until the result is ready (the
        AsyncDevice calls it from the waiter thread).

        shape_key = (seq_len,) for LM categories. Decode jobs run on the
        slot arena: ``slots`` steps the allocator-assigned rows
        (continuous batching — the set must be ALL currently live rows:
        every step writes each live row's cache at its cursor, so
        stepping a strict subset would clobber the skipped rows; masked
        per-row cache writes are the extension point if partial stepping
        is ever needed); ``slots=None`` uses the first ``batch_size``
        rows (the profiler/benchmark workload). Either way the SAME
        compiled program executes — only the active bitmap and cursors
        change, and in slot mode both are device-resident; the staged
        token vector is the ONE per-step host->device transfer.

        ``payload`` carries the job's real ingested bytes through the
        staging ring: prefill takes a (batch_size, seq) int32 token
        array (rows beyond the true batch stage as zeros inside the
        bucket); decode takes a (batch_size,) array in prefix mode or a
        slot-aligned array / {slot: token} dict in slot mode. ``None``
        stages a zero frame — same ring, the profiler's input.

        ``step_rows`` (slot mode only): the subset of live rows that
        carry a REAL token this step. Rows outside it stay allocator-
        live but run with ``active=0``: their attention is masked and
        their cursor does NOT advance, so a leased stream with no frame
        in this window never consumes a phantom zero token — its
        unconditional cache write lands at the frozen cursor and is
        overwritten by the stream's next real token before anything
        attends to it. (Recurrent-state blocks — rwkv/rglru — update
        state unconditionally regardless of ``active``; idle-row
        fidelity for those is the same pre-existing caveat as prefix-
        mode dead rows.) ``None`` = every live row is active (the
        profiler / single-stream workload).
        """
        self._check_not_frozen("dispatch")
        seq = shape_key[0]
        self.stats["dispatches"] += 1
        if kind == "prefill":
            b = bucket(batch_size)
            self.stats["real_rows"] += batch_size
            self.stats["bucket_rows"] += b
            fn = self._prefill_fn(mid, seq, b)
            ring = self.staging_ring("prefill", mid, seq, b)
            tokens = self._stage_prefill_tokens(ring, payload, batch_size)
            out = fn(self.params[mid], tokens)
            handle = StepHandle(out, mid, kind, batch_size, b)
            # The handle's wait guards this scratch buffer's reuse: the
            # ring refills it only after this step finished reading it
            # (zero-copy uploads alias host memory — see StagingRing).
            ring.attach_consumer(handle.wait)
            return handle
        if batch_size > self.max_slots:
            raise ValueError(
                f"decode batch {batch_size} > max_slots {self.max_slots}: "
                f"size the arena via bucketing.arena_slots at engine build"
            )
        m = self.max_slots
        arena = self.arena(mid, seq)
        fn = self._decode_fn(mid, seq)
        ring = self.staging_ring("decode", mid, seq, m)
        tok = self._stage_decode_tokens(
            ring, payload, prefix_rows=batch_size if slots is None else None
        )
        if slots is None:
            if len(arena.free) != arena.max_slots:
                raise ValueError(
                    f"arena {mid}/seq={seq} has allocator-live rows "
                    f"{sorted(arena.live)}; prefix-mode dispatch would "
                    f"overwrite their KV at synthetic cursors — pass "
                    f"slots= (all live rows) instead"
                )
            cur, active = self._prefix_inputs(mid, seq, batch_size)
        else:
            ids = [int(s) for s in slots]
            if len(ids) != batch_size or len(set(ids)) != len(ids):
                raise ValueError(
                    f"need {batch_size} distinct slot ids, got {ids}"
                )
            if set(ids) != set(arena.live):
                raise ValueError(
                    f"slot dispatch must step ALL live rows "
                    f"{sorted(arena.live)}, got {sorted(ids)}"
                )
            cur, active = arena.cur, arena.active
            if step_rows is not None:
                step = [int(s) for s in step_rows]
                extra = sorted(set(step) - set(ids))
                if extra:
                    raise ValueError(
                        f"step_rows {extra} are not live rows {sorted(ids)}"
                    )
                rows = (
                    jnp.zeros((m,), bool).at[jnp.array(step)].set(True)
                    if step else jnp.zeros((m,), bool)
                )
                active = arena.active & rows
        k = batch_size if self.masked_decode else m
        self.stats["real_rows"] += batch_size
        self.stats["bucket_rows"] += m
        self.stats["real_slots"] += batch_size * seq
        self.stats["total_slots"] += k * seq
        logits, new_cache, new_cur = fn(
            self.params[mid], arena.cache, tok, cur, active
        )
        # The arena pytree is REPLACED every step (with donation the new
        # leaves alias the old buffers — in-place; without, XLA copied).
        arena.cache = new_cache
        if slots is not None:
            arena.cur = new_cur  # advanced on-device, no host round-trip
        handle = StepHandle(logits, mid, kind, batch_size, m)
        ring.attach_consumer(handle.wait)
        return handle

    def decode_chunk(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int, k: int,
        slots: Optional[Sequence[int]] = None,
        payloads: Optional[Sequence] = None,
        step_rows: Optional[Sequence[Optional[Sequence[int]]]] = None,
    ) -> StepHandle:
        """Launch ONE k-step decode chunk without waiting for the device.

        The chunked twin of a decode ``dispatch``: the same slot-arena
        semantics (``slots`` must be ALL live rows; prefix mode when
        ``slots=None``), executed k steps deep by the scanned program
        from ``_decode_chunk_fn`` — bit-identical to k sequential
        single-step dispatches, with the k-1 intermediate host returns
        removed.

        ``payloads``: length-k sequence of per-step decode payloads
        (each in any form single-step ``dispatch`` accepts: None, a
        slot-aligned array, or a {slot: token} dict); ``None`` = all
        steps zero-staged (the profiler's input). Each step's tokens go
        through the SAME decode staging ring — one ring slot per step,
        all guarded by this chunk's completion — so ``k`` must not
        exceed ``ring.capacity`` (the engine sizes decode rings from
        ``chunk_depth`` at construction; a deeper ad-hoc chunk is
        rejected loudly rather than allowed to deadlock on its own
        not-yet-dispatched consumer).

        ``step_rows``: length-k sequence of per-step frame-bearing row
        subsets (``None`` entry = every live row steps). Idle leased
        rows at step i run masked: attention skipped, cursor frozen —
        identical to single-step ``step_rows``, held per step across
        the chunk.
        """
        self._check_not_frozen("decode_chunk")
        seq = shape_key[0]
        m = self.max_slots
        if k < 1:
            raise ValueError(f"chunk depth must be >= 1, got {k}")
        if batch_size > m:
            raise ValueError(
                f"decode batch {batch_size} > max_slots {m}: size the "
                f"arena via bucketing.arena_slots at engine build"
            )
        if payloads is not None and len(payloads) != k:
            raise ValueError(
                f"chunk of depth {k} needs {k} per-step payloads, "
                f"got {len(payloads)}"
            )
        if step_rows is not None and len(step_rows) != k:
            raise ValueError(
                f"chunk of depth {k} needs {k} per-step row sets, "
                f"got {len(step_rows)}"
            )
        arena = self.arena(mid, seq)
        ring = self.staging_ring("decode", mid, seq, m)
        if k > ring.capacity:
            raise ValueError(
                f"chunk depth {k} exceeds the decode ring's in-flight "
                f"capacity {ring.capacity}: build the engine with "
                f"chunk_depth >= {k}"
            )
        if slots is None:
            if len(arena.free) != arena.max_slots:
                raise ValueError(
                    f"arena {mid}/seq={seq} has allocator-live rows "
                    f"{sorted(arena.live)}; prefix-mode decode_chunk "
                    f"would overwrite their KV at synthetic cursors — "
                    f"pass slots= (all live rows) instead"
                )
            cur, active = self._prefix_inputs(mid, seq, batch_size)
        else:
            ids = [int(s) for s in slots]
            if len(ids) != batch_size or len(set(ids)) != len(ids):
                raise ValueError(
                    f"need {batch_size} distinct slot ids, got {ids}"
                )
            if set(ids) != set(arena.live):
                raise ValueError(
                    f"slot dispatch must step ALL live rows "
                    f"{sorted(arena.live)}, got {sorted(ids)}"
                )
            cur, active = arena.cur, arena.active
            if step_rows is not None:
                for i, rows_i in enumerate(step_rows):
                    if rows_i is None:
                        continue
                    extra = sorted(set(int(s) for s in rows_i) - set(ids))
                    if extra:
                        raise ValueError(
                            f"step {i} rows {extra} are not live rows "
                            f"{sorted(ids)}"
                        )
        # Per-step token staging: one ring slot per step, every slot
        # guarded by THIS chunk's completion (the guard closure resolves
        # the handle after dispatch; a later chunk's refill of any of
        # these scratches blocks until this chunk finished reading).
        pending: Dict[str, Optional[StepHandle]] = {"handle": None}

        def _chunk_guard() -> None:
            h = pending["handle"]
            if h is not None:
                h.wait()

        staged = []
        prefix = batch_size if slots is None else None
        for i in range(k):
            payload_i = payloads[i] if payloads is not None else None
            staged.append(
                self._stage_decode_tokens(ring, payload_i, prefix_rows=prefix)
            )
            ring.attach_consumer(_chunk_guard)
        toks = jnp.stack(staged)
        masks = self._step_masks(k, step_rows)
        fn = self._decode_chunk_fn(mid, seq, k)
        kk = batch_size if self.masked_decode else m
        self.stats["dispatches"] += 1
        self.stats["chunk_steps"] += k
        self.stats["real_rows"] += batch_size * k
        self.stats["bucket_rows"] += m * k
        self.stats["real_slots"] += batch_size * seq * k
        self.stats["total_slots"] += kk * seq * k
        logits, new_cache, new_cur = fn(
            self.params[mid], arena.cache, toks, cur, active, masks
        )
        arena.cache = new_cache
        if slots is not None:
            arena.cur = new_cur
        handle = StepHandle(logits, mid, "decode", batch_size, m, steps=k)
        pending["handle"] = handle
        return handle

    def _step_masks(
        self, k: int, step_rows: Optional[Sequence[Optional[Sequence[int]]]]
    ) -> jax.Array:
        """The (k, max_slots) per-step frame mask a chunk consumes.

        All-active masks (the profiler / single-stream case) are cached
        resident per depth; real per-step subsets build one small numpy
        buffer and upload it — the chunk's only host->device transfer
        besides the staged tokens."""
        m = self.max_slots
        if step_rows is None or all(r is None for r in step_rows):
            if k not in self._full_masks:
                self._full_masks[k] = jnp.ones((k, m), bool)
            return self._full_masks[k]
        buf = np.zeros((k, m), bool)
        for i, rows_i in enumerate(step_rows):
            if rows_i is None:
                buf[i, :] = True
            else:
                for s in rows_i:
                    buf[i, int(s)] = True
        return jnp.asarray(buf)

    def execute(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int,
        kind: str = "prefill", slots: Optional[Sequence[int]] = None,
        payload=None,
    ) -> float:
        """Run one batched job synchronously; returns wall seconds. The
        offline profiler path (and the benchmarks' latency probes)."""
        t0 = time.perf_counter()
        self.dispatch(
            mid, shape_key, batch_size, kind, slots=slots, payload=payload
        ).wait()
        return time.perf_counter() - t0

    def execute_chunk(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int, k: int,
        slots: Optional[Sequence[int]] = None,
        payloads: Optional[Sequence] = None,
    ) -> float:
        """Run one k-step decode chunk synchronously; returns wall
        seconds. The offline profiler's per-depth measurement path (and
        the benchmarks' chunk latency probes)."""
        t0 = time.perf_counter()
        self.decode_chunk(
            mid, shape_key, batch_size, k, slots=slots, payloads=payloads
        ).wait()
        return time.perf_counter() - t0

    # ----- accounting -----------------------------------------------------
    @property
    def staging_bytes(self) -> int:
        """Lifetime host->device payload bytes staged across all rings."""
        return sum(r.bytes_staged for r in self._rings.values())

    @property
    def staging_fills(self) -> int:
        return sum(r.fills for r in self._rings.values())

    @property
    def staging_host_allocs(self) -> int:
        """Host scratch buffers ever allocated; equals
        ``staging_depth * len(rings)`` forever — the zero-per-step-
        allocation bar the ingest bench asserts."""
        return sum(r.host_allocs for r in self._rings.values())

    def job_bytes(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int,
        kind: str = "prefill", steps: int = 1,
    ) -> float:
        """Bytes a running job pins on-device (staging + the arena it
        executes against).

        The arena is model-resident (it neither grows nor moves with the
        batch), but the device runs one job at a time, so charging it to
        the in-flight decode job keeps ``resident_bytes``/``peak_bytes``
        reflecting the KV memory decode actually holds — same contract
        the per-bucket caches had.
        """
        seq = shape_key[0]
        if kind == "prefill":
            return float(4 * bucket(batch_size) * seq)  # int32 tokens
        # steps > 1: a chunk stages one token vector per step (plus the
        # (steps, max_slots) bool step-mask plane) on top of the shared
        # cursors/active pair; steps == 1 is the classic tok+cur+active.
        staging = (2 + steps) * 4 * self.max_slots
        if steps > 1:
            staging += steps * self.max_slots
        return float(staging + self.arena_nbytes(mid, seq))

    @property
    def padding_waste(self) -> float:
        """Measured fraction of attended decode KV slots spent on dead
        rows (0.0 under the masked arena: dead rows attend to nothing)."""
        if self.stats["total_slots"] == 0:
            return 0.0
        return max(0.0, 1.0 - self.stats["real_slots"] / self.stats["total_slots"])

    def telemetry(self) -> Dict[str, object]:
        """JSON-able execution-substrate snapshot: per-arena occupancy
        and allocator churn, staging-ring reuse, compile/dispatch
        counters. Registered as a cluster ``telemetry_probes`` entry by
        the live factory so ``ClusterScheduler.telemetry_snapshot`` folds
        engine state in without core importing serving."""
        arenas = {}
        for (mid, seq), arena in self._arenas.items():
            arenas[f"{mid}/seq{seq}"] = {
                "max_slots": arena.max_slots,
                "free": len(arena.free),
                "occupied": arena.max_slots - len(arena.free),
                "allocs": arena.allocs,
                "resets": arena.resets,
                "nbytes": self.arena_nbytes(mid, seq),
            }
        return {
            "arenas": arenas,
            "staging": {
                "rings": len(self._rings),
                "bytes": self.staging_bytes,
                "fills": self.staging_fills,
                "host_allocs": self.staging_host_allocs,
            },
            "stats": dict(self.stats),
            "padding_waste": self.padding_waste,
            "frozen": self.frozen,
        }
