"""Inference engine: compiled batched steps for DeepRT categories.

A DeepRT *category* is (model_id, shape bucket). The engine pre-compiles
one XLA program per (model, kind, seq bucket, batch bucket) — batch
sizes are padded up to the next power of two via the SHARED
``repro.core.bucketing.bucket`` (the same rounding the profiler grid and
the admission WCET lookup use), so the compile count stays logarithmic
while the table stays consistent with what actually runs.

Hot-path design (the zero-stall serving pipeline):

- ``dispatch`` launches a step WITHOUT blocking: JAX async dispatch
  returns futures, the host thread goes straight back to scheduling, and
  the ``AsyncDevice`` waiter observes completion via ``StepHandle.wait``.
  ``execute`` (= dispatch + wait) remains the synchronous path for the
  offline profiler and the before/after benchmark A/B.
- KV caches are DONATED (``jax.jit(..., donate_argnums=...)``): each
  decode step updates the cache in place instead of allocating a full
  copy — per-step allocation cost drops from O(cache) to O(batch).
- Input staging arrays are preallocated per (kind, model, seq, bucket):
  no per-call ``jnp.zeros`` allocation or host->device transfer on the
  hot path (see ``_stage`` for the double-buffering plan once real
  token ingestion writes into them).
- Decode is padding-free in effect: a true batch of k runs in a
  ``bucket(k)``-slot buffer, but pad rows carry cursor 0 so the
  position/validity masking (the same bitmap path the decode Pallas
  kernel uses) reduces their attended KV slots to one — pad rows cost
  ~nothing instead of a full-seq attention row. ``stats`` exposes the
  measured real-vs-total slot accounting.

Two step kinds per the shape pool:
- ``prefill``: full forward over (b, seq) tokens -> last-token logits
- ``decode`` : one token against a seq-length KV cache
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.bucketing import bucket
from repro.models import model_for
from repro.models.kvcache import cache_nbytes


@dataclass
class StepHandle:
    """One in-flight dispatched step (outputs may still be computing)."""

    outputs: Any  # jax array(s): prefill -> next tokens; decode -> logits
    mid: str
    kind: str
    true_batch: int
    bucket_batch: int

    def wait(self) -> Any:
        """Block until the device finishes; returns the ready outputs."""
        jax.block_until_ready(self.outputs)
        return self.outputs


class InferenceEngine:
    def __init__(
        self,
        configs: Dict[str, ModelConfig],
        seed: int = 0,
        donate_cache: bool = True,
        masked_decode: bool = True,
    ):
        """``donate_cache=False`` and ``masked_decode=False`` recreate the
        old copying / blind-padding behavior — kept ONLY so the hot-path
        benchmark and the equivalence tests can A/B against them."""
        self.configs = dict(configs)
        self.models = {mid: model_for(cfg) for mid, cfg in configs.items()}
        self.donate_cache = donate_cache
        self.masked_decode = masked_decode
        key = jax.random.PRNGKey(seed)
        self.params = {}
        for i, (mid, model) in enumerate(self.models.items()):
            self.params[mid] = model.init(jax.random.fold_in(key, i))
        self._compiled: Dict[Tuple, Any] = {}
        self._caches: Dict[Tuple, Any] = {}
        self._staging: Dict[Tuple, Dict[str, jax.Array]] = {}
        self._cursors: Dict[Tuple, jax.Array] = {}
        # Measured padding accounting (decode): attended KV slots.
        self.stats: Dict[str, int] = {}
        self.reset_stats()

    def reset_stats(self) -> None:
        """Zero the padding/dispatch counters. build_live_scheduler calls
        this after the offline profiling pass so ``stats`` reflects only
        served traffic, not warmup/profiling dispatches."""
        self.stats.update(
            real_rows=0, bucket_rows=0, real_slots=0, total_slots=0,
            dispatches=0,
        )

    # ----- compiled step factories ----------------------------------------
    def _prefill_fn(self, mid: str, seq: int, batch: int):
        key = ("prefill", mid, seq, batch)
        if key not in self._compiled:
            model = self.models[mid]

            def run(params, tokens):
                logits, _ = model.forward(params, tokens)
                return logits[:, -1].argmax(-1)

            self._compiled[key] = jax.jit(run)
        return self._compiled[key]

    def _decode_fn(self, mid: str, seq: int, batch: int):
        key = ("decode", mid, seq, batch, self.donate_cache)
        if key not in self._compiled:
            model = self.models[mid]

            def run(params, cache, tok, cur):
                return model.decode_step(params, cache, tok, cur)

            donate = (1,) if self.donate_cache else ()
            self._compiled[key] = jax.jit(run, donate_argnums=donate)
        return self._compiled[key]

    def _cache_for(self, mid: str, seq: int, batch: int):
        key = (mid, seq, batch)
        if key not in self._caches:
            self._caches[key] = self.models[mid].init_cache(batch, seq)
        return self._caches[key]

    # ----- preallocated input staging -------------------------------------
    def _stage(self, kind: str, mid: str, seq: int, batch: int) -> Dict[str, jax.Array]:
        """Preallocated input arrays per (kind, model, seq, bucket): no
        fresh ``jnp.zeros`` allocation or host->device transfer per call.
        Inputs are synthetic (zero tokens) for now, so one buffer per key
        suffices; once real token ingestion lands, writes must
        double-buffer (fill buffer B while the in-flight job reads A) —
        reintroduce the flip at that point, not before."""
        key = (kind, mid, seq, batch)
        buf = self._staging.get(key)
        if buf is None:
            if kind == "prefill":
                buf = {"tokens": jnp.zeros((batch, seq), jnp.int32)}
            else:
                buf = {"tok": jnp.zeros((batch,), jnp.int32)}
            self._staging[key] = buf
        return buf

    def _cursor_for(self, seq: int, batch: int, true_batch: int) -> jax.Array:
        """Per-row cursors: real rows sit at position seq-1; pad rows (the
        validity-bitmap path) sit at 0, so masking shrinks their attended
        KV range to a single slot instead of a full seq-length row."""
        if not self.masked_decode:
            true_batch = batch  # blind padding: every row does full work
        key = (seq, batch, true_batch)
        if key not in self._cursors:
            cur = jnp.concatenate(
                [
                    jnp.full((true_batch,), seq - 1, jnp.int32),
                    jnp.zeros((batch - true_batch,), jnp.int32),
                ]
            )
            self._cursors[key] = cur
        return self._cursors[key]

    # ----- execution ---------------------------------------------------------
    def warmup(self, mid: str, shape_key: Tuple[int, ...], batch_sizes,
               kind: str = "prefill") -> None:
        for b in batch_sizes:
            self.execute(mid, shape_key, b, kind)

    def dispatch(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int,
        kind: str = "prefill",
    ) -> StepHandle:
        """Launch one batched job WITHOUT waiting for the device.

        Returns immediately after JAX async dispatch; the returned
        handle's ``wait()`` blocks until the result is ready (the
        AsyncDevice calls it from the waiter thread). First call per
        (kind, model, seq, bucket) compiles — warm up via the profiler.
        shape_key = (seq_len,) for LM categories.
        """
        seq = shape_key[0]
        b = bucket(batch_size)
        self.stats["dispatches"] += 1
        self.stats["real_rows"] += batch_size
        self.stats["bucket_rows"] += b
        if kind == "prefill":
            fn = self._prefill_fn(mid, seq, b)
            stage = self._stage("prefill", mid, seq, b)
            out = fn(self.params[mid], stage["tokens"])
            return StepHandle(out, mid, kind, batch_size, b)
        fn = self._decode_fn(mid, seq, b)
        cache = self._cache_for(mid, seq, b)
        stage = self._stage("decode", mid, seq, b)
        cur = self._cursor_for(seq, b, batch_size)
        k = batch_size if self.masked_decode else b
        self.stats["real_slots"] += batch_size * seq
        self.stats["total_slots"] += k * seq + (b - k)
        logits, new_cache = fn(self.params[mid], cache, stage["tok"], cur)
        # Replace (never reuse) the stored cache: with donation the old
        # buffers were consumed by the step and updated in place.
        self._caches[(mid, seq, b)] = new_cache
        return StepHandle(logits, mid, kind, batch_size, b)

    def execute(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int,
        kind: str = "prefill",
    ) -> float:
        """Run one batched job synchronously; returns wall seconds. The
        offline profiler path (and the benchmark's blocking A/B arm)."""
        t0 = time.perf_counter()
        self.dispatch(mid, shape_key, batch_size, kind).wait()
        return time.perf_counter() - t0

    # ----- accounting -----------------------------------------------------
    def job_bytes(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int,
        kind: str = "prefill",
    ) -> float:
        """Resident bytes one job pins on-device (staging + KV cache)."""
        seq = shape_key[0]
        b = bucket(batch_size)
        n = 4 * b * (seq if kind == "prefill" else 1)  # int32 staging
        if kind == "decode":
            n += cache_nbytes(self._cache_for(mid, seq, b))
        return float(n)

    @property
    def padding_waste(self) -> float:
        """Measured fraction of attended decode KV slots spent on pad
        rows (0.0 when every batch exactly fills its bucket)."""
        if self.stats["total_slots"] == 0:
            return 0.0
        return 1.0 - self.stats["real_slots"] / self.stats["total_slots"]
