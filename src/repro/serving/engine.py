"""Inference engine: compiled batched steps for DeepRT categories.

A DeepRT *category* is (model_id, shape bucket). The engine pre-compiles
one XLA program per (model, kind, seq bucket, batch bucket) — batch
sizes are padded up to the next power of two so the compile count stays
logarithmic while the profiler table (which is keyed on true batch size,
rounded up identically) stays consistent with what actually runs.

Two step kinds per the shape pool:
- ``prefill``: full forward over (b, seq) tokens -> last-token logits
- ``decode`` : one token against a seq-length KV cache

``execute`` runs a job instance synchronously (the device is sequential —
exactly DeepRT's execution model) and returns measured wall seconds, so
the EDF worker's exec_time_fn plugs straight in (batcher_bridge.py).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model_for


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class InferenceEngine:
    def __init__(self, configs: Dict[str, ModelConfig], seed: int = 0):
        self.configs = dict(configs)
        self.models = {mid: model_for(cfg) for mid, cfg in configs.items()}
        key = jax.random.PRNGKey(seed)
        self.params = {}
        for i, (mid, model) in enumerate(self.models.items()):
            self.params[mid] = model.init(jax.random.fold_in(key, i))
        self._compiled: Dict[Tuple, Any] = {}
        self._caches: Dict[Tuple, Any] = {}

    # ----- compiled step factories ----------------------------------------
    def _prefill_fn(self, mid: str, seq: int, batch: int):
        key = ("prefill", mid, seq, batch)
        if key not in self._compiled:
            model = self.models[mid]

            def run(params, tokens):
                logits, _ = model.forward(params, tokens)
                return logits[:, -1].argmax(-1)

            self._compiled[key] = jax.jit(run)
        return self._compiled[key]

    def _decode_fn(self, mid: str, seq: int, batch: int):
        key = ("decode", mid, seq, batch)
        if key not in self._compiled:
            model = self.models[mid]
            self._compiled[key] = jax.jit(
                lambda params, cache, tok, cur: model.decode_step(
                    params, cache, tok, cur
                )
            )
        return self._compiled[key]

    def _cache_for(self, mid: str, seq: int, batch: int):
        key = (mid, seq, batch)
        if key not in self._caches:
            self._caches[key] = self.models[mid].init_cache(batch, seq)
        return self._caches[key]

    # ----- execution ---------------------------------------------------------
    def warmup(self, mid: str, shape_key: Tuple[int, ...], batch_sizes,
               kind: str = "prefill") -> None:
        for b in batch_sizes:
            self.execute(mid, shape_key, b, kind)

    def execute(
        self, mid: str, shape_key: Tuple[int, ...], batch_size: int,
        kind: str = "prefill",
    ) -> float:
        """Run one batched job synchronously; returns wall seconds.
        shape_key = (seq_len,) for LM categories."""
        seq = shape_key[0]
        b = _bucket(batch_size)
        cfg = self.configs[mid]
        tokens = jnp.zeros((b, seq), jnp.int32)
        if kind == "prefill":
            fn = self._prefill_fn(mid, seq, b)
            t0 = time.perf_counter()
            fn(self.params[mid], tokens).block_until_ready()
            return time.perf_counter() - t0
        fn = self._decode_fn(mid, seq, b)
        cache = self._cache_for(mid, seq, b)
        tok = jnp.zeros((b,), jnp.int32)
        cur = jnp.full((b,), seq - 1, jnp.int32)
        t0 = time.perf_counter()
        logits, new_cache = fn(self.params[mid], cache, tok, cur)
        logits.block_until_ready()
        self._caches[(mid, seq, b)] = new_cache
        return time.perf_counter() - t0
