"""Bridge: DeepRT scheduler <-> the compiled inference engine.

Live serving uses the identical scheduler objects as simulation, with
two swaps:
- the event loop is a WallClock;
- the device is an ``AsyncDevice``: the EDF worker's submit launches the
  job via non-blocking JAX dispatch and the loop keeps scheduling
  (DisBatcher window joints, admission, adaptation) while XLA executes —
  exactly the overlap the ``SequentialDevice`` simulation models. The
  completion lands back on the loop thread from a lightweight waiter
  keyed off ``block_until_ready``.

``dispatch="sync"`` recreates the old blocking path (the EDF worker's
``exec_time_fn`` runs the job synchronously and stalls the loop for its
duration). It exists ONLY as the A/B baseline for
``benchmarks/serving_hotpath.py`` and will be removed once the async
path has a few PRs of mileage — do not build on it.

``build_live_scheduler`` also runs the offline Performance Profiler
(paper §4.1) over the engine to produce the WCET table the Admission
Control Module consumes.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import (
    DeepRT,
    ExecutionModel,
    MeasuredProfiler,
    ProfileTable,
    SequentialDevice,
    WallClock,
)
from repro.serving.async_device import AsyncDevice
from repro.serving.engine import InferenceEngine


class _BlockingDevice(SequentialDevice):
    """Sync-arm device: by the time the EDF worker calls ``submit`` the
    job has ALREADY executed (exec_time_fn blocked the loop for its
    duration), so the completion fires immediately instead of being
    re-scheduled ``exec_time`` in the future — which would double-count
    every job's duration in latencies and busy_until."""

    def submit(self, job, exec_time, on_complete, job_bytes=0.0):
        super().submit(job, 0.0, on_complete, job_bytes)
        self.busy_time += exec_time


def profile_engine(
    engine: InferenceEngine,
    categories: Iterable[Tuple[str, Tuple[int, ...], str]],
    batch_sizes=(1, 2, 4, 8),
    runs: int = 5,
    quantile: float = 0.99,
) -> ProfileTable:
    """Offline profiler pass (paper §4.1): p99 over repeated runs per
    (model, shape, batch bucket). Batch sizes are deduped to buckets —
    the engine executes the identical program for every size in one."""
    table = ProfileTable()
    profiler = MeasuredProfiler(warmup=2, runs=runs, quantile=quantile)
    for mid, shape_key, kind in categories:
        profiler.profile(
            table,
            mid,
            shape_key,
            list(batch_sizes),
            lambda b, _m=mid, _s=shape_key, _k=kind: engine.execute(_m, _s, b, _k),
        )
    return table


def build_live_scheduler(
    configs: Dict[str, ModelConfig],
    categories: Iterable[Tuple[str, Tuple[int, ...], str]],
    batch_sizes=(1, 2, 4, 8),
    utilization_bound: float = 1.0,
    dispatch: str = "async",
    engine: Optional[InferenceEngine] = None,
) -> Tuple[DeepRT, InferenceEngine, ProfileTable]:
    """Build the live wall-clock DeepRT over a compiled engine.

    ``dispatch="async"`` (default): zero-stall pipeline — profiled WCET
    estimates drive ``busy_until``, the AsyncDevice measures reality.
    ``dispatch="sync"``: legacy blocking execution, A/B baseline only.
    """
    if engine is None:
        engine = InferenceEngine(configs)
    cats = list(categories)
    kinds = {(mid, shape): kind for mid, shape, kind in cats}
    table = profile_engine(engine, cats, batch_sizes)
    engine.reset_stats()  # stats cover served traffic, not profiling
    loop = WallClock()

    def kind_of(job) -> str:
        return kinds.get((job.category.model_id, job.shape_key), "prefill")

    def job_bytes(job) -> float:
        return engine.job_bytes(
            job.category.model_id, job.shape_key, job.batch_size, kind_of(job)
        )

    if dispatch == "async":
        device = AsyncDevice(
            loop,
            dispatch_fn=lambda job: engine.dispatch(
                job.category.model_id, job.shape_key, job.batch_size, kind_of(job)
            ),
        )
        # exec_time under async dispatch is the busy-until ESTIMATE (the
        # profiled WCET); the device reports the real completion instant.
        sched = DeepRT(
            table,
            loop=loop,
            execution=ExecutionModel(actual_fn=lambda job, wcet: wcet),
            utilization_bound=utilization_bound,
            device=device,
        )
    elif dispatch == "sync":
        def run_job(job, wcet):
            return engine.execute(
                job.category.model_id, job.shape_key, job.batch_size, kind_of(job)
            )

        sched = DeepRT(
            table,
            loop=loop,
            execution=ExecutionModel(actual_fn=run_job),
            utilization_bound=utilization_bound,
            device=_BlockingDevice(loop),
        )
    else:
        raise ValueError(f"dispatch must be 'async' or 'sync', got {dispatch!r}")
    sched.worker.job_bytes_fn = job_bytes
    return sched, engine, table
