"""Bridge: DeepRT scheduler <-> the compiled inference engine.

Live serving uses the identical scheduler objects as simulation, with
two swaps:
- the event loop is a WallClock;
- the EDF worker's ``exec_time_fn`` EXECUTES the job synchronously on
  the engine and returns the measured wall time (the device is
  sequential, so blocking the loop for the duration of one job is
  precisely DeepRT's non-preemptive execution model — paper §4.3).

``build_live_scheduler`` also runs the offline Performance Profiler
(paper §4.1) over the engine to produce the WCET table the Admission
Control Module consumes.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import (
    DeepRT,
    ExecutionModel,
    MeasuredProfiler,
    ProfileTable,
    WallClock,
)
from repro.serving.engine import InferenceEngine


def profile_engine(
    engine: InferenceEngine,
    categories: Iterable[Tuple[str, Tuple[int, ...], str]],
    batch_sizes=(1, 2, 4, 8),
    runs: int = 5,
    quantile: float = 0.99,
) -> ProfileTable:
    """Offline profiler pass (paper §4.1): p99 over repeated runs per
    (model, shape, batch)."""
    table = ProfileTable()
    profiler = MeasuredProfiler(warmup=2, runs=runs, quantile=quantile)
    for mid, shape_key, kind in categories:
        profiler.profile(
            table,
            mid,
            shape_key,
            list(batch_sizes),
            lambda b, _m=mid, _s=shape_key, _k=kind: engine.execute(_m, _s, b, _k),
        )
    return table


def build_live_scheduler(
    configs: Dict[str, ModelConfig],
    categories: Iterable[Tuple[str, Tuple[int, ...], str]],
    batch_sizes=(1, 2, 4, 8),
    utilization_bound: float = 1.0,
) -> Tuple[DeepRT, InferenceEngine, ProfileTable]:
    engine = InferenceEngine(configs)
    cats = list(categories)
    kinds = {(mid, shape): kind for mid, shape, kind in cats}
    table = profile_engine(engine, cats, batch_sizes)

    def run_job(job, wcet):
        kind = kinds.get((job.category.model_id, job.shape_key), "prefill")
        return engine.execute(
            job.category.model_id, job.shape_key, job.batch_size, kind
        )

    sched = DeepRT(
        table,
        loop=WallClock(),
        execution=ExecutionModel(actual_fn=run_job),
        utilization_bound=utilization_bound,
    )
    return sched, engine, table
