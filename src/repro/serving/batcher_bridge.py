"""Bridge: DeepRT scheduler <-> the compiled inference engine.

Live serving uses the identical scheduler objects as simulation, with
two swaps:
- the event loop is a WallClock;
- the device is an ``AsyncDevice``: the EDF worker's submit launches the
  job via non-blocking JAX dispatch and the loop keeps scheduling
  (DisBatcher window joints, admission, adaptation) while XLA executes —
  exactly the overlap the ``SequentialDevice`` simulation models. The
  completion lands back on the loop thread from a lightweight waiter
  keyed off ``block_until_ready``.

(The legacy blocking dispatch mode — ``dispatch="sync"``, where the EDF
worker's exec_time_fn stalled the loop for each job's duration — is
deleted; ``benchmarks/serving_hotpath.py`` replays its recorded numbers
for the before/after instead of re-running dead code.)

``build_live_scheduler`` also runs the offline Performance Profiler
(paper §4.1) over the engine to produce the WCET table the Admission
Control Module consumes. Profiling mirrors the engine's two regimes:
prefill categories get a power-of-two bucket curve; decode categories
get ONE flat entry measured with every arena row live (the worst case of
the single program that serves all batch sizes) via
``ProfileTable.record_flat``. The engine's arena is sized with the
shared ``bucketing.arena_slots`` so the profiled program IS the served
program.

``build_live_cluster`` generalizes this to a pod: N slices on ONE
WallClock, each with its own engine (per-slice arena sized by
``bucketing.slice_arena_slots`` under that slice's Phase-1 utilization
bound), its own AsyncDevice, and its own profiled table, registered
into a ``ClusterScheduler`` that does placement, spill, per-request
arena-row leases, and failover re-admission (``core/cluster.py``).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (
    DeepRT,
    ExecutionModel,
    MeasuredProfiler,
    ProfileTable,
    WallClock,
)
from repro.core.bucketing import (
    arena_slots,
    bucket,
    chunk_depths,
    slice_arena_slots,
)
from repro.core.cluster import ClusterScheduler, LiveSlice, SliceSpec
from repro.core.faults import (
    CompletionWatchdog,
    FaultPlan,
    FaultyDevice,
    WatchdogConfig,
)
from repro.core.request import ChunkJob
from repro.core.scheduler import NONRT_BATCH_CAP
from repro.serving.async_device import AsyncDevice
from repro.serving.engine import InferenceEngine


def profile_engine(
    engine: InferenceEngine,
    categories: Iterable[Tuple[str, Tuple[int, ...], str]],
    batch_sizes=(1, 2, 4, 8),
    runs: int = 5,
    quantile: float = 0.99,
    chunk_depth: int = 1,
) -> ProfileTable:
    """Offline profiler pass (paper §4.1): p99 over repeated runs.

    Prefill: per batch-bucket curve (deduped to buckets — the engine
    executes the identical program for every size in one). Decode: the
    slot arena runs one program whose cost is flat in batch size, so a
    per-batch curve would time the same program repeatedly; measure the
    worst case (all ``max_slots`` rows live) once and record it flat.

    ``chunk_depth`` > 1 additionally profiles each decode category's
    k-step chunked programs over the power-of-two depth ladder
    (``bucketing.chunk_depths``), recording the per-depth flat WCET
    family (``record_flat(..., k=k)``) that the EDF worker's slack rule
    consumes. Measuring here is also the WARM-UP: every chunk program
    the worker can later choose is compiled during profiling, so serving
    stays at zero decode recompiles. Raw per-depth measurements are
    clamped monotone non-decreasing in k before recording (timer jitter
    on near-equal depths must not read as a family inversion).
    """
    cats = list(categories)
    # ProfileTable keys (and the bridge's kind_of map) are (model, shape)
    # — one kind per key by design. Profiling a shape as BOTH kinds would
    # make the flat decode entry silently shadow the prefill curve; fail
    # loudly instead.
    seen_kinds: Dict[Tuple[str, Tuple[int, ...]], str] = {}
    for mid, shape_key, kind in cats:
        prev = seen_kinds.setdefault((mid, tuple(shape_key)), kind)
        if prev != kind:
            raise ValueError(
                f"category ({mid}, {shape_key}) profiled as both {prev!r} "
                f"and {kind!r}; WCET keys carry no kind — use distinct "
                f"shapes per kind"
            )
    table = ProfileTable()
    profiler = MeasuredProfiler(warmup=2, runs=runs, quantile=quantile)
    for mid, shape_key, kind in cats:
        if kind == "decode":
            # Measure into a throwaway table (never into ``table``, whose
            # (mid, shape) key space the flat entry will own), with
            # bucketed=False: max_slots need not be a power of two, and
            # rounding it up would probe a batch the engine rejects.
            probe = ProfileTable()
            profiler.profile(
                probe,
                mid,
                shape_key,
                [engine.max_slots],
                lambda b, _m=mid, _s=shape_key: engine.execute(_m, _s, b, "decode"),
                bucketed=False,
            )
            wcet = probe.entries[(mid, tuple(shape_key))][engine.max_slots]
            table.record_flat(mid, shape_key, wcet, engine.max_slots)
            if chunk_depth > 1:
                depth = min(chunk_depth, engine.max_chunk_depth)
                prev = 0.0
                for k in chunk_depths(depth):
                    probe_k = ProfileTable()
                    profiler.profile(
                        probe_k,
                        mid,
                        shape_key,
                        [engine.max_slots],
                        lambda b, _m=mid, _s=shape_key, _k=k: (
                            engine.execute_chunk(_m, _s, b, _k)
                        ),
                        bucketed=False,
                    )
                    w = probe_k.entries[(mid, tuple(shape_key))][
                        engine.max_slots
                    ]
                    w = max(w, prev)
                    table.record_flat(
                        mid, shape_key, w, engine.max_slots, k=k
                    )
                    prev = w
        else:
            profiler.profile(
                table,
                mid,
                shape_key,
                list(batch_sizes),
                lambda b, _m=mid, _s=shape_key, _k=kind: engine.execute(_m, _s, b, _k),
            )
    return table


def _wire_live_scheduler(
    engine: InferenceEngine,
    table: ProfileTable,
    loop: WallClock,
    kinds: Dict[Tuple[str, Tuple[int, ...]], str],
    utilization_bound: float = 1.0,
    slot_aware: bool = False,
    leases: Optional[Dict[int, Tuple[str, int, Tuple[int, ...]]]] = None,
    device_wrap: Optional[Callable[[AsyncDevice], object]] = None,
) -> Tuple[DeepRT, object]:
    """Wire one live DeepRT over one engine behind the device contract.

    Shared by the single-device ``build_live_scheduler`` and the
    per-slice loop of ``build_live_cluster``. ``slot_aware=True`` makes
    decode jobs step the arena's allocator-live rows (the cluster leases
    one row per admitted decode stream) instead of the synthetic
    first-``batch_size``-rows prefix; either way the SAME compiled
    program executes — batch size is data.

    ``device_wrap`` interposes on the device AFTER construction but
    BEFORE the scheduler binds to it (fault injection wraps here: the
    scheduler then submits through the wrapper, while the wrapper
    injects at the real AsyncDevice's dispatch-handle layer).

    ``leases`` (slot-aware mode) is the request_id -> (mid, seq, rows)
    map the ``LiveSlice`` maintains — shared BY REFERENCE so decode
    dispatch can slot-align each frame's ingested token: stream X's
    payload lands in stream X's resident arena row, never a neighbor's.
    """

    def kind_of(job) -> str:
        # Keyed by the CATEGORY's shape: step kind is a property of the
        # category, and an adaptation-shrunk job must keep its kind even
        # if its running shape coincides with another category's.
        return kinds.get(
            (job.category.model_id, job.category.shape_key), "prefill"
        )

    def job_payload(job):
        """Per-frame ingested payloads, in the engine's payload form.
        All-``None`` (simulation traces, profiler warm-up) collapses to
        ``None`` — a zero frame through the same staging ring."""
        if all(f.payload is None for f in job.frames):
            return None
        return [f.payload for f in job.frames]

    # Filled in once the scheduler exists (the device needs dispatch_job
    # at construction, before the DeepRT that owns the metrics).
    metrics_ref: Dict[str, object] = {}

    def slot_payload(job, mid: str, seq: int):
        """{arena row -> token} for a slot-mode decode step: each
        frame's token goes to its own stream's leased row. One step
        consumes ONE token per row, so when a window batched two frames
        of the same stream the EARLIEST frame's token is staged (tokens
        stay in order) and the collision is counted in
        ``Metrics.payload_collisions`` — visible degradation, not a
        silent overwrite."""
        if leases is None or all(f.payload is None for f in job.frames):
            return None
        out: Dict[int, int] = {}
        for f in job.frames:
            lease = leases.get(f.request_id)
            if lease is None or lease[0] != mid or lease[1] != seq:
                continue  # no resident row (e.g. re-admitted mid-window)
            row = lease[2][0]
            tok = 0 if f.payload is None else int(np.asarray(f.payload))
            if row in out:
                metrics = metrics_ref.get("metrics")
                if metrics is not None:
                    metrics.payload_collisions += 1
                continue  # earliest frame's token wins (in-order)
            out[row] = tok
        return out or None

    def job_bytes(job) -> float:
        steps = job.k if isinstance(job, ChunkJob) else 1
        return engine.job_bytes(
            job.category.model_id, job.shape_key, job.batch_size,
            kind_of(job), steps=steps,
        )

    def executed_rows(job) -> int:
        # Arena decode always runs max_slots rows; prefill pads to the
        # power-of-two bucket. Keeps Metrics.padding_waste describing
        # what the engine really launched.
        if kind_of(job) == "decode":
            return engine.max_slots
        return bucket(job.batch_size)

    def frame_rows(job, mid: str, seq: int):
        """Arena rows whose stream has a frame in THIS job: only they
        run active (consume their token, advance their cursor) — a
        leased stream with no frame this window must not eat a phantom
        zero token. None (no lease info) = step everything active.
        An EMPTY list is returned as-is, never collapsed to None: a job
        whose every frame lost its lease (stream closed with a frame
        still queued in the window) must step NOTHING active, or the
        surviving streams' rows would each consume a phantom zero."""
        if leases is None:
            return None
        rows = []
        for f in job.frames:
            lease = leases.get(f.request_id)
            if lease is not None and lease[0] == mid and lease[1] == seq:
                rows.append(lease[2][0])
        return rows

    def dispatch_job(job):
        mid, shape = job.category.model_id, job.shape_key
        kind = kind_of(job)
        if isinstance(job, ChunkJob):
            # A fused k-step decode chunk: ONE scanned dispatch, with
            # each member job's payload staged as its own step (one
            # staging-ring slot per step) and each step's frame-bearing
            # rows masked per member — the idle-row semantics of
            # single-step ``step_rows``, held per step.
            if kind != "decode":
                raise RuntimeError(
                    f"chunked dispatch for non-decode category {mid}/{shape}"
                )
            seq = shape[0]
            if slot_aware:
                live = engine.arena(mid, seq).live
                if live:
                    return engine.decode_chunk(
                        mid, shape, len(live), job.k, slots=live,
                        payloads=[
                            slot_payload(j, mid, seq) for j in job.jobs
                        ],
                        step_rows=[
                            frame_rows(j, mid, seq) for j in job.jobs
                        ],
                    )
            for j in job.jobs:
                if job_payload(j) is not None and leases is None:
                    raise RuntimeError(
                        f"decode chunk for {mid}/{shape} carries real "
                        f"payload but no arena leases: ingest decode "
                        f"streams through build_live_cluster "
                        f"(slot-aware), not the prefix path"
                    )
            # No leased rows left (streams closed with frames queued):
            # drain the chunk as a zero-payload prefix dispatch.
            b = min(max(j.batch_size for j in job.jobs), engine.max_slots)
            return engine.decode_chunk(mid, shape, b, job.k)
        if slot_aware and kind == "decode":
            live = engine.arena(mid, shape[0]).live
            if live:
                # Continuous batching: every step runs ALL leased rows
                # through the one compiled program (partial stepping
                # would change the dispatch shape), but only the rows
                # whose stream has a frame this window are ACTIVE.
                return engine.dispatch(
                    mid, shape, len(live), kind, slots=live,
                    payload=slot_payload(job, mid, shape[0]),
                    step_rows=frame_rows(job, mid, shape[0]),
                )
        payload = job_payload(job)
        if kind == "decode" and payload is not None:
            if leases is None:
                # Prefix-mode decode assigns rows POSITIONALLY per
                # window and never advances the resident cursors — real
                # tokens would land in different rows step to step,
                # reading other streams' KV. Payload-carrying decode
                # requires the slot-aware cluster path (arena-row
                # leases); fail loudly rather than serve silently
                # corrupted streams. (The gateway also refuses decode
                # registration on a single-device target.)
                raise RuntimeError(
                    f"decode job for {mid}/{shape} carries real payload "
                    f"but no arena leases: ingest decode streams through "
                    f"build_live_cluster (slot-aware), not the prefix path"
                )
            # Cluster path with NO leased row left on this arena: every
            # frame's stream already released its lease (closed with
            # frames still queued). Nothing resident to step — drain the
            # job as a zero-payload no-op (tokens discarded; the frames
            # complete, the streams are gone).
            payload = None
        return engine.dispatch(mid, shape, job.batch_size, kind, payload=payload)

    device = AsyncDevice(loop, dispatch_fn=dispatch_job)
    if device_wrap is not None:
        device = device_wrap(device)
    # exec_time under async dispatch is the busy-until ESTIMATE (the
    # profiled WCET); the device reports the real completion instant.
    sched = DeepRT(
        table,
        loop=loop,
        execution=ExecutionModel(actual_fn=lambda job, wcet: wcet),
        utilization_bound=utilization_bound,
        device=device,
    )
    sched.worker.job_bytes_fn = job_bytes
    sched.worker.executed_rows_fn = executed_rows
    metrics_ref["metrics"] = sched.metrics
    # Non-RT requests bypass admission (the flat table's inf cannot
    # reject them), so bound their batches by the arena too — including
    # for caller-supplied engines whose max_slots may be small.
    sched.nonrt_batch_cap = min(sched.nonrt_batch_cap, engine.max_slots)
    return sched, device


def build_live_scheduler(
    configs: Dict[str, ModelConfig],
    categories: Iterable[Tuple[str, Tuple[int, ...], str]],
    batch_sizes=(1, 2, 4, 8),
    utilization_bound: float = 1.0,
    engine: Optional[InferenceEngine] = None,
    chunk_depth: int = 1,
    tracer=None,
) -> Tuple[DeepRT, InferenceEngine, ProfileTable]:
    """Build the live wall-clock DeepRT over a compiled engine.

    Zero-stall pipeline: profiled WCET estimates drive ``busy_until``,
    the AsyncDevice measures reality. The engine's decode arena is sized
    to the largest requested batch (``arena_slots``), so every admitted
    job fits the one resident program.

    ``chunk_depth`` > 1 enables multi-step decode chunking: the engine
    is built to serve chunks that deep, every depth on the ladder is
    profiled into the table's chunk family, and DeepRT auto-wires the
    EDF worker's slack-driven depth policy off that family.
    """
    if engine is None:
        # Non-RT requests bypass admission (their batches are bounded by
        # NONRT_BATCH_CAP, not by the imitator), so the arena must hold
        # that cap too — RT oversubscription is rejected at admission via
        # the flat table's inf beyond max_slots.
        engine = InferenceEngine(
            configs,
            max_slots=arena_slots(max(*batch_sizes, NONRT_BATCH_CAP)),
            chunk_depth=chunk_depth,
        )
    cats = list(categories)
    kinds = {(mid, tuple(shape)): kind for mid, shape, kind in cats}
    table = profile_engine(engine, cats, batch_sizes, chunk_depth=chunk_depth)
    engine.reset_stats()  # stats cover served traffic, not profiling
    sched, _device = _wire_live_scheduler(
        engine, table, WallClock(), kinds, utilization_bound
    )
    if tracer is not None:
        sched.attach_tracer(tracer)
    return sched, engine, table


def build_live_cluster(
    configs: Dict[str, ModelConfig],
    categories: Iterable[Tuple[str, Tuple[int, ...], str]],
    slice_names: Sequence[str] = ("slice0", "slice1"),
    batch_sizes=(1, 2, 4, 8),
    utilization_bounds: Optional[Dict[str, float]] = None,
    profile_runs: int = 5,
    nonrt_cap: int = NONRT_BATCH_CAP,
    watchdog: Optional[WatchdogConfig] = None,
    fault_plans: Optional[Dict[str, FaultPlan]] = None,
    chunk_depth: int = 1,
    tracer=None,
) -> Tuple[ClusterScheduler, Dict[str, LiveSlice]]:
    """Build a live multi-slice cluster: ``build_live_scheduler``, sliced.

    One shared WallClock; per slice, its OWN InferenceEngine (resident
    KV arena sized by ``bucketing.slice_arena_slots`` under that slice's
    Phase-1 utilization bound), its own AsyncDevice, and its own
    profiled WCET table — the arena is device-resident state, so slicing
    the fleet slices the arenas (ROADMAP open item, shipped here).
    Placement, spill-on-reject, per-request arena-row leases, and
    ``fail_slice`` re-admission all run through the returned
    ``ClusterScheduler``.

    ``utilization_bounds``: per-slice-name Phase-1 ceiling (default 1.0).
    ``profile_runs``: offline-profiler repetitions per slice (each slice
    profiles its own compiled programs — WCETs are per-mesh).
    ``nonrt_cap``: lets callers that serve no non-RT traffic shrink the
    arena floor below ``NONRT_BATCH_CAP`` (tests, benchmarks).
    ``watchdog``: arms the fault-tolerance loop — each slice's device
    gets a ``CompletionWatchdog`` (per-submit deadline = WCET × slack,
    floored by ``min_deadline``) and measured-completion reporting wired
    to the cluster's ``SliceHealthMonitor``, which drives the
    healthy/suspect/quarantined state machine, auto-``fail_slice`` on
    hangs, and live WCET re-profiling. Profiling itself bypasses the
    device, so watchdog deadlines only ever cover served jobs.
    ``fault_plans``: per-slice-name deterministic fault injection
    (``FaultyDevice`` wraps that slice's AsyncDevice at the
    dispatch-handle layer — chaos tests and benchmarks only).
    ``chunk_depth``: > 1 enables slack-driven multi-step decode
    chunking on every slice (engines built chunk-capable, per-depth
    WCET families profiled, EDF workers auto-wired — see
    ``build_live_scheduler``).
    """
    cats = list(categories)
    kinds = {(mid, tuple(shape)): kind for mid, shape, kind in cats}
    bounds = dict(utilization_bounds or {})
    unknown = set(bounds) - set(slice_names)
    if unknown:
        # A typoed bound would otherwise silently default that slice to
        # 1.0 — full-size arena, unbounded admission.
        raise ValueError(
            f"utilization_bounds for unknown slices {sorted(unknown)}; "
            f"slice_names = {list(slice_names)}"
        )
    plans = dict(fault_plans or {})
    unknown_plans = set(plans) - set(slice_names)
    if unknown_plans:
        raise ValueError(
            f"fault_plans for unknown slices {sorted(unknown_plans)}; "
            f"slice_names = {list(slice_names)}"
        )
    loop = WallClock()
    cluster = ClusterScheduler(loop=loop, watchdog=watchdog)
    slices: Dict[str, LiveSlice] = {}
    max_batch = max(*batch_sizes, nonrt_cap)
    for name in slice_names:
        bound = bounds.get(name, 1.0)
        engine = InferenceEngine(
            configs, max_slots=slice_arena_slots(max_batch, bound),
            chunk_depth=chunk_depth,
        )
        table = profile_engine(
            engine, cats, batch_sizes, runs=profile_runs,
            chunk_depth=chunk_depth,
        )
        engine.reset_stats()  # stats cover served traffic, not profiling
        # One lease map per slice, shared by reference between the
        # dispatch closure (slot-aligned payload staging) and the
        # LiveSlice (lease lifecycle).
        leases: Dict[int, Tuple[str, int, Tuple[int, ...]]] = {}
        wrap = None
        if name in plans:
            wrap = partial(_wrap_faulty, plan=plans[name])
        sched, device = _wire_live_scheduler(
            engine, table, loop, kinds,
            utilization_bound=bound, slot_aware=True, leases=leases,
            device_wrap=wrap,
        )
        inner = device.inner if isinstance(device, FaultyDevice) else device
        if watchdog is not None:
            # The watchdog lives on the REAL AsyncDevice: injected faults
            # then look exactly like hardware misbehavior to it.
            inner.watchdog = CompletionWatchdog(
                loop, watchdog,
                on_overdue=partial(cluster.health.note_overdue, name),
            )
            inner.on_measured = partial(cluster.health.note_complete, name)
        if isinstance(device, FaultyDevice):
            device.on_submit_error = partial(
                cluster.health.note_submit_error, name
            )
        spec = SliceSpec(name=name, table=table, utilization_bound=bound)
        sl = LiveSlice(
            spec, scheduler=sched, engine=engine, kinds=kinds, leases=leases
        )
        cluster.register(sl)
        slices[name] = sl
        # Execution-substrate observability: telemetry_snapshot folds in
        # each engine's arena occupancy / staging-ring reuse via probes.
        cluster.telemetry_probes[f"engine_{name}"] = engine.telemetry
    if tracer is not None:
        cluster.attach_tracer(tracer)
    return cluster, slices


def _wrap_faulty(device: AsyncDevice, plan: FaultPlan) -> FaultyDevice:
    return FaultyDevice(device, plan)


def build_live_transport(
    configs: Dict[str, ModelConfig],
    categories: Iterable[Tuple[str, Tuple[int, ...], str]],
    slice_names: Sequence[str] = ("slice0", "slice1"),
    batch_sizes=(1, 2, 4, 8),
    utilization_bounds: Optional[Dict[str, float]] = None,
    profile_runs: int = 5,
    nonrt_cap: int = NONRT_BATCH_CAP,
    watchdog: Optional[WatchdogConfig] = None,
    fault_plans: Optional[Dict[str, FaultPlan]] = None,
    chunk_depth: int = 1,
    tracer=None,
    shedding: bool = True,
    udp: bool = False,
    host: str = "127.0.0.1",
    port: int = 0,
    **transport_kwargs,
):
    """``build_live_cluster`` with the network front door attached.

    Stacks the ingest gateway and the transport server over a live
    cluster — the full networked serving path on one WallClock: wire
    datagrams -> reassembly (reorder window, dedup, late rejection) ->
    gateway shedding/backpressure -> placement/admission/leases -> EDF.
    The transport server registers as the cluster's rehome owner, so a
    ``fail_slice`` re-homes live sessions with their buffered bytes.

    ``udp=True`` additionally binds a real UDP socket front end (started;
    callers own ``binding.close()``). ``transport_kwargs`` forward to
    :class:`~repro.ingest.transport.TransportServer` (flow_control,
    reorder_window, record_payloads, ...).

    Returns ``(cluster, slices, gateway, transport, binding)`` with
    ``binding=None`` unless ``udp``.
    """
    # Imported here: serving must stay importable without dragging the
    # ingest package into every bridge user (and vice versa).
    from repro.ingest.session import IngestGateway
    from repro.ingest.transport import TransportServer, UdpServerBinding

    cluster, slices = build_live_cluster(
        configs, categories,
        slice_names=slice_names,
        batch_sizes=batch_sizes,
        utilization_bounds=utilization_bounds,
        profile_runs=profile_runs,
        nonrt_cap=nonrt_cap,
        watchdog=watchdog,
        fault_plans=fault_plans,
        chunk_depth=chunk_depth,
        tracer=tracer,
    )
    gateway = IngestGateway(cluster, shedding=shedding)
    transport = TransportServer(gateway, **transport_kwargs)
    if tracer is not None:
        gateway.tracer = tracer
        transport.tracer = tracer
    binding = None
    if udp:
        binding = UdpServerBinding(transport, host=host, port=port).start()
    return cluster, slices, gateway, transport, binding
