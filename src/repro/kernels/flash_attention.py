"""Flash attention Pallas TPU kernel (prefill/training path).

Schedule: grid (batch, heads, q_blocks, kv_blocks) — the kv axis is
innermost and TPU grids execute sequentially, so the online-softmax
running state (m, l, acc) lives in VMEM scratch and carries across kv
iterations; the output tile is written once on the last kv block.

VMEM working set per grid step (f32):
    q tile (bq, D) + k/v tiles (bk, D) + logits (bq, bk) + acc (bq, D)
With bq = bk = 128, D <= 256 that is well under 1 MiB — far inside the
~16 MiB VMEM budget; block sizes are multiples of the 128-lane MXU tiling.

GQA is handled in the index map: the kv-head index is ``h // group``, so
K/V tiles are fetched once per kv head without materializing the
expanded (B, S, H, D) tensors the XLA fallback would need.

Causal and sliding-window masks are applied from block-relative iota
positions; fully-masked (q_block, kv_block) pairs are skipped via
``pl.when`` (block-sparse schedule — the same trick that makes causal
flash ~2x over the dense loop on TPU).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    seq_len: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k
    run = k_lo < seq_len  # skip fully padded kv blocks
    if causal:
        run = jnp.logical_and(run, k_lo <= q_lo + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_lo + block_k - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :]  # (bq, D)
        k = k_ref[0, :, 0, :]  # (bk, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q,
            k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype),
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _write():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    kv = k.shape[2]
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, max(s, 8))
    block_k = min(block_k, max(s, 8))
    nq = math.ceil(s / block_q)
    nk = math.ceil(s / block_k)
    s_pad_q = nq * block_q
    s_pad_k = nk * block_k
    qp = jnp.pad(q, ((0, 0), (0, s_pad_q - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, s_pad_k - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, s_pad_k - s), (0, 0), (0, 0)))

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        seq_len=s,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (1, block_q, 1, d), lambda b_, h_, q_, k_: (b_, q_, h_, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, d),
                lambda b_, h_, q_, k_: (b_, k_, h_ // group, 0),
            ),
            pl.BlockSpec(
                (1, block_k, 1, d),
                lambda b_, h_, q_, k_: (b_, k_, h_ // group, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, d), lambda b_, h_, q_, k_: (b_, q_, h_, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, s_pad_q, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s]
