"""RWKV-6 WKV Pallas TPU kernel (Finch time-mix recurrence).

Per head, the matrix-valued state S (K x V) evolves as
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Schedule: grid (batch, heads, t_blocks), time innermost; S carries in
VMEM scratch (K x V f32 — 64x64x4B = 16 KiB per head, trivially VMEM-
resident). Within a time block each step is two rank-1 updates and a
vector-matrix product on (K, V) tiles — K = V = 64 matches the MXU/VPU
tile granularity of the head layout.

The r/k/v/g/w projections, token-shift ddlerp and LoRA decay stay in XLA
outside the kernel: they are batched matmuls XLA already schedules well.
The kernel owns only the sequential state dependency.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    r_ref,  # (1, bt, 1, K)
    k_ref,
    v_ref,  # (1, bt, 1, V)
    w_ref,  # (1, bt, 1, K)
    u_ref,  # (1, K)
    s0_ref,  # (1, 1, K, V) block of (B, H, K, V)
    o_ref,  # (1, bt, 1, V)
    slast_ref,  # (1, 1, K, V)
    s_ref,  # scratch (K, V) f32
    *,
    block_t: int,
    n_t_blocks: int,
):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (bt, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bt, V)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)  # (K,)

    def step(t, S):
        kv = k[t][:, None] * v[t][None, :]  # (K, V)
        out = jnp.dot(
            r[t][None, :], S + u[:, None] * kv,
            preferred_element_type=jnp.float32,
        )  # (1, V)
        o_ref[0, t, 0, :] = out[0].astype(o_ref.dtype)
        return w[t][:, None] * S + kv

    S = jax.lax.fori_loop(0, block_t, step, s_ref[...])
    s_ref[...] = S

    @pl.when(ti == n_t_blocks - 1)
    def _write_state():
        slast_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv6(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, S, H, V)
    w: jax.Array,  # (B, S, H, K)
    u: jax.Array,  # (H, K)
    state: Optional[jax.Array] = None,  # (B, H, K, V)
    *,
    block_t: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)
    block_t = min(block_t, s)
    nt = math.ceil(s / block_t)
    s_pad = nt * block_t
    # Pad w with 1 (identity decay), k/v/r with 0: padded steps are no-ops.
    rp = jnp.pad(r, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    wp = jnp.pad(
        w, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)), constant_values=1.0
    )
    kernel = functools.partial(_kernel, block_t=block_t, n_t_blocks=nt)
    out, slast = pl.pallas_call(
        kernel,
        grid=(b, h, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, 1, dk), lambda b_, h_, t_: (b_, t_, h_, 0)),
            pl.BlockSpec((1, block_t, 1, dk), lambda b_, h_, t_: (b_, t_, h_, 0)),
            pl.BlockSpec((1, block_t, 1, dv), lambda b_, h_, t_: (b_, t_, h_, 0)),
            pl.BlockSpec((1, block_t, 1, dk), lambda b_, h_, t_: (b_, t_, h_, 0)),
            pl.BlockSpec((1, dk), lambda b_, h_, t_: (h_, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b_, h_, t_: (b_, h_, 0, 0)),
        ],  # s0: (B, H, K, V)
        out_specs=[
            pl.BlockSpec((1, block_t, 1, dv), lambda b_, h_, t_: (b_, t_, h_, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b_, h_, t_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s_pad, h, dv), r.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(rp, kp, vp, wp, u, state)
    return out[:, :s], slast
