"""Jitted dispatch wrappers for the Pallas kernels.

The model code calls these (``cfg.impl == 'pallas'``); on a CPU backend
they transparently run in interpret mode (the kernel bodies execute in
Python for correctness validation), on TPU they compile to Mosaic.
Wrappers own layout/padding plumbing so kernels stay minimal.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _decode
from repro.kernels import flash_attention as _flash
from repro.kernels import rglru as _rglru
from repro.kernels import wkv6 as _wkv6


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    positions: Optional[jax.Array] = None,  # (B, S) — must be arange
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    """Prefill/training attention. The kernel assumes standard arange
    positions (left-aligned prefill); callers with exotic position maps
    use the XLA path instead."""
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, interpret=_interpret()
    )


def decode_attention(
    q: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cursor: jax.Array,
    kv_pos: jax.Array,
    kv_valid: jax.Array,
    active: Optional[jax.Array] = None,  # (B,) live-slot bitmap (arena)
    *,
    window: Optional[int] = None,
) -> jax.Array:
    return _decode.decode_attention(
        q,
        cache_k,
        cache_v,
        cursor,
        kv_pos,
        kv_valid,
        active,
        window=window,
        interpret=_interpret(),
    )


def rglru_scan(
    a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    return _rglru.rglru_scan(a, b, h0, interpret=_interpret())


def wkv6(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    return _wkv6.wkv6(r, k, v, w, u, state, interpret=_interpret())
