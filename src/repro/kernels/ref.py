"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose in
interpret mode). They intentionally re-derive the math independently of
the model code paths where practical.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    """Materialized-softmax attention with arange positions."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d).astype(jnp.float32)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)
    ) / math.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (B, 1, H, D)
    cache_k: jax.Array,  # (B, S, KV, D)
    cache_v: jax.Array,
    cursor: jax.Array,  # (B,) current absolute position
    kv_pos: jax.Array,  # (B, S)
    kv_valid: jax.Array,  # (B, S) bool
    active: Optional[jax.Array] = None,  # (B,) bool — dead rows output 0
    *,
    window: Optional[int] = None,
) -> jax.Array:
    b, _, h, d = q.shape
    kv = cache_k.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, d).astype(jnp.float32)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, cache_k.astype(jnp.float32)
    ) / math.sqrt(d)
    mask = (kv_pos <= cursor[:, None]) & kv_valid
    if window is not None:
        mask &= kv_pos > (cursor[:, None] - window)
    if active is not None:
        mask &= active[:, None]
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cache_v.astype(jnp.float32))
    out = out.reshape(b, 1, h, d)
    if active is not None:
        # Match the kernel's skip semantics: a fully-dead row attends to
        # nothing and outputs exact 0 (softmax over all-NEG_INF would
        # instead emit the uniform mean of V).
        out = jnp.where(active[:, None, None, None], out, 0.0)
    return out.astype(q.dtype)


def rglru_ref(
    a: jax.Array,  # (B, S, D) decay in (0, 1)
    b_in: jax.Array,  # (B, S, D) gated inputs
    h0: Optional[jax.Array] = None,  # (B, D)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t."""
    bsz, s, d = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, d), jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_last, hs = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (
            a.transpose(1, 0, 2).astype(jnp.float32),
            b_in.transpose(1, 0, 2).astype(jnp.float32),
        ),
    )
    return hs.transpose(1, 0, 2).astype(a.dtype), h_last


def wkv6_ref(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,  # (B, S, H, K)
    v: jax.Array,  # (B, S, H, V)
    w: jax.Array,  # (B, S, H, K) decay in (0, 1)
    u: jax.Array,  # (H, K) bonus
    state: Optional[jax.Array] = None,  # (B, H, K, V)
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(S, ins):
        rt, kt, vt, wt = ins
        kvt = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kvt)
        return wt[..., :, None] * S + kvt, out

    state, outs = jax.lax.scan(
        step,
        state.astype(jnp.float32),
        (
            r.transpose(1, 0, 2, 3).astype(jnp.float32),
            k.transpose(1, 0, 2, 3).astype(jnp.float32),
            v.transpose(1, 0, 2, 3).astype(jnp.float32),
            w.transpose(1, 0, 2, 3).astype(jnp.float32),
        ),
    )
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), state
