"""RG-LRU linear-recurrence Pallas TPU kernel (RecurrentGemma prefill).

Computes h_t = a_t * h_{t-1} + b_t over time, channel-blocked.

Schedule: grid (batch, d_blocks, t_blocks), time innermost (TPU grids are
sequential, so the hidden state h carries across time blocks in VMEM
scratch). Within a time block the recurrence is stepped with a fori_loop
of fused multiply-adds over a (block_d,)-wide channel vector — VPU work.
The gate/decay computation (sigmoids, matmuls) stays in XLA outside the
kernel; the kernel owns exactly the sequential dependency, which is the
part XLA cannot parallelize or fuse well.

TPU adaptation note (DESIGN.md §2): GPU implementations of linear scans
lean on warp shuffles for intra-warp prefix products; the TPU-native
formulation is this chunked-carry schedule — HBM traffic is exactly one
read of (a, b) and one write of h per element, making the kernel purely
bandwidth-bound, which is the roofline optimum for a recurrence.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    a_ref,  # (1, bt, bd)
    b_ref,
    h0_ref,  # (1, bd)
    o_ref,  # (1, bt, bd)
    hlast_ref,  # (1, bd)
    h_ref,  # scratch (bd,) f32
    *,
    block_t: int,
    n_t_blocks: int,
    seq_len: int,
):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[0, :].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (bt, bd)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ti == n_t_blocks - 1)
    def _write_state():
        hlast_ref[0, :] = h_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_d", "interpret")
)
def rglru_scan(
    a: jax.Array,  # (B, S, D) decay in (0, 1)
    b: jax.Array,  # (B, S, D) inputs
    h0: Optional[jax.Array] = None,  # (B, D)
    *,
    block_t: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    bsz, s, d = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, d), jnp.float32)
    block_t = min(block_t, s)
    block_d = min(block_d, d)
    nt = math.ceil(s / block_t)
    nd = math.ceil(d / block_d)
    s_pad, d_pad = nt * block_t, nd * block_d
    # Pad decays with 1 (identity) and inputs with 0 so padded time steps
    # leave the state untouched.
    ap = jnp.pad(a, ((0, 0), (0, s_pad - s), (0, d_pad - d)), constant_values=1.0)
    bp = jnp.pad(b, ((0, 0), (0, s_pad - s), (0, d_pad - d)))
    hp = jnp.pad(h0, ((0, 0), (0, d_pad - d)))

    kernel = functools.partial(
        _kernel, block_t=block_t, n_t_blocks=nt, seq_len=s
    )
    out, hlast = pl.pallas_call(
        kernel,
        grid=(bsz, nd, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b_, d_, t_: (b_, t_, d_)),
            pl.BlockSpec((1, block_t, block_d), lambda b_, d_, t_: (b_, t_, d_)),
            pl.BlockSpec((1, block_d), lambda b_, d_, t_: (b_, d_)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b_, d_, t_: (b_, t_, d_)),
            pl.BlockSpec((1, block_d), lambda b_, d_, t_: (b_, d_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s_pad, d_pad), a.dtype),
            jax.ShapeDtypeStruct((bsz, d_pad), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(ap, bp, hp)
    return out[:, :s, :d], hlast[:, :d]
