"""Decode (single-token) attention Pallas TPU kernel — flash-decoding.

One new token per sequence attends to its full (or ring) KV cache.
Schedule: grid (batch, kv_heads, kv_blocks); the G = H/KV query heads of
one kv head are processed together as a (G, D) tile (G is small for GQA,
so this keeps the MXU busy with a (G, D) x (D, bk) matmul instead of G
vector-matrix products). Online-softmax state (m, l, acc) lives in VMEM
scratch across kv blocks; output written on the last block.

Masking is fully position-driven: the caller passes per-slot absolute
positions and a validity bitmap, so full caches, ring (sliding-window)
caches, and continuous-batching caches with per-sequence cursors all use
the same kernel. Fully-masked kv blocks are SKIPPED (``pl.when``), which
is bit-identical for any row with at least one live slot. On top of that
sits the slot-arena path: ``active`` is a per-row bitmap (the engine's
live-slot set — batch size as DATA, not shape), folded into every
block's mask, so a dead arena row skips ALL its kv blocks — the whole
row costs two scalar compares per block instead of attention. A row with
zero live slots outputs exact 0 (the mathematically sensible "attended
to nothing"), not the uniform mean-of-V an unskipped softmax would give.

The serving engine's decode hot loop is THE perf-critical path of the
DeepRT reproduction (batched decode job instances are what the GPU/TPU
executes most of the time), which is why this kernel exists.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, bk, 1, D)
    v_ref,
    cursor_ref,  # (1, 1) int32
    active_ref,  # (1, 1) int32 (0/1) — live arena slot?
    pos_ref,  # (1, bk) int32
    valid_ref,  # (1, bk) int32 (0/1)
    o_ref,  # (1, 1, G, D)
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    window: Optional[int],
    n_kv_blocks: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, :]  # (G, D)
    cursor = cursor_ref[0, 0]
    active = active_ref[0, 0] != 0
    pos = pos_ref[0, :]  # (bk,)
    valid = valid_ref[0, :] != 0

    mask = jnp.logical_and(jnp.logical_and(pos <= cursor, valid), active)
    if window is not None:
        mask = jnp.logical_and(mask, pos > cursor - window)

    # Skip fully-masked kv blocks: a masked block's contribution is
    # exactly zero (p underflows to 0, alpha = 1), so eliding the two
    # MXU matmuls is bit-identical. This is what makes dead arena rows
    # free — ``active=0`` zeroes every block's mask so the row skips ALL
    # kv blocks — and a ring cache skips its unwritten tail.
    @pl.when(jnp.any(mask))
    def _accumulate():
        k = k_ref[0, :, 0, :]  # (bk, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, bk)
        s = jnp.where(mask[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _write():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret")
)
def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    cache_k: jax.Array,  # (B, S, KV, D)
    cache_v: jax.Array,
    cursor: jax.Array,  # (B,) int32
    kv_pos: jax.Array,  # (B, S) int32
    kv_valid: jax.Array,  # (B, S) bool
    active: Optional[jax.Array] = None,  # (B,) bool — None = all live
    *,
    window: Optional[int] = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, one, h, d = q.shape
    if active is None:
        active = jnp.ones((b,), jnp.int32)
    s, kv = cache_k.shape[1], cache_k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    block_k = min(block_k, max(s, 8))
    nk = math.ceil(s / block_k)
    s_pad = nk * block_k
    kp = jnp.pad(cache_k, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    vp = jnp.pad(cache_v, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    pp = jnp.pad(kv_pos, ((0, 0), (0, s_pad - s)), constant_values=2**30)
    vv = jnp.pad(
        kv_valid.astype(jnp.int32), ((0, 0), (0, s_pad - s))
    )
    # Layout: (B, KV, G, D) so one block = one kv-head's query group.
    q_kv = q.reshape(b, kv, g, d)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        window=window,
        n_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, k_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, h_, k_: (b_, k_, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, h_, k_: (b_, k_, h_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, k_: (b_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, k_: (b_, 0)),
            pl.BlockSpec((1, block_k), lambda b_, h_, k_: (b_, k_)),
            pl.BlockSpec((1, block_k), lambda b_, h_, k_: (b_, k_)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, k_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(
        q_kv,
        kp,
        vp,
        cursor[:, None].astype(jnp.int32),
        active[:, None].astype(jnp.int32),
        pp.astype(jnp.int32),
        vv,
    )
    return out.reshape(b, 1, h, d)
