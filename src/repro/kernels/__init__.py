"""Pallas TPU kernels for the serving hot spots (flash_attention,
decode_attention, rglru, wkv6) - each with ops.py jitted wrappers and
ref.py pure-jnp oracles; tests sweep shapes/dtypes in interpret mode."""
