"""Int8 error-feedback gradient compression for the cross-pod link.

In-pod ICI is fast (~50 GB/s/link); the cross-pod DCI link is the slow
edge of the multi-pod mesh, so only the POD-axis reduction is
compressed. Scheme per leaf:

  1. add the carried error-feedback residual to the local gradient;
  2. per-block (last-dim) max-abs scales -> symmetric int8 quantization;
  3. all_gather(int8 blocks + f32 scales) over the pod axis
     (for pod counts of 2-4, gather+local-sum moves ~the same bytes as a
     ring all-reduce but admits int8 payloads, which jax.lax.psum would
     overflow);
  4. dequantize-and-mean locally; residual = local_grad - own quantized
     contribution (error feedback keeps the compression unbiased over
     time — SGD-EF convergence argument).

Bytes on the wire: 1/4 of bf16, 1/8 of f32 gradients (+ scales epsilon).

Used inside shard_map over the pod axis by train_loop when
``cross_pod_compression=True``; in-pod reductions stay full precision.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (..., N) -> int8 codes (..., N) + scales (..., N/BLOCK)."""
    shape = x.shape
    n = shape[-1]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(shape[:-1] + (-1, BLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _dequantize(codes: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    xb = codes.astype(jnp.float32) * scale
    return xb.reshape(xb.shape[:-2] + (-1,))[..., :n]


def compressed_pod_mean(
    grad: jax.Array, residual: jax.Array, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Mean-reduce ``grad`` over ``axis_name`` with int8 EF compression.
    Returns (reduced grad f32, new residual). Call under shard_map with
    the pod axis in scope."""
    g = grad.astype(jnp.float32) + residual
    flat = g.reshape(-1)
    codes, scale = _quantize(flat)
    own = _dequantize(codes, scale, flat.shape[0])
    new_residual = (flat - own).reshape(grad.shape)
    all_codes = jax.lax.all_gather(codes, axis_name)  # (P, nb, BLOCK) int8
    all_scales = jax.lax.all_gather(scale, axis_name)
    n_pods = all_codes.shape[0]
    total = jnp.sum(
        all_codes.astype(jnp.float32) * all_scales, axis=0
    )
    mean = (
        total.reshape(-1)[: flat.shape[0]] / n_pods
    ).reshape(grad.shape)
    return mean, new_residual


def compress_tree_pod_mean(
    grads: Any, residuals: Any, axis_name: str
) -> Tuple[Any, Any]:
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [compressed_pod_mean(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
