"""Training step factory: loss -> grads -> AdamW, sharded via pjit.

``make_train_step`` builds the jittable step closed over (model, opt
config); ``shardings_for_state`` derives every in/out sharding from the
model's logical-axes tree through the rules engine — the same function
serves real training (examples/train_small.py) and the multi-pod dry-run
(launch/dryrun.py), which only lowers it.

Gradient accumulation wraps the loss in a lax.scan over microbatches.
Optional cross-pod int8 error-feedback compression (training/
compression.py) replaces the pod-axis portion of the gradient reduction
when params are NOT pod-sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.distributed import sharding as shd
from repro.training import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.AdamWState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    grad_accum: int = 1
    aux_weight: float = 0.01


def init_state(model, key, dtype=None) -> TrainState:
    params = model.init(key, dtype)
    return TrainState(params=params, opt=opt.init(params))


def abstract_state(model, dtype=None) -> TrainState:
    params = model.abstract_params(dtype)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return TrainState(
        params=params,
        opt=opt.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(f32, params),
            v=jax.tree.map(f32, params),
        ),
    )


def state_axes(model) -> TrainState:
    axes = model.axes()
    return TrainState(
        params=axes,
        opt=opt.AdamWState(step=(), m=axes, v=axes),
    )


def shardings_for_state(model, mesh: Mesh) -> TrainState:
    axes = state_axes(model)
    shapes = abstract_state(model)

    def leafshard(leaf, ax):
        return NamedSharding(
            mesh, shd.spec_for_shape(leaf.shape, ax, mesh, shd.PARAM_RULES)
        )

    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    params_sh = jax.tree.map(
        leafshard, shapes.params, axes.params, is_leaf=None
    )
    m_sh = jax.tree.map(leafshard, shapes.opt.m, axes.opt.m)
    v_sh = jax.tree.map(leafshard, shapes.opt.v, axes.opt.v)
    return TrainState(
        params=params_sh,
        opt=opt.AdamWState(
            step=NamedSharding(mesh, PartitionSpec()), m=m_sh, v=v_sh
        ),
    )


def batch_sharding(
    mesh: Mesh, shape: Tuple[int, ...], axes: Optional[Tuple] = None
) -> NamedSharding:
    """Sharding for a data-batch array: batch over (pod, data)."""
    if axes is None:
        axes = ("batch",) + ("seq",) * (len(shape) - 1)
    return NamedSharding(
        mesh, shd.spec_for_shape(shape, axes, mesh, shd.ACT_RULES)
    )


def make_train_step(
    model, tcfg: TrainConfig
) -> Callable[[TrainState, Any], Tuple[TrainState, dict]]:
    """Returns train_step(state, batch) -> (state', metrics).

    ``batch`` is {'tokens': (B, S)} (+ 'positions' for mrope archs, or
    {'frames','dec_tokens'} for encdec). With grad_accum=k the global
    batch is split along dim 0 into k microbatches and gradients are
    accumulated in f32 by a lax.scan (remat inside the model bounds live
    activation memory per microbatch).
    """

    def loss_fn(params, micro):
        if "frames" in micro:
            return model.loss(params, micro["frames"], micro["dec_tokens"])
        return model.loss(
            params, micro["tokens"], micro.get("positions"),
            aux_weight=tcfg.aux_weight,
        )

    def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        if tcfg.grad_accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            k = tcfg.grad_accum

            def split(x):
                b = x.shape[0] if x.ndim < 3 else x.shape[1]
                if x.ndim == 3 and x.shape[0] == 3:  # mrope positions
                    return x.reshape(3, k, -1, *x.shape[2:]).transpose(1, 0, 2, 3)
                return x.reshape(k, -1, *x.shape[1:])

            micros = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )

            def acc(carry, micro):
                tot_l, tot_g = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, micro)
                tot_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), tot_g, g
                )
                return (tot_l + l, tot_g), None

            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros(()), zero_g), micros
            )
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        new_params, new_opt, metrics = opt.update(
            tcfg.adamw, grads, state.opt, state.params
        )
        metrics["loss"] = loss
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step
