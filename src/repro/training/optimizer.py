"""AdamW in pure JAX (no optax), with cosine schedule and global-norm
clipping. Optimizer state mirrors the parameter tree (same logical axes,
so m/v shard exactly like params — ZeRO-style when params are FSDP-
sharded)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    decay_steps = max(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(
    cfg: AdamWConfig,
    grads: Any,
    state: AdamWState,
    params: Any,
) -> Tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_m = treedef.unflatten([n[1] for n in new])
    new_v = treedef.unflatten([n[2] for n in new])
    return (
        new_p,
        AdamWState(step=step, m=new_m, v=new_v),
        {"grad_norm": gnorm, "lr": lr},
    )
