"""Synthetic token data pipeline.

Deterministic, seekable, and shardable: batch ``i`` is a pure function of
(seed, i), so a restarted run resumes mid-epoch from the checkpointed
step with identical data, and each data-parallel host can generate only
its slice (``host_slice``). Generation mimics a Zipfian token
distribution so embedding-gather and softmax cost profiles are realistic
rather than uniform-random.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1


class SyntheticTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipfian token probabilities (stable across runs).
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._probs = (p / p.sum()).astype(np.float64)

    def batch(self, index: int, host_slice: Optional[Tuple[int, int]] = None
              ) -> Dict[str, np.ndarray]:
        """Batch ``index``; host_slice=(host_id, n_hosts) generates only
        this host's rows of the global batch."""
        cfg = self.cfg
        lo, hi = 0, cfg.global_batch
        if host_slice is not None:
            host, n_hosts = host_slice
            per = cfg.global_batch // n_hosts
            lo, hi = host * per, (host + 1) * per
        rows = []
        for r in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, index, r])
            )
            rows.append(
                rng.choice(cfg.vocab_size, size=cfg.seq_len, p=self._probs)
            )
        tokens = np.stack(rows).astype(np.int32)
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1
