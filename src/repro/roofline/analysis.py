"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (idealized):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_bw_per_chip

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed) — for an
SPMD module XLA reports the PER-DEVICE program, so terms divide by
per-chip peaks, not by the whole mesh. ``collective_bytes`` is not in
cost_analysis: we parse the optimized HLO text and sum the operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (two passes: first build a value->bytes table from
definition sites, then sum operands of collective ops).

Also reported: MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the
ratio MODEL_FLOPS / HLO_FLOPS — how much of the compiled compute is
"useful" (catches remat recompute and dispatch waste). For decode steps
D = batch tokens (one step), and the 2x backward factor is absent.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w\.\-]+)\s*=\s*(\(?[^)]*?\)?)\s*(\w[\w\-]*)\(")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, possibly a tuple '(bf16[..], ..)'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    # Pass 1: value name -> bytes at definition.
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        name = lhs.strip().lstrip("%").split(" ")[0].strip()
        if not name:
            continue
        # Type annotation is the prefix of rhs up to the op name.
        rhs = rhs.strip()
        # e.g. "bf16[8,128]{1,0} all-gather(%x), ..." or tuple types.
        op_m = re.match(r"^(\(?.*?\)?(?:\{[\d,]*\})?)\s+([\w\-]+)\(", rhs)
        if op_m:
            sizes[name] = _shape_bytes(op_m.group(1))
    # Pass 2: operands of collectives.
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\(?.*?\)?(?:\{[\d,]*\})?)\s+([\w\-]+)\((.*)$", stripped)
        if not m:
            continue
        op = m.group(2)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        args = m.group(3)
        # Operand names: %foo or bare identifiers before first ')'.
        arg_str = args.split(")")[0]
        total = 0
        for ref in re.finditer(r"%?([\w\.\-]+)", arg_str):
            nm = ref.group(1)
            if nm in sizes:
                total += sizes[nm]
        if total == 0:
            # Fallback: use the op's own result size.
            total = _shape_bytes(m.group(1))
        out[kind] += total
    return out


@dataclasses.dataclass
class RooflineReport:
    flops: float  # per-device
    hbm_bytes: float  # per-device (ideal-fusion lower bound)
    coll_bytes: float  # per-device, total over collective kinds
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: Optional[float] = None
    hbm_bytes_upper: Optional[float] = None  # CPU-HLO fusion upper bound

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_per_device": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "hbm_bytes_upper_per_device": self.hbm_bytes_upper,
        }


def analyze(
    compiled,
    hlo_text: str,
    model_flops_global: Optional[float] = None,
    n_devices: int = 1,
    jaxpr_flops_global: Optional[float] = None,
    jaxpr_bytes_global: Optional[float] = None,
) -> RooflineReport:
    """FLOPs + HBM bytes: exact jaxpr counts (global) / n_devices —
    trip-count correct, backend-independent, ideal-fusion traffic (the
    roofline idealization). Collectives + the bytes UPPER bound:
    trip-count-corrected walk of the compiled per-device HLO
    (repro.roofline.hlo_cost). ``compiled.cost_analysis()`` alone
    undercounts every while body by its trip count, which would zero out
    scan-over-layers models — it is recorded for reference only."""
    from repro.roofline.hlo_cost import HloCost

    hc = HloCost(hlo_text)
    ideal_flops = (
        jaxpr_flops_global / n_devices if jaxpr_flops_global is not None else None
    )
    # TRUE per-device flops from post-SPMD HLO dots — charges replicated
    # compute (unshardeable heads etc.) to every device. The compute term
    # uses max(hlo, ideal): the HLO count can miss dots hidden in backend
    # custom-calls, the ideal count can miss replication waste.
    hlo_flops = hc.dot_flops()
    if ideal_flops is not None:
        flops = max(hlo_flops, ideal_flops)
    elif hlo_flops > 0:
        flops = hlo_flops
    else:  # fallback (documented caveat: undercounts scans)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        flops = float(cost.get("flops", 0.0))
    hbm_upper = hc.hbm_bytes()
    hbm = (
        jaxpr_bytes_global / n_devices
        if jaxpr_bytes_global is not None
        else hbm_upper
    )
    coll = {k: float(v) for k, v in hc.collective_bytes().items()}
    coll_total = float(sum(coll.values()))
    return RooflineReport(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=coll_total,
        coll_breakdown=coll,
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=coll_total / ICI_BW,
        model_flops=(
            model_flops_global / n_devices if model_flops_global else None
        ),
        hbm_bytes_upper=hbm_upper,
    )


def model_flops_for(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """6·N_active·D for train (fwd+bwd), 2·N_active·D for inference."""
    n_active = cfg.active_param_count_estimate()
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * global_batch
