"""Trip-count-correct cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop BODY once, ignoring
the trip count — fatal for scan-over-layers models (a 126-layer llama3
would report 1 layer's FLOPs/bytes). This module re-derives costs from
the compiled module text with computation multipliers:

  1. parse computations and the call graph (fusion ``calls=``,
     ``to_apply=``, while ``condition=/body=``);
  2. while trip counts come from XLA's ``backend_config=
     {"known_trip_count":{"n":...}}`` annotation (scan always produces a
     known count), fallback 1;
  3. propagate multipliers from ENTRY (while body/cond edges multiply by
     the trip count, plain call edges by 1);
  4. per computation, accumulate
       - HBM bytes: operand + result bytes of every top-level op
         (post-fusion: a fusion op's operands/results ARE its HBM
         traffic; its internals stay on-chip), skipping bookkeeping ops;
       - collective bytes: operand bytes of all-gather / all-reduce /
         reduce-scatter / all-to-all / collective-permute, per kind.

FLOPs are NOT taken from HLO (CPU-backend lowering can hide dots inside
custom calls); repro.roofline.jaxpr_cost walks the jaxpr instead —
backend-independent and exact, with scan multipliers.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# Ops that move no HBM bytes of their own.
_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "domain",
    "opt-barrier", "copy-start", "copy-done", "async-start", "async-done",
    "async-update", "get-dimension-size",
    # Control-flow ops alias their carried buffers; the traffic happens
    # inside their body computations (counted with multipliers).
    "while", "conditional", "call",
}

_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?(?:\{[\d,:TSE()]*\})?)\s+([\w\-]+)\((.*)$"
)
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?')
_CALLS_RE = re.compile(r"(?:calls|to_apply|condition|body|branch_computations)=\{?%?([\w\.\-,%\s]+)\}?")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


class HloCost:
    def __init__(self, text: str):
        self.text = text
        self._parse()
        self._propagate()

    # -- parsing -----------------------------------------------------------
    def _parse(self) -> None:
        self.comp_ops: Dict[str, List[Tuple[str, str, str, str]]] = defaultdict(list)
        # op tuples: (name, type_str, opcode, args_str)
        self.value_bytes: Dict[str, int] = {}
        self.value_dims: Dict[str, List[int]] = {}
        self.entry: str = ""
        # edges: (parent_comp, child_comp, multiplier_kind) where kind is
        # 'call' or ('while', trip)
        self.edges: List[Tuple[str, str, int]] = []
        current = None
        for raw in self.text.splitlines():
            m = _COMP_START.match(raw)
            if m and raw.rstrip().endswith("{"):
                current = m.group(1)
                if raw.lstrip().startswith("ENTRY"):
                    self.entry = current
                continue
            if raw.startswith("}"):
                current = None
                continue
            if current is None:
                continue
            om = _OP_LINE.match(raw)
            if not om:
                continue
            name, type_str, opcode, args = om.groups()
            self.comp_ops[current].append((name, type_str, opcode, args))
            self.value_bytes[name] = _shape_bytes(type_str)
            sm = _SHAPE_RE.search(type_str)
            if sm is not None:
                dims = sm.group(2)
                self.value_dims[name] = (
                    [int(d) for d in dims.split(",") if d] if dims else []
                )
            if opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(raw)
                if tm:
                    trip = int(tm.group(1))
                cm = re.search(r"condition=%?([\w\.\-]+)", raw)
                bm = re.search(r"body=%?([\w\.\-]+)", raw)
                if bm:
                    self.edges.append((current, bm.group(1), trip))
                if cm:
                    self.edges.append((current, cm.group(1), trip + 1))
            else:
                for attr in ("calls", "to_apply"):
                    am = re.search(attr + r"=%?([\w\.\-]+)", raw)
                    if am:
                        self.edges.append((current, am.group(1), 1))
                bm = re.search(r"branch_computations=\{([^}]*)\}", raw)
                if bm:
                    for child in bm.group(1).split(","):
                        self.edges.append(
                            (current, child.strip().lstrip("%"), 1)
                        )

    def _propagate(self) -> None:
        self.multiplier: Dict[str, float] = defaultdict(float)
        if not self.entry:
            # Fallback: treat every computation as entry-level.
            for c in self.comp_ops:
                self.multiplier[c] = 1.0
            return
        children = defaultdict(list)
        for parent, child, k in self.edges:
            children[parent].append((child, k))
        stack = [(self.entry, 1.0)]
        seen_guard = 0
        while stack:
            comp, mult = stack.pop()
            self.multiplier[comp] += mult
            seen_guard += 1
            if seen_guard > 100000:
                break  # cyclic safety (should not happen in HLO)
            for child, k in children.get(comp, []):
                stack.append((child, mult * k))

    # -- accounting -----------------------------------------------------------
    def hbm_bytes(self) -> float:
        """Operand+result bytes of top-level ops, weighted by computation
        multipliers. Fusion internals excluded (their computations are
        reached via 'calls' edges — we zero non-collective fusion-callee
        traffic by only counting computations reachable as while bodies
        or entry; see _counts_traffic)."""
        total = 0.0
        for comp, ops in self.comp_ops.items():
            mult = self.multiplier.get(comp, 0.0)
            if mult == 0.0 or not self._counts_traffic(comp):
                continue
            for name, type_str, opcode, args in ops:
                if opcode in _SKIP_OPS:
                    continue
                own = self.value_bytes.get(name, 0)
                operands = self._operand_bytes(args)
                total += mult * (own + operands)
        return total

    def _counts_traffic(self, comp: str) -> bool:
        """Only entry + while bodies/conds execute as sequences of kernels;
        computations referenced via calls/to_apply (fusion internals,
        reducers) run on-chip inside their caller's kernel."""
        if comp == self.entry:
            return True
        kinds = {k for p, c, k in self.edges if c == comp}
        # while edges carry trip>=1 multipliers recorded as ints > 0;
        # call edges recorded with k == 1 as well — disambiguate by parent
        # op: we recorded while children from 'while' lines only. Track:
        return comp in self._while_comps()

    def _while_comps(self):
        if not hasattr(self, "_wc"):
            wc = set()
            for comp, ops in self.comp_ops.items():
                for name, type_str, opcode, args in ops:
                    if opcode == "while":
                        cm = re.search(r"condition=%?([\w\.\-]+)", args)
                        bm = re.search(r"body=%?([\w\.\-]+)", args)
                        if cm:
                            wc.add(cm.group(1))
                        if bm:
                            wc.add(bm.group(1))
            self._wc = wc
        return self._wc

    def _operand_bytes(self, args: str) -> int:
        arg_str = args.split(")")[0]
        total = 0
        for ref in re.finditer(r"%([\w\.\-]+)", arg_str):
            total += self.value_bytes.get(ref.group(1), 0)
        return total

    def dot_flops(self) -> float:
        """TRUE per-device FLOPs from post-SPMD dot shapes, with while
        multipliers. Unlike the jaxpr count (global / n_devices, which
        assumes perfect sharding), this charges replicated compute to
        every device — e.g. attention whose heads cannot shard. Used as
        the roofline compute term; jaxpr flops remain the ideal."""
        total = 0.0
        for comp, ops in self.comp_ops.items():
            mult = self.multiplier.get(comp, 0.0)
            if mult == 0.0:
                continue
            for name, type_str, opcode, args in ops:
                if opcode != "dot":
                    continue
                out_dims = self.value_dims.get(name, [])
                lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", args)
                am = re.match(r"\s*%([\w\.\-]+)", args)
                if lm is None or am is None:
                    continue
                lhs_dims = self.value_dims.get(am.group(1), [])
                k = 1
                for ci in lm.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
                out = 1
                for d in out_dims:
                    out *= d
                total += mult * 2.0 * out * k
        return total

    def collective_bytes(self) -> Dict[str, float]:
        out = {k: 0.0 for k in _COLLECTIVES}
        for comp, ops in self.comp_ops.items():
            mult = self.multiplier.get(comp, 0.0)
            if mult == 0.0:
                continue
            for name, type_str, opcode, args in ops:
                if opcode.endswith("-done"):
                    continue  # async pair: count the -start only
                kind = next(
                    (c for c in _COLLECTIVES if opcode.startswith(c)), None
                )
                if kind is None:
                    continue
                operands = self._operand_bytes(args)
                if operands == 0:
                    operands = self.value_bytes.get(name, 0)
                out[kind] += mult * operands
        return out
