"""Exact FLOP counting by walking the jaxpr (backend-independent).

Scan bodies multiply by their trip count; pjit / remat / custom-vjp
regions recurse. Matmul FLOPs use the 2*B*M*N*K convention from
dot_general dimension numbers; elementwise/reduce FLOPs are ignored
(sub-1% at LM shapes — documented in EXPERIMENTS.md §Roofline
methodology). Counts are GLOBAL (logical program); divide by mesh size
for per-device (assumes FLOPs shard evenly — true for every sharding the
rules engine emits).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    k = math.prod(lhs.shape[i] for i in lc)
    b = math.prod(lhs.shape[i] for i in lb)
    m = math.prod(
        lhs.shape[i]
        for i in range(len(lhs.shape))
        if i not in set(lc) | set(lb)
    )
    n = math.prod(
        rhs.shape[i]
        for i in range(len(rhs.shape))
        if i not in set(rc) | set(rb)
    )
    return 2.0 * b * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    # flops = 2 * out_elems * (kernel spatial+input-feature size)
    dn = eqn.params["dimension_numbers"]
    kshape = rhs.shape
    out_elems = math.prod(out.shape)
    kernel_fanin = math.prod(kshape) / kshape[dn.rhs_spec[0]]
    return 2.0 * out_elems * kernel_fanin


def jaxpr_flops(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn)
        elif name == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif name == "scan":
            body = eqn.params["jaxpr"].jaxpr
            total += eqn.params["length"] * jaxpr_flops(body)
        elif name == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            # Unknown trip count: count once (we never emit raw whiles).
            total += jaxpr_flops(body)
        elif name == "cond":
            branches = eqn.params["branches"]
            total += max(jaxpr_flops(b.jaxpr) for b in branches)
        elif "jaxpr" in eqn.params:
            sub = eqn.params["jaxpr"]
            total += jaxpr_flops(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
        elif "call_jaxpr" in eqn.params:
            sub = eqn.params["call_jaxpr"]
            total += jaxpr_flops(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
    return total


_DTYPE_BYTES = {
    "bool": 1, "int8": 1, "uint8": 1, "int16": 2, "uint16": 2,
    "int32": 4, "uint32": 4, "int64": 8, "uint64": 8,
    "bfloat16": 2, "float16": 2, "float32": 4, "float64": 8,
}


def _aval_bytes(aval) -> float:
    return math.prod(aval.shape) * _DTYPE_BYTES.get(str(aval.dtype), 4)


_TRAFFIC_PRIMS = {
    "dot_general",
    "conv_general_dilated",
    "gather",
    "scatter",
    "scatter-add",
    "scatter_add",
    "dynamic_slice",
    "dynamic_update_slice",
    "sort",
    "cumsum",
}


def _is_attention_internal(aval) -> bool:
    """Attention-block tensors (logits/probs/acc in the chunked schedule)
    are rank-5 (b, kv, g, q_chunk, kv_chunk|d) float32 by construction in
    repro.models.attention. On the TPU target these live in VMEM inside
    the flash/decode Pallas kernels and never touch HBM, so the ideal
    traffic count excludes them. Model weights/activations are rank<=4
    and unaffected; the convention is documented in EXPERIMENTS.md."""
    return len(aval.shape) >= 5 and str(aval.dtype) == "float32"


def jaxpr_bytes(jaxpr) -> float:
    """Ideal-fusion HBM traffic: operand+result bytes of matmuls and
    data-movement ops only (gather/scatter/slice/sort), everything
    elementwise assumed fused into its producers/consumers; attention-
    internal block tensors excluded (VMEM-resident in the Pallas
    kernels — see _is_attention_internal). Scan bodies multiply by trip
    count. This is a LOWER bound on real traffic and the roofline-
    appropriate idealization; repro.roofline.hlo_cost gives the
    (CPU-fusion) upper bound."""
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in _TRAFFIC_PRIMS:
            total += sum(
                _aval_bytes(v.aval)
                for v in eqn.invars
                if hasattr(v, "aval") and not _is_attention_internal(v.aval)
            )
            total += sum(
                _aval_bytes(v.aval)
                for v in eqn.outvars
                if not _is_attention_internal(v.aval)
            )
        elif name == "scan":
            body = eqn.params["jaxpr"].jaxpr
            total += eqn.params["length"] * jaxpr_bytes(body)
        elif name == "while":
            total += jaxpr_bytes(eqn.params["body_jaxpr"].jaxpr)
        elif name == "cond":
            total += max(jaxpr_bytes(b.jaxpr) for b in eqn.params["branches"])
        elif "jaxpr" in eqn.params:
            sub = eqn.params["jaxpr"]
            total += jaxpr_bytes(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
        elif "call_jaxpr" in eqn.params:
            sub = eqn.params["call_jaxpr"]
            total += jaxpr_bytes(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
    return total


def flops_of(fn, *abstract_args) -> float:
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_flops(closed.jaxpr)


def costs_of(fn, *abstract_args):
    """(flops, ideal_bytes) — one trace, both counts."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_flops(closed.jaxpr), jaxpr_bytes(closed.jaxpr)
