"""Sharded, atomic, async checkpointing with reshard-on-load.

Layout on disk:

    <dir>/step_000123.tmp/...      (in-flight write)
    <dir>/step_000123/             (atomically renamed when complete)
        manifest.json              (step, leaf index, shapes, dtypes)
        leaf_00000.npy ...

Guarantees a 1000-node deployment needs:
- **atomicity**: a crash mid-save leaves only a ``.tmp`` dir, which
  restore ignores and the next save garbage-collects — the newest
  *renamed* directory is always a complete checkpoint;
- **async**: ``save`` snapshots device arrays to host (device_get) and
  hands serialization to a background thread, so the train loop stalls
  only for the device->host copy, not the filesystem;
- **reshard-on-load**: ``restore`` takes target shardings and
  ``jax.device_put``s each leaf — loading a 16x16-trained checkpoint
  onto a 2x16x16 mesh (or a degraded elastic mesh) is the same code
  path;
- **retention**: ``keep`` newest checkpoints are preserved.

In a true multi-host deployment each host would write only its
addressable shards (the manifest already records per-leaf metadata to
support that extension); in this single-process container leaves are
gathered before writing.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np


def _leafpaths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()  # one in-flight save at a time
        # Snapshot on the main thread (device -> host).
        leaves = [
            (name, np.asarray(jax.device_get(leaf)))
            for name, leaf in _leafpaths(tree)
        ]

        def _write():
            try:
                tmp = os.path.join(self.directory, f"step_{step:08d}.tmp")
                final = os.path.join(self.directory, f"step_{step:08d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                manifest = {"step": step, "leaves": []}
                for i, (name, arr) in enumerate(leaves):
                    fname = f"leaf_{i:05d}.npy"
                    np.save(os.path.join(tmp, fname), arr)
                    manifest["leaves"].append(
                        {
                            "name": name,
                            "file": fname,
                            "shape": list(arr.shape),
                            "dtype": str(arr.dtype),
                        }
                    )
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
                self._gc()
            except BaseException as e:  # surfaced by wait()
                self._error = e

        if blocking:
            _write()
            self.wait()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))
        # Remove orphaned tmp dirs from crashed saves.
        for d in os.listdir(self.directory):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    # ----- restore ----------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        target: Any,
        shardings: Optional[Any] = None,
    ) -> Any:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching tree of NamedShardings
        for reshard-on-load; None = default placement."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_flat = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat)
        )
        out = []
        for (kp, leaf), shard in zip(flat, shard_flat):
            name = jax.tree_util.keystr(kp)
            entry = by_name[name]
            arr = np.load(os.path.join(path, entry["file"]))
            expected = tuple(leaf.shape)
            if tuple(arr.shape) != expected:
                raise ValueError(
                    f"checkpoint leaf {name} shape {arr.shape} != {expected}"
                )
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(arr))
        return treedef.unflatten(out)
