"""Data model for DeepRT: requests, frames, categories, job instances.

Terminology follows the paper (§3.1):

- A *request* is a periodic stream of frames from one client. Each frame
  carries a relative deadline. Different requests may use different models
  and input shapes.
- A *category* groups frames that may be batched together: same model and
  same input shape (and the same real-time class — non-RT requests are
  never co-batched with RT requests, paper §3.3).
- A *job instance* is one batched execution unit: all frames of one
  category that arrived within one DisBatcher time window.
- A *task instance* is the per-category stream of job instances — a
  non-preemptive multiframe task. It is implicit in this implementation
  (the DisBatcher holds per-category state).

Time is in float seconds throughout. In the TPU adaptation a "frame" is one
inference step (a prefill of S tokens or a decode step); the shape key
identifies the padded shape bucket the step compiles to.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional, Tuple

_request_ids = itertools.count()
_job_ids = itertools.count()


@dataclass(frozen=True, order=True)
class Category:
    """A batchable class of frames: same model, same shape, same RT class."""

    model_id: str
    shape_key: Tuple[int, ...]  # e.g. (3, 224, 224) or (seq_len,) for LM steps
    realtime: bool = True

    def __str__(self) -> str:
        rt = "rt" if self.realtime else "nrt"
        return f"{self.model_id}/{'x'.join(map(str, self.shape_key))}/{rt}"


@dataclass
class Request:
    """A client request: a finite periodic stream of frames (paper §3.1).

    Frame i arrives at ``start_time + i * period`` and must complete by
    arrival + ``relative_deadline``.
    """

    category: Category
    period: float
    relative_deadline: float
    n_frames: int
    start_time: float = 0.0
    request_id: int = field(default_factory=lambda: next(_request_ids))

    def __post_init__(self) -> None:
        if self.period <= 0:
            raise ValueError(f"period must be positive, got {self.period}")
        if self.relative_deadline <= 0:
            raise ValueError(
                f"relative_deadline must be positive, got {self.relative_deadline}"
            )
        if self.n_frames <= 0:
            raise ValueError(f"n_frames must be positive, got {self.n_frames}")

    def frame_arrival(self, i: int) -> float:
        return self.start_time + i * self.period

    @property
    def end_time(self) -> float:
        """Arrival time of the last frame."""
        return self.frame_arrival(self.n_frames - 1)


@dataclass
class Frame:
    """One unit of client data awaiting inference.

    ``payload`` carries the frame's real input bytes (int32 token array
    for LM categories: ``(seq,)`` for prefill frames, scalar for decode
    frames); ``None`` marks a synthetic frame (simulation traces,
    admission pseudo-frames) whose staged input is zeros. ``ingest_time``
    is when the bytes entered the system at the gateway — it equals
    ``arrival_time`` unless the gateway deferred delivery; end-to-end
    latency is measured from it.
    """

    request_id: int
    category: Category
    index: int
    arrival_time: float
    deadline: float  # absolute
    payload: Optional[object] = None  # np.ndarray when ingested
    ingest_time: Optional[float] = None
    # Filled in on completion:
    completion_time: Optional[float] = None

    @property
    def latency(self) -> Optional[float]:
        if self.completion_time is None:
            return None
        return self.completion_time - self.arrival_time

    @property
    def e2e_latency(self) -> Optional[float]:
        """Arrival-at-gateway -> completion (== ``latency`` when the
        frame was never queued upstream of the scheduler)."""
        if self.completion_time is None:
            return None
        t0 = self.ingest_time if self.ingest_time is not None else self.arrival_time
        return self.completion_time - t0

    @property
    def missed(self) -> Optional[bool]:
        if self.completion_time is None:
            return None
        return self.completion_time > self.deadline + 1e-12

    @property
    def overdue(self) -> float:
        """Positive overdue time, 0 if met (valid once completed)."""
        if self.completion_time is None:
            return 0.0
        return max(0.0, self.completion_time - self.deadline)


@dataclass
class JobInstance:
    """A batched execution unit produced by the DisBatcher.

    ``relative_deadline`` equals the time-window length used to produce it
    (paper §3.2); ``deadline`` is absolute: release_time + relative_deadline.
    ``shape_key`` may differ from ``category.shape_key`` when the Adaptation
    Module has shrunk the category (paper §4.4).
    """

    category: Category
    frames: list  # list[Frame]
    release_time: float
    relative_deadline: float
    shape_key: Tuple[int, ...]
    job_id: int = field(default_factory=lambda: next(_job_ids))
    # Execution bookkeeping:
    start_time: Optional[float] = None
    completion_time: Optional[float] = None
    profiled_wcet: Optional[float] = None

    @property
    def deadline(self) -> float:
        return self.release_time + self.relative_deadline

    @property
    def batch_size(self) -> int:
        return len(self.frames)

    def __lt__(self, other: "JobInstance") -> bool:
        # Priority-queue ordering: EDF on absolute deadline, job id tiebreak.
        return (self.deadline, self.job_id) < (other.deadline, other.job_id)


class ChunkJob:
    """An ordered run of same-category decode job instances fused into ONE
    device dispatch (a k-step scanned decode program, ``serving/engine.py``).

    Built by the EDF worker at dispatch time (never queued): the worker
    pops the earliest-deadline decode job plus the next k-1 queued jobs of
    the same category — consecutive in deadline order, so fusing them
    reorders nothing — and submits the chunk as a single unit whose
    profiled WCET is the k-step family value from the ProfileTable. Inner
    jobs keep their own deadlines and frames; completion fans back out to
    each of them in order.
    """

    __slots__ = (
        "jobs", "start_time", "completion_time", "profiled_wcet", "_queued_wcet"
    )

    def __init__(self, jobs: list):
        if not jobs:
            raise ValueError("a chunk needs at least one job")
        head = jobs[0]
        for j in jobs[1:]:
            if j.category is not head.category and j.category != head.category:
                raise ValueError("chunked jobs must share one category")
        self.jobs = list(jobs)
        self.start_time: Optional[float] = None
        self.completion_time: Optional[float] = None
        self.profiled_wcet: Optional[float] = None
        self._queued_wcet = 0.0

    @property
    def k(self) -> int:
        """Chunk depth: decode steps executed by the single dispatch."""
        return len(self.jobs)

    @property
    def category(self) -> Category:
        return self.jobs[0].category

    @property
    def shape_key(self) -> Tuple[int, ...]:
        return self.jobs[0].shape_key

    @property
    def job_id(self) -> int:
        return self.jobs[0].job_id

    @property
    def release_time(self) -> float:
        return self.jobs[0].release_time

    @property
    def deadline(self) -> float:
        """The head job's deadline — the earliest in the run (EDF order)."""
        return self.jobs[0].deadline

    @property
    def batch_size(self) -> int:
        """Widest per-step frame count (the arena executes max_slots rows
        regardless; this feeds bucket-accounting fallbacks only)."""
        return max(j.batch_size for j in self.jobs)

    @property
    def frames(self) -> list:
        """All frames across the chunk's steps, in execution order."""
        return [f for j in self.jobs for f in j.frames]


@dataclass
class PseudoJob:
    """A virtual job instance used by admission control (paper §4.2, step 2).

    Only the scheduling-relevant fields: release, execution estimate,
    relative deadline, and the frames' own deadlines for latency prediction.
    """

    category: Category
    release_time: float
    exec_time: float
    relative_deadline: float
    n_frames: int
    # (request_id, frame_index, arrival, abs deadline) for accuracy eval:
    frame_refs: tuple = ()

    @property
    def deadline(self) -> float:
        return self.release_time + self.relative_deadline
