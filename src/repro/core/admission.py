"""Two-phase Admission Control Module (paper §4.2).

Phase 1 — utilization filter. For each category g the average number of
frames per window is ``n_g = floor(sum_m W_g / p_m)``; the estimated task
utilization is ``Ũ_s = E^{n_g} / W_g``. A pending request is rejected
outright if ``sum_g Ũ_s > 1``. This deliberately *underestimates* load
(floor, averages, optimistic interpolated lookups), so Phase 1 only
short-circuits obvious overload — admission safety rests entirely on
Phase 2, which always runs for Phase-1-passing requests.

Phase 2 — exact analysis in three steps:
  1. system-state recording: waiting frames per category, queued job
     instances, window epochs, remaining frames per request, device
     busy-until;
  2. pseudo-job generation: replay the DisBatcher forward in virtual time,
     assigning every future frame to its batching joint and looking up the
     profiled WCET per batch — linear in the number of frames;
  3. the EDF imitator (paper Algorithm 1): replay non-idling EDF over the
     pseudo jobs, advancing a clock by profiled WCETs and checking every
     virtual completion against its deadline.

Bit-exactness: joint times come from ``disbatcher.joint_time`` with the
same float operations the live DisBatcher uses, and all boundary
comparisons are exact — the imitator's schedule IS the live schedule when
execution times equal WCETs and early-flush is off (strict mode). The
imitator also returns per-frame predicted completion times, which
benchmarks/imitator_accuracy.py compares against real executions (Fig 8).

Conservatisms (all in the safe direction — no false admits):
- the imitator charges full profiled WCET; real executions at or below
  WCET plus the guarded early flush can only complete frames earlier
  (up to a bounded EDF-order perturbation, see scheduler.DeepRT);
- when the pending request shrinks a category window, the shrunk window is
  used for the whole horizon even though it could grow back after the
  tight request departs.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.disbatcher import DisBatcher, joint_time
from repro.core.profiler import ProfileTable
from repro.core.request import Category, PseudoJob, Request


@dataclass
class CategorySnapshot:
    """State of one category at admission time (Phase 2, step 1)."""

    category: Category
    window: float
    epoch_t0: float
    next_index: int
    # (arrival, abs_deadline, request_id, frame_index) of frames already
    # waiting in the DisBatcher queue:
    waiting: List[Tuple[float, float, int, int]] = field(default_factory=list)
    # Requests with frames still to arrive (or arriving now):
    requests: List[Request] = field(default_factory=list)
    shape_key: Optional[Tuple[int, ...]] = None  # adaptation override

    @property
    def effective_shape(self) -> Tuple[int, ...]:
        return self.shape_key or self.category.shape_key

    def joint(self, i: int) -> float:
        return joint_time(self.epoch_t0, i, self.window)


@dataclass
class SystemState:
    now: float
    device_free_at: float
    # Already-batched jobs waiting in the deadline queue:
    queued_jobs: List[PseudoJob] = field(default_factory=list)
    categories: List[CategorySnapshot] = field(default_factory=list)


@dataclass
class AdmissionResult:
    admitted: bool
    phase: int  # 0 (bypassed), 1 or 2 (which phase decided)
    utilization: float
    reason: str = ""
    # (request_id, frame_index) -> predicted completion time:
    predicted_completions: Dict[Tuple[int, int], float] = field(default_factory=dict)
    n_pseudo_jobs: int = 0


class AdmissionControl:
    def __init__(self, table: ProfileTable):
        self.table = table
        # Verdict counters for the telemetry snapshot: which phase
        # turned requests away matters for capacity planning (phase-1 =
        # raw utilization, phase-2 = deadline packing).
        self.stats = {"admitted": 0, "rejected_phase1": 0,
                      "rejected_phase2": 0}

    # ------------------------------------------------------------------
    # Phase 1: utilization-based filter.
    # ------------------------------------------------------------------
    def phase1_utilization(self, categories: List[CategorySnapshot]) -> float:
        total = 0.0
        for snap in categories:
            if not snap.requests:
                continue
            w = snap.window
            n_g = math.floor(sum(w / r.period for r in snap.requests))
            if n_g <= 0:
                continue
            e = self.table.wcet_optimistic(
                snap.category.model_id, snap.effective_shape, n_g
            )
            total += e / w
        return total

    # ------------------------------------------------------------------
    # Phase 2, step 2: pseudo-job generation (linear in #frames).
    # ------------------------------------------------------------------
    def generate_pseudo_jobs(self, state: SystemState) -> List[PseudoJob]:
        jobs: List[PseudoJob] = list(state.queued_jobs)
        for snap in state.categories:
            jobs.extend(self._category_jobs(state.now, snap))
        # Stable sort: categories are iterated in creation order, which is
        # also the live tie order for joints firing at the same instant.
        jobs.sort(key=lambda j: (j.release_time, j.deadline))
        return jobs

    def _category_jobs(self, now: float, snap: CategorySnapshot) -> List[PseudoJob]:
        w = snap.window
        base = snap.next_index
        buckets: Dict[int, List[Tuple[float, float, int, int]]] = {}

        def joint_index(arrival: float) -> int:
            """Smallest i >= base with joint(i) >= arrival, computed with
            the exact joint_time expression (estimate, then fix up)."""
            if arrival <= snap.joint(base):
                return base
            i = base + max(1, int(math.ceil((arrival - snap.joint(base)) / w)) )
            while i > base and snap.joint(i - 1) >= arrival:
                i -= 1
            while snap.joint(i) < arrival:
                i += 1
            return i

        seen: Set[Tuple[int, int]] = set()
        for rec in snap.waiting:
            buckets.setdefault(base, []).append(rec)
            seen.add((rec[2], rec[3]))
        for r in snap.requests:
            for i in range(r.n_frames):
                a = r.frame_arrival(i)
                if a < now or (r.request_id, i) in seen:
                    continue
                k = joint_index(a)
                buckets.setdefault(k, []).append(
                    (a, a + r.relative_deadline, r.request_id, i)
                )
        out = []
        for k, recs in sorted(buckets.items()):
            release = snap.joint(k)
            exec_time = self.table.wcet(
                snap.category.model_id, snap.effective_shape, len(recs)
            )
            out.append(
                PseudoJob(
                    category=snap.category,
                    release_time=release,
                    exec_time=exec_time,
                    relative_deadline=w,
                    n_frames=len(recs),
                    frame_refs=tuple(recs),
                )
            )
        return out

    # ------------------------------------------------------------------
    # Phase 2, step 3: the EDF imitator (paper Algorithm 1).
    # ------------------------------------------------------------------
    @staticmethod
    def edf_imitator(
        jobs: List[PseudoJob], start_time: float
    ) -> Tuple[bool, Dict[Tuple[int, int], float]]:
        """Replay non-idling EDF; return (schedulable, frame predictions).

        ``jobs`` must be sorted by release time. ``start_time`` is the
        moment the device is next free (now, or the in-flight job's
        completion).
        """
        predictions: Dict[Tuple[int, int], float] = {}
        q: List[Tuple[float, int, PseudoJob]] = []  # (deadline, seq, job)
        seq = 0
        t = start_time
        i = 0
        n = len(jobs)
        while q or i < n:
            if not q:
                # Idle until the next release (Algorithm 1, lines 3-5).
                t = max(t, jobs[i].release_time)
                heapq.heappush(q, (jobs[i].deadline, seq, jobs[i]))
                seq += 1
                i += 1
                # Admit everything else released by then.
                while i < n and jobs[i].release_time <= t:
                    heapq.heappush(q, (jobs[i].deadline, seq, jobs[i]))
                    seq += 1
                    i += 1
                continue
            _, _, k = heapq.heappop(q)
            t += k.exec_time
            if t > k.deadline:
                return False, predictions
            for arrival, _dl, rid, fidx in k.frame_refs:
                predictions[(rid, fidx)] = t
            while i < n and jobs[i].release_time <= t:
                heapq.heappush(q, (jobs[i].deadline, seq, jobs[i]))
                seq += 1
                i += 1
        return True, predictions

    # ------------------------------------------------------------------
    # Full admission decision.
    # ------------------------------------------------------------------
    def admit(self, state: SystemState, utilization_bound: float = 1.0) -> AdmissionResult:
        """Run Phase 1 then Phase 2 over a hypothetical state that already
        includes the pending request (the caller builds ``state`` with the
        pending request folded into its category snapshot)."""
        u = self.phase1_utilization(state.categories)
        if u > utilization_bound + 1e-9:
            self.stats["rejected_phase1"] += 1
            return AdmissionResult(
                admitted=False,
                phase=1,
                utilization=u,
                reason=f"phase-1 utilization {u:.3f} > {utilization_bound}",
            )
        jobs = self.generate_pseudo_jobs(state)
        ok, preds = self.edf_imitator(jobs, start_time=max(state.now, state.device_free_at))
        self.stats["admitted" if ok else "rejected_phase2"] += 1
        return AdmissionResult(
            admitted=ok,
            phase=2,
            utilization=u,
            reason="" if ok else "phase-2 EDF imitator found a deadline miss",
            predicted_completions=preds,
            n_pseudo_jobs=len(jobs),
        )


def phase1_from_scheduler(sched) -> float:
    """Current Phase-1 utilization of a live scheduler (duck-typed: any
    object with ``loop``/``disbatcher``/``worker``/``device``/``table``/
    ``admission`` — i.e. a ``DeepRT``). The cluster placement loop ranks
    slices by this value; it is also what the per-slice utilization-bound
    tests read, so it must see EXACTLY the state ``submit_request``'s
    admission test would see (same snapshot code, no pending fold-in).
    """
    state = snapshot_from_scheduler(
        now=sched.loop.now,
        disbatcher=sched.disbatcher,
        queued_jobs=sched.worker.queue.snapshot(),
        device_free_at=sched.device.busy_until or sched.loop.now,
        table=sched.table,
    )
    return sched.admission.phase1_utilization(state.categories)


def snapshot_from_scheduler(
    now: float,
    disbatcher: DisBatcher,
    queued_jobs,
    device_free_at: float,
    table: ProfileTable,
    pending: Optional[Request] = None,
) -> SystemState:
    """Phase 2 step 1: record live scheduler state, optionally folding a
    pending request into the hypothesis.

    The fold-in replicates DisBatcher.add_request's epoch arithmetic
    exactly so the hypothetical joint schedule is bit-identical to what
    the live DisBatcher would do after admission.
    """
    snaps: Dict[Category, CategorySnapshot] = {}
    for cat in disbatcher.categories():
        st = disbatcher.state_of(cat)
        reqs = [r for r in st.requests.values() if r.end_time >= now]
        if st.next_index is None:
            # Retired timer: a pending same-category request would restart
            # a fresh epoch; without one there is nothing to simulate.
            if pending is None or pending.category != cat:
                if st.frames:
                    # Defensive: retired with waiting frames cannot happen
                    # (_joint only retires when the queue is empty).
                    raise AssertionError("retired category with waiting frames")
                continue
            w = disbatcher.window_for(cat, reqs + [pending])
            snaps[cat] = CategorySnapshot(
                category=cat,
                window=w,
                epoch_t0=now + w,
                next_index=0,
                requests=reqs + [pending],
                shape_key=st.shape_override,
            )
            continue
        snap = CategorySnapshot(
            category=cat,
            window=st.window,
            epoch_t0=st.epoch_t0,
            next_index=st.next_index,
            waiting=[
                (f.arrival_time, f.deadline, f.request_id, f.index)
                for f in st.frames
            ],
            requests=reqs,
            shape_key=st.shape_override,
        )
        snaps[cat] = snap
        if pending is not None and pending.category == cat:
            snap.requests = snap.requests + [pending]
            new_w = disbatcher.window_for(cat, snap.requests)
            if new_w < snap.window:
                cand_new = now + new_w
                j_next = snap.joint(snap.next_index)
                if cand_new < j_next:
                    snap.epoch_t0 = cand_new
                else:
                    snap.epoch_t0 = j_next
                snap.next_index = 0
                snap.window = new_w
    if pending is not None and pending.category not in snaps:
        cat = pending.category
        w = disbatcher.window_for(cat, [pending])
        snaps[cat] = CategorySnapshot(
            category=cat,
            window=w,
            epoch_t0=now + w,
            next_index=0,
            requests=[pending],
        )
    pseudo_queued = []
    for job in queued_jobs:
        exec_time = table.wcet(job.category.model_id, job.shape_key, job.batch_size)
        pseudo_queued.append(
            PseudoJob(
                category=job.category,
                release_time=job.release_time,
                exec_time=exec_time,
                relative_deadline=job.relative_deadline,
                n_frames=job.batch_size,
                frame_refs=tuple(
                    (f.arrival_time, f.deadline, f.request_id, f.index)
                    for f in job.frames
                ),
            )
        )
    return SystemState(
        now=now,
        device_free_at=device_free_at,
        queued_jobs=pseudo_queued,
        categories=list(snaps.values()),
    )
