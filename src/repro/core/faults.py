"""Fault injection and completion watchdog for the shared device contract.

Real edge accelerators throttle, stall, and die mid-step; the paper's
Adaptation Module (§4.4) only reacts *after* a job completes late, so a
hung step is invisible to it forever.  This module supplies both halves
of the fix:

- :class:`FaultyDevice` wraps either device-contract implementation
  (``SequentialDevice`` in virtual time, ``AsyncDevice`` live) and
  injects deterministic, seed-driven faults — completion delay
  (throttling), indefinite stall (hang), transient submit error, and
  permanent death — so failure paths are testable and replayable.
- :class:`CompletionWatchdog` arms a per-submit completion deadline
  (expected WCET × slack, floored by ``min_deadline``) plus a heartbeat
  while a submit is overdue.  It uses only ``loop.schedule / cancel /
  now``, so the *same* code runs under ``EventLoop`` virtual time and
  the live ``WallClock``.

The watchdog reports to a policy callback (the cluster's
``SliceHealthMonitor``); it never decides anything itself.
"""
from __future__ import annotations

import math
import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import telemetry as T


class TransientSubmitError(RuntimeError):
    """A submit that failed without damaging the device; safe to retry."""


class DeviceDeadError(RuntimeError):
    """Submit on a device that has permanently died."""


# Fault kinds.
DELAY = "delay"            # completion lands late (throttled accelerator)
STALL = "stall"            # completion never lands (hung step)
SUBMIT_ERROR = "submit_error"  # submit raises TransientSubmitError once
DEATH = "death"            # current submit stalls AND all future submits die
# Network-shaped completion faults: the device finishes on time but its
# completion SIGNAL misbehaves (a retried RPC ack lands twice; an ack is
# held in a queue and arrives after later jobs' acks).
DUP_COMPLETE = "dup_complete"        # completion callback fires twice
REORDER_COMPLETE = "reorder_complete"  # completion callback arrives late,
                                       # possibly after later jobs' callbacks

FAULT_KINDS = (DELAY, STALL, SUBMIT_ERROR, DEATH, DUP_COMPLETE, REORDER_COMPLETE)


@dataclass(frozen=True)
class FaultSpec:
    """One injected fault, keyed by the device's submit index.

    ``factor``/``extra`` apply to DELAY only: the completion lands at
    ``max(expected * factor, expected + extra)`` after the submit, which
    lets tests express both relative throttling (factor) and absolute
    lateness large enough to cross a watchdog's ``min_deadline`` floor
    (extra) regardless of how small the profiled WCET is.
    """

    kind: str
    at_submit: int
    factor: float = 3.0
    extra: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.at_submit < 0:
            raise ValueError("at_submit must be >= 0")
        if self.kind == DELAY and self.factor < 1.0 and self.extra <= 0.0:
            raise ValueError("a DELAY fault must actually delay (factor >= 1 or extra > 0)")
        if self.kind == REORDER_COMPLETE and self.factor <= 1.0 and self.extra <= 0.0:
            raise ValueError(
                "a REORDER_COMPLETE fault must defer the signal "
                "(factor > 1 or extra > 0)"
            )


class FaultPlan:
    """A deterministic fault schedule: at most one fault per submit index."""

    def __init__(self, specs: Tuple[FaultSpec, ...] = ()) -> None:
        self.by_submit: Dict[int, FaultSpec] = {}
        for spec in specs:
            if spec.at_submit in self.by_submit:
                raise ValueError(f"duplicate fault at submit index {spec.at_submit}")
            self.by_submit[spec.at_submit] = spec

    @property
    def specs(self) -> List[FaultSpec]:
        return [self.by_submit[i] for i in sorted(self.by_submit)]

    def for_submit(self, index: int) -> Optional[FaultSpec]:
        return self.by_submit.get(index)

    def __len__(self) -> int:
        return len(self.by_submit)

    @classmethod
    def from_seed(
        cls,
        seed: int,
        n_submits: int,
        p_delay: float = 0.0,
        p_stall: float = 0.0,
        p_error: float = 0.0,
        p_death: float = 0.0,
        p_dup_complete: float = 0.0,
        p_reorder_complete: float = 0.0,
        delay_factor: Tuple[float, float] = (2.0, 6.0),
        delay_extra: Tuple[float, float] = (0.0, 0.0),
    ) -> "FaultPlan":
        """Draw an independent fault (or none) for each submit index.

        Same seed and parameters -> identical plan, so any failure found
        under a random plan is replayable from its seed alone.  The
        per-index draw count is branch-independent, so plans with the
        same seed agree on their common prefix regardless of length.
        """
        total = p_delay + p_stall + p_error + p_death
        total += p_dup_complete + p_reorder_complete
        if total > 1.0:
            raise ValueError("fault probabilities must sum to <= 1")
        rng = random.Random(seed)
        specs = []
        for i in range(n_submits):
            r = rng.random()
            factor = rng.uniform(*delay_factor)
            extra = rng.uniform(*delay_extra)
            if r < p_delay:
                specs.append(FaultSpec(DELAY, i, factor=factor, extra=extra))
            elif r < p_delay + p_stall:
                specs.append(FaultSpec(STALL, i))
            elif r < p_delay + p_stall + p_error:
                specs.append(FaultSpec(SUBMIT_ERROR, i))
            elif r < p_delay + p_stall + p_error + p_death:
                specs.append(FaultSpec(DEATH, i))
            elif r < p_delay + p_stall + p_error + p_death + p_dup_complete:
                specs.append(FaultSpec(DUP_COMPLETE, i))
            elif r < total:
                specs.append(
                    FaultSpec(REORDER_COMPLETE, i,
                              factor=max(factor, 1.0 + 1e-9), extra=extra)
                )
        return cls(tuple(specs))


@dataclass(frozen=True)
class WatchdogConfig:
    """Knobs for per-submit completion deadlines and slice health policy.

    A submit's completion deadline is ``max(expected * slack,
    min_deadline)``; a completion later than that is a *late signal*, as
    is every heartbeat that fires while the submit is still outstanding.
    A submit outstanding past ``hang_slack / slack`` times its deadline
    is declared *hung* (immediate quarantine — a hang can never produce
    a late completion to count).  ``min_deadline`` floors the deadline
    in wall-clock terms so millisecond-scale WCETs on a busy CI host do
    not false-positive on scheduler jitter.
    """

    slack: float = 4.0
    hang_slack: float = 12.0
    heartbeat: Optional[float] = None  # None: re-check every deadline interval
    min_deadline: float = 0.0
    suspect_after: int = 2      # consecutive late signals: healthy -> suspect
    quarantine_after: int = 6   # consecutive late signals: suspect -> quarantined
    recover_after: int = 3      # consecutive clean completions: suspect -> healthy
    sample_window: int = 64     # (expected, actual) samples retained per slice
    reprofile_samples: int = 8  # recent samples consulted on suspect entry
    reprofile_quantile: float = 0.9

    def __post_init__(self) -> None:
        if self.slack <= 1.0:
            raise ValueError("slack must be > 1 (a deadline at the WCET itself is all-late)")
        if self.hang_slack <= self.slack:
            raise ValueError("hang_slack must exceed slack")
        for name in ("suspect_after", "quarantine_after", "recover_after"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1")
        if not 0.0 < self.reprofile_quantile <= 1.0:
            raise ValueError("reprofile_quantile must be in (0, 1]")

    def deadline_for(self, expected: float) -> float:
        return max(expected * self.slack, self.min_deadline)

    def hang_after(self, expected: float) -> float:
        return self.deadline_for(expected) * (self.hang_slack / self.slack)


class CompletionWatchdog:
    """Per-device completion deadline + heartbeat, loop-generic.

    The owning device calls :meth:`started` on submit and
    :meth:`completed` when the completion lands (both on the loop
    thread).  While a submit is outstanding past its deadline,
    ``on_overdue(job, expected, elapsed)`` fires on every heartbeat
    until the job completes or the watchdog is closed (quarantining a
    slice closes its device, which closes the watchdog).
    """

    def __init__(self, loop, config: WatchdogConfig, on_overdue: Callable) -> None:
        self.loop = loop
        self.config = config
        self.on_overdue = on_overdue
        self.overdue_events = 0
        # Frame-lifecycle tracer (core/telemetry.py) for standalone
        # (non-cluster) watchdogs; the cluster lane emits via its
        # SliceHealthMonitor instead, which knows the slice name.
        self.tracer = None
        self.tracer_tag: Optional[str] = None
        self._token = 0
        self._outstanding: Optional[Tuple[int, object, float, float]] = None
        self._eid = None
        self._closed = False

    def started(self, job, expected: float) -> None:
        if self._closed:
            return
        if self._outstanding is not None:
            raise RuntimeError(
                "CompletionWatchdog: overlapping submits on a sequential device"
            )
        self._token += 1
        start = self.loop.now
        self._outstanding = (self._token, job, expected, start)
        self._arm(self._token, start + self.config.deadline_for(expected))

    def completed(self) -> None:
        self._outstanding = None
        if self._eid is not None:
            self.loop.cancel(self._eid)
            self._eid = None

    def close(self) -> None:
        self._closed = True
        self.completed()

    def _arm(self, token: int, when: float) -> None:
        self._eid = self.loop.schedule(
            max(when, self.loop.now),
            lambda: self._check(token),
            priority=getattr(self.loop, "PRIO_COMPLETE", 0),
        )

    def _check(self, token: int) -> None:
        self._eid = None
        out = self._outstanding
        if self._closed or out is None or out[0] != token:
            return
        _, job, expected, start = out
        elapsed = self.loop.now - start
        self.overdue_events += 1
        if self.tracer is not None:
            self.tracer.emit(
                T.WATCHDOG_OVERDUE, self.loop.now, where=self.tracer_tag,
                meta={"expected": expected, "elapsed": elapsed})
        self.on_overdue(job, expected, elapsed)
        # The overdue handler may have quarantined the slice (closing us)
        # by the time it returns; never re-arm in that case.
        if self._closed or self._outstanding is None or self._outstanding[0] != token:
            return
        beat = self.config.heartbeat
        if beat is None:
            beat = self.config.deadline_for(expected)
        self._arm(token, self.loop.now + beat)


class _WedgedHandle:
    """A dispatch handle whose ``wait()`` blocks until released.

    Handed to ``AsyncDevice``'s dispatch path on an injected STALL/DEATH:
    the waiter thread wedges inside ``wait()`` exactly as it would on a
    hung ``block_until_ready``, which is what the close-with-timeout
    path and the watchdog must survive.
    """

    def __init__(self, release: threading.Event) -> None:
        self._release = release

    def wait(self):
        self._release.wait()
        return None


class _ThrottledHandle:
    """Delays an underlying handle's completion to a fixed instant."""

    def __init__(self, inner, clock: Callable[[], float], until: float) -> None:
        self._inner = inner
        self._clock = clock
        self._until = until

    def wait(self):
        result = self._inner.wait() if self._inner is not None else None
        remaining = self._until - self._clock()
        if remaining > 0:
            time.sleep(remaining)
        return result


class FaultyDevice:
    """Deterministic fault injection behind the shared device contract.

    Wraps either contract implementation:

    - live ``AsyncDevice`` (detected by its ``dispatch_fn`` attribute):
      DELAY/STALL/DEATH inject at the dispatch-handle layer, so the
      inner device's waiter thread, watchdog, and hold/release
      accounting see exactly what a throttled or hung accelerator does;
    - simulated ``SequentialDevice``: DELAY inflates the completion
      event, STALL/DEATH never schedule one.  The optional ``watchdog``
      and ``on_measured`` hooks mirror what ``AsyncDevice`` provides
      natively, so the health machinery runs identically in sim.

    DEATH stalls the current submit and additionally marks the device
    dead: every later submit raises :class:`DeviceDeadError` and
    ``idle`` stays False, so an EDF worker can never dispatch to it
    again.  The device is *not* closed — detection is the watchdog's
    job, exactly as for a real dying accelerator.
    """

    def __init__(
        self,
        inner,
        plan: FaultPlan,
        watchdog: Optional[CompletionWatchdog] = None,
        on_measured: Optional[Callable[[float, float], None]] = None,
        on_submit_error: Optional[Callable[[], None]] = None,
    ) -> None:
        self.inner = inner
        self.plan = plan
        self.loop = inner.loop
        self.watchdog = watchdog
        self.on_measured = on_measured
        self.on_submit_error = on_submit_error
        self.is_live = hasattr(inner, "dispatch_fn")
        self.submits = 0
        self.injected: List[Tuple[int, str, float]] = []  # (index, kind, t)
        self._dead = False
        self._stalled = False
        self._stall_until: Optional[float] = None
        self._wedge = threading.Event()  # released on close: wedged waiters drain

    # ------------------------------------------------------------------
    # Device contract
    # ------------------------------------------------------------------
    @property
    def idle(self) -> bool:
        if self._dead or self._stalled:
            return False
        return self.inner.idle

    @property
    def busy_until(self) -> Optional[float]:
        if self._stalled:
            return self._stall_until
        return self.inner.busy_until

    @property
    def closed(self) -> bool:
        return self.inner.closed

    @property
    def on_idle(self):
        return self.inner.on_idle

    @on_idle.setter
    def on_idle(self, fn) -> None:
        # DeepRT assigns device.on_idle after construction; a plain
        # attribute set here would shadow the inner device's callback.
        self.inner.on_idle = fn

    def submit(self, job, exec_time: float, on_complete, job_bytes: float = 0.0) -> None:
        if self._dead:
            raise DeviceDeadError(f"device died at submit {self._death_index()}; cannot run {job!r}")
        index = self.submits
        self.submits += 1
        spec = self.plan.for_submit(index)
        if spec is None:
            self._submit_clean(job, exec_time, on_complete, job_bytes)
            return
        self.injected.append((index, spec.kind, self.loop.now))
        if spec.kind == DUP_COMPLETE:
            self._submit_clean(job, exec_time, self._duplicated(on_complete), job_bytes)
            return
        if spec.kind == REORDER_COMPLETE:
            defer = max(exec_time * (spec.factor - 1.0), spec.extra)
            self._submit_clean(job, exec_time, self._deferred(on_complete, defer), job_bytes)
            return
        if spec.kind == SUBMIT_ERROR:
            if self.on_submit_error is not None:
                self.on_submit_error()
            raise TransientSubmitError(f"injected submit fault at index {index}")
        if spec.kind == DEATH:
            self._dead = True
        if spec.kind in (STALL, DEATH):
            self._begin_stall(job, exec_time, on_complete, job_bytes)
            return
        self._submit_delayed(job, exec_time, on_complete, job_bytes, spec)

    def close(self) -> None:
        if self.watchdog is not None:
            self.watchdog.close()
        self.inner.close()
        # Drain any waiter wedged on an injected stall into the (now
        # closed) inner device, where its completion is swallowed.
        self._wedge.set()

    def __getattr__(self, name):
        inner = self.__dict__.get("inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)

    # ------------------------------------------------------------------
    # Injection mechanics
    # ------------------------------------------------------------------
    def _death_index(self) -> int:
        for index, kind, _t in self.injected:
            if kind == DEATH:
                return index
        return -1

    def _duplicated(self, on_complete):
        """DUP_COMPLETE: the signal lands twice — once on time, once
        again immediately after (a retried ack).  The device itself runs
        the job once; only the callback repeats, so the consumer's
        idempotency (EDF's completed-job guard) is what is under test."""
        def wrapped(job, t) -> None:
            on_complete(job, t)
            def again() -> None:
                if not self.closed:
                    on_complete(job, t)
            self.loop.schedule(
                self.loop.now, again,
                priority=getattr(self.loop, "PRIO_COMPLETE", 0),
            )
        return wrapped

    def _deferred(self, on_complete, defer: float):
        """REORDER_COMPLETE: the device frees on time (later jobs run and
        complete), but THIS job's completion signal is held for ``defer``
        seconds — it can arrive after later jobs' signals."""
        def wrapped(job, t) -> None:
            def late() -> None:
                if not self.closed:
                    on_complete(job, t)
            self.loop.schedule(
                self.loop.now + defer, late,
                priority=getattr(self.loop, "PRIO_COMPLETE", 0),
            )
        return wrapped

    def _submit_clean(self, job, exec_time, on_complete, job_bytes) -> None:
        if self.is_live:
            self.inner.submit(job, exec_time, on_complete, job_bytes=job_bytes)
            return
        self._sim_submit(job, exec_time, exec_time, on_complete, job_bytes)

    def _submit_delayed(self, job, exec_time, on_complete, job_bytes, spec: FaultSpec) -> None:
        effective = max(exec_time * spec.factor, exec_time + spec.extra)
        if self.is_live:
            inner_dispatch = self.inner.dispatch_fn
            until = self.loop.now + effective
            self.inner.dispatch_fn = lambda j: _ThrottledHandle(
                inner_dispatch(j), lambda: self.loop.now, until
            )
            try:
                self.inner.submit(job, exec_time, on_complete, job_bytes=job_bytes)
            finally:
                self.inner.dispatch_fn = inner_dispatch
            return
        self._sim_submit(job, exec_time, effective, on_complete, job_bytes)

    def _begin_stall(self, job, exec_time, on_complete, job_bytes) -> None:
        if self.is_live:
            # Wedge the real waiter thread: this submit's handle never
            # resolves, the inner device's hold on the loop stays up
            # until close() releases it, and the inner watchdog sees a
            # genuinely missing completion.
            inner_dispatch = self.inner.dispatch_fn
            self.inner.dispatch_fn = lambda j: _WedgedHandle(self._wedge)
            try:
                self.inner.submit(job, exec_time, on_complete, job_bytes=job_bytes)
            finally:
                self.inner.dispatch_fn = inner_dispatch
            return
        # Sim: the device goes busy forever without touching the inner
        # device; only the watchdog can notice.
        self._stalled = True
        self._stall_until = math.inf
        if self.watchdog is not None:
            self.watchdog.started(job, exec_time)

    def _sim_submit(self, job, expected, effective, on_complete, job_bytes) -> None:
        if self.watchdog is not None:
            self.watchdog.started(job, expected)
        start = self.loop.now

        def _measured(j, t) -> None:
            if self.watchdog is not None:
                self.watchdog.completed()
            if self.on_measured is not None:
                self.on_measured(expected, t - start)
            if self.closed:
                # This very measurement was the late signal that
                # quarantined the slice: fail_slice already reconciled
                # the job's frames as lost — reporting the completion
                # now would double-count them.
                return
            on_complete(j, t)

        self.inner.submit(job, effective, _measured, job_bytes=job_bytes)
