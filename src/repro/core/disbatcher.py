"""DisBatcher: deadline-centric time-window batching (paper §3.2).

Frames of the same category arriving within one time window are batched at
the window joint into a single job instance whose relative deadline equals
the window length. Window length per category (paper Theorem 1):

    W_g = 1/2 * min_{m in M_g} d_m^g

With at least two window joints between any frame's arrival and its
deadline, the job instance's deadline lower-bounds every member frame's
deadline, so EDF-schedulability of job instances implies no frame misses.

Bit-exact joint arithmetic
--------------------------
The Phase-2 admission imitator must replay this machinery EXACTLY — an
epsilon disagreement about which window a boundary frame falls into
changes a job's batch (and hence its WCET and every later completion
time). Joints are therefore *epoch-indexed*: an epoch is (t0, W), with
joints at ``joint_time(t0, i, W) = t0 + i * W`` — never accumulated.
Both the live DisBatcher and the admission module compute joints through
the same ``joint_time`` helper with the same float operations, and all
boundary comparisons are exact (frames arriving exactly at a joint join
the window closing at that joint, enforced by event-loop priorities).
A window shrink starts a new epoch.

Implemented details from the paper:
- per-category recurrent countdown timers (here: event-loop timers);
- timer interval shrinks immediately when a newly admitted request has a
  smaller relative deadline (§4.3) — the pending joint is pulled in if the
  new window length would place it earlier, never pushed out;
- the early-flush optimization (§4.3), with a safety guard (see
  ``flush_early``);
- non-RT categories use a large window and are never co-batched with RT
  frames (§3.3);
- adaptation hook (§4.4): shape override for future job instances.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import telemetry as T
from repro.core.request import Category, Frame, JobInstance, Request

WINDOW_FRACTION = 0.5  # Theorem 1: half of the smallest relative deadline.
NONRT_WINDOW = 10.0  # seconds; "a large time window" for non-RT requests.


def joint_time(epoch_t0: float, index: int, window: float) -> float:
    """THE joint-time expression. Live scheduling and admission analysis
    must both call this so boundary comparisons are bit-exact."""
    return epoch_t0 + index * window


@dataclass
class _CategoryState:
    window: float
    epoch_t0: float  # joints at epoch_t0 + i*window for i >= next_index
    next_index: Optional[int]  # None = timer retired
    frames: List[Frame] = field(default_factory=list)
    requests: Dict[int, Request] = field(default_factory=dict)
    timer_event: Optional[int] = None
    shape_override: Optional[Tuple[int, ...]] = None

    @property
    def next_joint(self) -> Optional[float]:
        if self.next_index is None:
            return None
        return joint_time(self.epoch_t0, self.next_index, self.window)


class DisBatcher:
    """Transforms per-frame arrivals into batched job instances.

    ``emit`` receives each new JobInstance (the deadline queue push).
    """

    def __init__(self, loop, emit: Callable[[JobInstance], None]):
        self.loop = loop
        self.emit = emit
        self._cats: Dict[Category, _CategoryState] = {}
        # Frame-lifecycle tracer (core/telemetry.py); None = off.
        self.tracer = None
        self.tracer_tag: Optional[str] = None

    # ----- request lifecycle -------------------------------------------
    def window_for(self, category: Category, requests: List[Request]) -> float:
        if not category.realtime:
            return NONRT_WINDOW
        return WINDOW_FRACTION * min(r.relative_deadline for r in requests)

    def add_request(self, request: Request) -> None:
        cat = request.category
        st = self._cats.get(cat)
        now = self.loop.now
        if st is None:
            w = self.window_for(cat, [request])
            # Epoch starts so the first joint is exactly now + w.
            st = _CategoryState(window=w, epoch_t0=now + w, next_index=0)
            st.requests[request.request_id] = request
            self._cats[cat] = st
            self._arm_timer(cat)
            return
        st.requests[request.request_id] = request
        live = [r for r in st.requests.values() if r.end_time >= now]
        new_w = self.window_for(cat, live or [request])
        if st.next_index is None:
            # Timer retired (previous requests exhausted): fresh epoch.
            st.window = new_w
            st.epoch_t0 = now + new_w
            st.next_index = 0
            self._arm_timer(cat)
            return
        if new_w < st.window:
            cand_new = now + new_w
            j_next = st.next_joint
            if cand_new < j_next:
                # Pull the joint in: new epoch anchored at now.
                st.window = new_w
                st.epoch_t0 = cand_new
                st.next_index = 0
                if st.timer_event is not None:
                    self.loop.cancel(st.timer_event)
                self._arm_timer(cat)
            else:
                # Keep the pending joint; only the spacing after it shrinks.
                st.epoch_t0 = j_next
                st.next_index = 0
                st.window = new_w
                # Timer already armed at exactly j_next; leave it.

    def remove_request(self, request: Request) -> None:
        st = self._cats.get(request.category)
        if st is not None:
            st.requests.pop(request.request_id, None)

    def categories(self) -> List[Category]:
        return list(self._cats)

    def window_of(self, category: Category) -> float:
        return self._cats[category].window

    def state_of(self, category: Category) -> _CategoryState:
        return self._cats[category]

    def active_requests(self, category: Category) -> List[Request]:
        return list(self._cats[category].requests.values())

    def pending_frames(self, category: Category) -> List[Frame]:
        return list(self._cats[category].frames)

    # ----- adaptation hook (paper §4.4) ---------------------------------
    def set_shape_override(
        self, category: Category, shape: Optional[Tuple[int, ...]]
    ) -> None:
        if category in self._cats:
            self._cats[category].shape_override = shape

    def shape_override(self, category: Category):
        st = self._cats.get(category)
        return None if st is None else st.shape_override

    # ----- frame path ----------------------------------------------------
    def on_frame(self, frame: Frame) -> None:
        st = self._cats.get(frame.category)
        if st is None:
            raise KeyError(f"frame for unregistered category {frame.category}")
        st.frames.append(frame)
        if st.next_index is None:
            # Timer retired (requests looked exhausted) but a frame still
            # arrived — gateway-driven streams are jittery, so a late
            # frame can land after the declared last arrival. Fresh epoch
            # at the current window: no frame is ever stranded without a
            # closing joint.
            st.epoch_t0 = self.loop.now + st.window
            st.next_index = 0
            self._arm_timer(frame.category)

    # ----- window machinery ----------------------------------------------
    def _arm_timer(self, cat: Category) -> None:
        st = self._cats[cat]
        # PRIO_JOINT: frames arriving exactly at the joint are processed
        # first and join the closing window (imitator convention).
        st.timer_event = self.loop.schedule(
            st.next_joint,
            lambda: self._joint(cat),
            priority=getattr(self.loop, "PRIO_JOINT", 2),
        )

    def _joint(self, cat: Category) -> None:
        st = self._cats.get(cat)
        if st is None or st.next_index is None:
            return
        st.timer_event = None
        self._flush(cat, release_time=self.loop.now)
        # NOTE: the window never grows back mid-epoch (the paper only ever
        # shrinks the countdown interval, §4.3); regrowth would also break
        # the Phase-2 imitator's conservatism. A fresh window is computed
        # only when the category fully drains and a request restarts it.
        now = self.loop.now
        live = [r for r in st.requests.values() if r.end_time >= now]
        if not live and not st.frames:
            # All requests exhausted/removed and queue drained: retire
            # the timer (a late frame re-arms it via ``on_frame``). Also
            # covers a category whose every request was removed early
            # (``IngestGateway.close``) — an empty request dict must not
            # keep the timer alive forever.
            st.next_index = None
            return
        st.next_index += 1
        self._arm_timer(cat)

    def _flush(self, cat: Category, release_time: float) -> Optional[JobInstance]:
        st = self._cats[cat]
        if not st.frames:
            return None
        frames, st.frames = st.frames, []
        job = JobInstance(
            category=cat,
            frames=frames,
            release_time=release_time,
            relative_deadline=st.window,
            shape_key=st.shape_override or cat.shape_key,
        )
        tr = self.tracer
        if tr is not None:
            label = str(cat)
            for f in frames:
                tr.emit(T.WINDOW_CLOSE, release_time, f.request_id, f.index,
                        where=self.tracer_tag, cat=label,
                        meta={"job_id": job.job_id, "batch": len(frames),
                              "window": st.window})
        self.emit(job)
        return job

    def earliest_next_joint(self, realtime_only: bool = False) -> Optional[float]:
        """Earliest pending window joint (= earliest future job release)."""
        joints = [
            st.next_joint
            for cat, st in self._cats.items()
            if st.next_joint is not None and (cat.realtime or not realtime_only)
        ]
        return min(joints) if joints else None

    def flush_early(self, wcet_fn=None) -> bool:
        """Early-flush optimization: device idle + frames waiting (§4.3).

        Flushes the category whose earliest pending frame has the earliest
        deadline (most urgent first). Returns True if a job was emitted.

        Safety guard (beyond the paper, required for the admission
        guarantee): the flushed job must complete before the earliest
        upcoming window joint of ANY category — otherwise the non-
        preemptive flushed job could block a regularly released job in a
        way the Phase-2 EDF imitator never modeled. With the guard, an
        early flush only consumes device time the imitator treated as
        idle, and it can only shrink (never delay) the batch the next
        joint emits.
        """
        best = None
        for cat, st in self._cats.items():
            if st.frames:
                d = min(f.deadline for f in st.frames)
                if best is None or d < best[0]:
                    best = (d, cat)
        if best is None:
            return False
        cat = best[1]
        if wcet_fn is not None:
            st = self._cats[cat]
            exec_est = wcet_fn(cat, st.shape_override or cat.shape_key, len(st.frames))
            next_joint = self.earliest_next_joint()
            if next_joint is not None and self.loop.now + exec_est > next_joint:
                return False
        self._flush(cat, release_time=self.loop.now)
        return True

    def has_pending_frames(self) -> bool:
        return any(st.frames for st in self._cats.values())
