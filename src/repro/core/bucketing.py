"""Shared batch rounding for the live engine and the admission imitator.

Two regimes, one module, so the engine, the profiler grid, and the WCET
lookup can never drift apart (drift silently breaks the Phase-2
guarantee: the imitator's timeline would be faster than reality):

- PREFILL stays power-of-two bucketed: the engine compiles one XLA
  program per (model, seq bucket, batch bucket) via ``bucket``, so the
  compile count is logarithmic and admission charges the batch the
  engine actually pads to.
- DECODE is served from a resident slot arena (``serving/engine.py``):
  ONE compiled program per (model, seq) always executes ``max_slots``
  rows, and the live batch size is data (an active-slot bitmap), not a
  shape. Per-step decode cost is therefore FLAT in batch size, and the
  WCET table stores a single flat entry per decode category
  (``ProfileTable.record_flat``) instead of a per-bucket curve.
  ``arena_slots`` is the one place the arena's row count is derived.

Keep this module dependency-free (stdlib only); it is imported by the
engine, the profiler, and the admission path.
"""
from __future__ import annotations

import math
from typing import List


def bucket(n: int) -> int:
    """Next power of two >= n (the batch bucket the engine executes).

    ``bucket(0) == 0`` so zero-frame lookups stay free; negative sizes are
    a caller bug and raise.
    """
    if n < 0:
        raise ValueError(f"batch size must be >= 0, got {n}")
    if n <= 1:
        return n
    return 1 << (n - 1).bit_length()


def bucket_sizes(max_batch: int) -> List[int]:
    """All buckets up to and including ``bucket(max_batch)``: 1, 2, 4, ...

    The canonical profiling grid — profiling exactly the buckets makes
    every conservative table lookup an exact hit.
    """
    if max_batch <= 0:
        return []
    out = [1]
    while out[-1] < bucket(max_batch):
        out.append(out[-1] * 2)
    return out


def arena_slots(max_batch: int) -> int:
    """Row count of a model's resident decode arena.

    The arena is sized to the power-of-two bucket of the largest batch
    admission can produce, so any admitted decode job fits without a
    reshape or recompile. Sizing rule (documented in ROADMAP.md): the
    Phase-1 utilization filter bounds the mean frames per DisBatcher
    window at ``n_g = floor(sum_m W_g / p_m)``; size the arena to
    ``arena_slots(n_g_max + 1)`` over the categories the engine serves
    (the +1 absorbs the ceil of an in-flight partial period).
    """
    if max_batch <= 0:
        raise ValueError(f"arena needs >= 1 slot, got max_batch={max_batch}")
    return bucket(max_batch)


def slice_arena_slots(
    max_batch: int, utilization_bound: float = 1.0, min_slots: int = 1
) -> int:
    """Row count of ONE device slice's resident decode arena.

    A cluster partitions admissible load across slices by giving each a
    Phase-1 utilization bound β <= 1 (admission on that slice rejects
    anything pushing its Ũ past β). The frames-per-window bound scales
    the same way — ``n_g = floor(sum_m W_g / p_m)`` is what the
    utilization formula multiplies by E/W — so a slice carrying a β
    share of the load needs only ``ceil(β * max_batch)`` rows before
    rounding to the arena bucket. β = 1 degenerates to ``arena_slots``
    (the single-device rule). ``min_slots`` floors the result so a
    thin slice can still host at least one decode stream.
    """
    if not 0.0 < utilization_bound <= 1.0:
        raise ValueError(
            f"utilization_bound must be in (0, 1], got {utilization_bound}"
        )
    if min_slots < 1:
        raise ValueError(f"min_slots must be >= 1, got {min_slots}")
    return arena_slots(max(min_slots, math.ceil(utilization_bound * max_batch)))


def chunk_depths(max_depth: int) -> List[int]:
    """Power-of-two decode chunk depths up to ``bucket(max_depth)``: 1, 2, 4...

    The canonical profiling grid for multi-step decode chunks. The engine
    compiles ONE scanned program per (model, seq, k) for each k in this
    ladder, the profiler measures exactly those k, and the EDF worker's
    slack-chosen depth rounds DOWN to a member — so, like batch buckets,
    the chunk the worker charges is the chunk the engine actually runs.
    """
    if max_depth <= 0:
        return []
    out = [1]
    while out[-1] < bucket(max_depth):
        out.append(out[-1] * 2)
    return out


def padding_fraction(true_batch: int, bucket_batch: int = 0) -> float:
    """Fraction of executed batch slots that carry no real frame."""
    bb = bucket_batch or bucket(true_batch)
    if bb <= 0:
        return 0.0
    return (bb - true_batch) / bb
