"""Shared batch-bucket rounding for the live engine and the imitator.

The serving engine compiles one XLA program per (model, kind, seq bucket,
batch bucket), padding the true batch size up to the next power of two so
the compile count stays logarithmic. The admission imitator charges each
pseudo-job the WCET of the batch the engine will *actually run* — so both
sides MUST round through this one function. Any drift (engine pads to 8,
admission charges the batch-6 profile) silently breaks the Phase-2
guarantee: the imitator's timeline would be faster than reality.

Keep this module dependency-free; it is imported by the engine, the
profiler, and the admission path.
"""
from __future__ import annotations

from typing import List


def bucket(n: int) -> int:
    """Next power of two >= n (the batch bucket the engine executes).

    ``bucket(0) == 0`` so zero-frame lookups stay free; negative sizes are
    a caller bug and raise.
    """
    if n < 0:
        raise ValueError(f"batch size must be >= 0, got {n}")
    if n <= 1:
        return n
    return 1 << (n - 1).bit_length()


def bucket_sizes(max_batch: int) -> List[int]:
    """All buckets up to and including ``bucket(max_batch)``: 1, 2, 4, ...

    The canonical profiling grid — profiling exactly the buckets makes
    every conservative table lookup an exact hit.
    """
    if max_batch <= 0:
        return []
    out = [1]
    while out[-1] < bucket(max_batch):
        out.append(out[-1] * 2)
    return out


def padding_fraction(true_batch: int, bucket_batch: int = 0) -> float:
    """Fraction of executed batch slots that carry no real frame."""
    bb = bucket_batch or bucket(true_batch)
    if bb <= 0:
        return 0.0
    return (bb - true_batch) / bb
