"""Cluster scheduler: DeepRT at pod scale (beyond-paper layer).

The paper schedules one GPU. At pod scale a deployment runs many *slices*
(a pod, or a sub-mesh hosting one model's SPMD program). Each slice runs
its own DeepRT instance (DisBatcher + EDF + admission) — the paper's
design is per-accelerator, so it shards naturally. This layer adds what a
1000-node deployment needs on top:

- placement: route a new request to the slice with the lowest Phase-1
  utilization that can host its category (capability = profiled model)
  AND has a free decode-arena row for it; admission on the chosen slice
  decides finally (spill to the next candidate on rejection);
- fault tolerance: on slice failure every in-flight request of that slice
  is *re-admitted* elsewhere — the paper's admission test doubles as the
  recovery policy, so recovery never overloads surviving slices;
- degraded capacity / stragglers: a slice may be marked slow with factor f;
  its WCET table is scaled by f (ProfileTable.scaled) and its *future*
  admissions see the degraded table, while the overrun/adaptation machinery
  (paper §4.4) absorbs the transient — the paper's penalty mechanism is
  precisely straggler mitigation at this level;
- elastic scale-up: adding a slice makes its capacity available to the
  placement loop immediately.

Two slice flavors behind one interface:

- ``Slice``: simulation — its DeepRT runs on the cluster's (virtual)
  event loop against a ``SequentialDevice`` with sampled exec times.
- ``LiveSlice``: real serving — its DeepRT owns a compiled
  ``InferenceEngine`` (per-slice resident KV arena, per-slice
  ``max_slots`` from ``bucketing.slice_arena_slots`` under the slice's
  Phase-1 utilization bound), an ``AsyncDevice``, and a per-slice
  profiled WCET table, all behind the shared device contract
  (ROADMAP architecture note). Decode requests LEASE an arena row on
  their slice at admission and release it when their last frame
  completes; ``fail_slice`` fail-stops the slice (device closed, engine
  frozen — its arena rows are never touched again) and re-admits the
  in-flight tails onto surviving slices' arenas by re-leasing rows
  there, never by re-creating arenas. ``serving.batcher_bridge.
  build_live_cluster`` is the factory.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core import telemetry as T
from repro.core.faults import (
    CompletionWatchdog,
    FaultPlan,
    FaultyDevice,
    WatchdogConfig,
)
from repro.core.profiler import ProfileTable
from repro.core.request import Request
from repro.core.scheduler import DeepRT, ExecutionModel
from repro.core.simulator import EventLoop, SequentialDevice
from repro.core.telemetry import LatencyHistogram, render_text

# Slice health states (the watchdog-driven state machine):
#
#   HEALTHY --(suspect_after consecutive late signals)--> SUSPECT
#   SUSPECT --(recover_after consecutive clean completions)--> HEALTHY
#   SUSPECT --(quarantine_after consecutive late signals)--> QUARANTINED
#   any     --(hung submit / operator fail_slice)--> QUARANTINED
#
# SUSPECT slices stay alive and keep serving what they already host but
# receive NO new placements; entering and leaving SUSPECT both trigger
# live re-profiling (the WCET table is rescaled from measured
# completions). QUARANTINED is terminal: the slice is fail-stopped
# (``fail_slice``) and its tails re-admitted elsewhere.
HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"


@dataclass
class SliceSpec:
    name: str
    table: ProfileTable  # per-slice WCET table (mesh-dependent)
    models: Optional[Sequence[str]] = None  # None = hosts any profiled model
    # Phase-1 utilization ceiling this slice's admission enforces; the
    # live factory also sizes the slice's decode arena from it
    # (``bucketing.slice_arena_slots``).
    utilization_bound: float = 1.0


class Slice:
    def __init__(self, spec: SliceSpec, loop: EventLoop, execution=None,
                 adaptation_enabled: bool = True, scheduler: Optional[DeepRT] = None):
        """``scheduler=None`` (simulation) builds a DeepRT on the shared
        loop; ``LiveSlice`` passes a pre-wired live scheduler instead."""
        self.spec = spec
        if scheduler is None:
            scheduler = DeepRT(
                spec.table, loop=loop, execution=execution,
                adaptation_enabled=adaptation_enabled,
                utilization_bound=spec.utilization_bound,
            )
        self.scheduler = scheduler
        self.alive = True
        self.health = HEALTHY
        self.slow_factor = 1.0
        # Lease ledger — request_id -> token from ``_alloc``. The base
        # (simulation) slice tracks SYMBOLIC leases so lifecycle
        # invariants ("every terminal path releases its lease") are
        # checkable without live arenas; LiveSlice's ``_alloc``/``_free``
        # back the same ledger with real arena rows.
        self.leases: Dict[int, object] = {}
        self._frames_left: Dict[int, int] = {}
        # Release rows when a request's last frame completes, without
        # stealing the adaptation module's completion hook.
        prev = self.scheduler.worker.on_job_complete

        def _chained(job, actual, _prev=prev):
            if _prev is not None:
                _prev(job, actual)
            self._on_job_complete(job)

        self.scheduler.worker.on_job_complete = _chained

    def hosts(self, request: Request) -> bool:
        if not self.alive:
            return False
        if self.spec.models is not None and request.category.model_id not in self.spec.models:
            return False
        return self.spec.table.has(
            request.category.model_id, request.category.shape_key
        )

    def utilization(self) -> float:
        return self.scheduler.utilization()

    # -- capacity leases ---------------------------------------------------
    def can_lease(self, request: Request) -> bool:
        return True

    def _alloc(self, request: Request):
        """Resource hook: return the token recorded in ``leases`` (None
        = this request needs no resident resource). The sim token is
        symbolic — no backing resource, only the ledger entry."""
        return ("sim", request.category.model_id)

    def _free(self, token) -> None:
        pass

    def lease(self, request: Request) -> None:
        token = self._alloc(request)
        if token is None:
            return
        self.leases[request.request_id] = token
        self._frames_left[request.request_id] = request.n_frames

    def release(self, request_id: int) -> None:
        token = self.leases.pop(request_id, None)
        self._frames_left.pop(request_id, None)
        if token is None:
            return
        if not self.alive:
            # Dead slice: its resources must never be touched again —
            # the lease record is dropped, the backing rows stay as the
            # failure left them.
            return
        self._free(token)

    def _count_frame_done(self, rid: int) -> None:
        """One of ``rid``'s frames will never need the leased resource
        again (completed OR shed upstream); release on the last."""
        left = self._frames_left.get(rid)
        if left is None:
            return
        if left <= 1:
            self.release(rid)
        else:
            self._frames_left[rid] = left - 1

    def note_dropped(self, request_id: int) -> None:
        """Gateway shed one frame of this request: one fewer completion
        will ever arrive, so the lease frame-countdown must advance."""
        self._count_frame_done(request_id)

    def _on_job_complete(self, job) -> None:
        for frame in job.frames:
            self._count_frame_done(frame.request_id)

    def shutdown(self) -> None:
        """Fail-stop: stop hosting new requests and close the device
        (both contract implementations swallow any in-flight completion
        and report not-idle forever, so the dead scheduler's queued jobs
        never start — simulation and live fail identically). LiveSlice
        extends this to freeze its engine. The lease ledger clears —
        nothing can release through a dead slice, and ``fail_slice``
        reconciles the frames those leases were counting."""
        self.alive = False
        self.scheduler.device.close()
        self.leases.clear()
        self._frames_left.clear()


class LiveSlice(Slice):
    """A slice whose DeepRT executes real compiled programs.

    Owns the full live stack: ``engine`` (this slice's resident KV
    arenas + compiled steps), ``device`` (its AsyncDevice), and — via
    ``spec.table`` — its own profiled WCET table. ``kinds`` maps
    (model_id, shape_key) -> "prefill" | "decode" (the bridge's category
    list), so the slice knows which requests are decode streams that
    occupy an arena row for their lifetime.
    """

    def __init__(self, spec: SliceSpec, scheduler: DeepRT, engine,
                 kinds: Dict[Tuple[str, Tuple[int, ...]], str],
                 leases: Optional[Dict[int, Tuple[str, int, Tuple[int, ...]]]] = None):
        super().__init__(spec, loop=scheduler.loop, scheduler=scheduler)
        self.engine = engine
        # The slice's AsyncDevice IS the scheduler's device — derived,
        # not a second parameter, so shutdown can never close one object
        # while metrics readers watch another.
        self.device = scheduler.device
        self.kinds = dict(kinds)
        # request_id -> (model_id, seq, arena row ids) for decode streams.
        # The live factory passes the SAME dict it gave the dispatch
        # closure, so slot-aligned payload staging always sees current
        # leases (shared by reference, one source of truth).
        if leases is not None:
            self.leases = leases

    def _decode_key(self, request: Request) -> Optional[Tuple[str, int]]:
        cat = request.category
        key = (cat.model_id, tuple(cat.shape_key))
        if self.kinds.get(key) != "decode":
            return None
        return cat.model_id, cat.shape_key[0]

    def can_lease(self, request: Request) -> bool:
        key = self._decode_key(request)
        if key is None:
            return True  # prefill / unknown: no resident row needed
        return len(self.engine.arena(*key).free) >= 1

    def _alloc(self, request: Request):
        """Pin one arena row for an admitted decode stream (one sequence
        = one resident KV row). Caller must have checked ``can_lease``;
        the allocator raises on exhaustion rather than reshaping."""
        key = self._decode_key(request)
        if key is None:
            return None  # prefill / unknown: no resident row needed
        mid, seq = key
        slots = self.engine.alloc_slots(mid, seq, 1)
        return (mid, seq, slots)

    def _free(self, token) -> None:
        mid, seq, slots = token
        self.engine.free_slots(mid, seq, slots)

    def shutdown(self) -> None:
        """Fail-stop the live stack: the device is closed by the base
        shutdown; the engine freezes so any later touch of this slice's
        arenas raises."""
        super().shutdown()
        self.engine.freeze()


@dataclass
class ParkedTail:
    """A displaced tail no surviving slice could accept at failover time.

    The tail keeps its ORIGINAL clock (``tail.start_time`` is fixed at
    the failover instant + one period), so the frames still deliverable
    shrink monotonically as real time passes and the entry provably
    expires once the last frame's arrival is behind us — re-basing the
    start on every retry would make a parked tail immortal.
    """

    origin_rid: int  # the displaced request this tail continues
    tail: Request
    parked_at: float
    attempts: int = 0
    # Transport-owned tails are re-admitted with external arrivals (the
    # rehome owner delivers the real bytes) instead of synthetic frames.
    external: bool = False


class SliceHealthMonitor:
    """Watchdog-signal sink + the healthy/suspect/quarantined policy.

    Devices report raw signals here (per-slice partials bound by the
    factories): ``note_overdue`` from each device's
    :class:`~repro.core.faults.CompletionWatchdog`, ``note_complete``
    with measured ``(expected, actual)`` seconds per completion, and
    ``note_submit_error`` on transient submit failures. The monitor
    turns sustained drift into state transitions, quarantines hung
    slices through the cluster's ``fail_slice``, and re-profiles WCET
    tables from measured completions on suspect entry and recovery.

    Subscribers (``subscribe(fn)``, ``fn(name, old, new)``) are notified
    on every transition BEFORE a quarantined slice is failed, so the
    ingest gateway can abort the slice's sessions (stop deliveries)
    ahead of the lost-frame reconciliation.
    """

    def __init__(self, cluster: "ClusterScheduler", config: Optional[WatchdogConfig] = None):
        self.cluster = cluster
        self.config = config if config is not None else WatchdogConfig()
        # name -> recent (expected, actual) completion samples.
        self.samples: Dict[str, Deque[Tuple[float, float]]] = {}
        self.late_streak: Dict[str, int] = {}
        self.clean_streak: Dict[str, int] = {}
        self.submit_errors: Dict[str, int] = {}
        self.reprofiles: Dict[str, int] = {}
        # Audit trail: (t, name, old, new, reason).
        self.transitions: List[Tuple[float, str, str, str, str]] = []
        self.listeners: List[Callable[[str, str, str], None]] = []
        # Frame-lifecycle tracer (core/telemetry.py); None = off.
        self.tracer = None

    def subscribe(self, fn: Callable[[str, str, str], None]) -> None:
        self.listeners.append(fn)

    def state(self, name: str) -> str:
        return self.cluster.slices[name].health

    # -- device-facing signal sinks ---------------------------------------
    def note_overdue(self, name: str, job, expected: float, elapsed: float) -> None:
        sl = self.cluster.slices.get(name)
        if sl is None or not sl.alive:
            return
        if self.tracer is not None:
            self.tracer.emit(
                T.WATCHDOG_OVERDUE, self.cluster.loop.now, where=name,
                meta={"expected": expected, "elapsed": elapsed})
        if elapsed >= self.config.hang_after(expected):
            # A hang can never produce the late *completions* the streak
            # counts — it is quarantined directly.
            self._quarantine(
                name,
                f"hung: no completion after {elapsed:.4f}s "
                f"(expected {expected:.4f}s)",
            )
            return
        self._late_signal(name, "overdue submit")

    def note_complete(self, name: str, expected: float, actual: float) -> None:
        sl = self.cluster.slices.get(name)
        if sl is None or not sl.alive:
            return
        dq = self.samples.setdefault(name, deque(maxlen=self.config.sample_window))
        dq.append((expected, actual))
        if actual > self.config.deadline_for(expected):
            self._late_signal(name, "late completion")
            return
        self.late_streak[name] = 0
        if sl.health == SUSPECT:
            self.clean_streak[name] = self.clean_streak.get(name, 0) + 1
            if self.clean_streak[name] >= self.config.recover_after:
                self._set_state(
                    name,
                    HEALTHY,
                    f"recovered: {self.config.recover_after} consecutive clean completions",
                )

    def note_submit_error(self, name: str) -> None:
        self.submit_errors[name] = self.submit_errors.get(name, 0) + 1
        sl = self.cluster.slices.get(name)
        if sl is None or not sl.alive:
            return
        self._late_signal(name, "transient submit error")

    # -- live re-profiling -------------------------------------------------
    def measured_drift(self, name: str, n_samples: Optional[int] = None) -> float:
        """Observed WCET drift: a high quantile of ``actual / expected``
        over the most recent completions, clamped to >= 1 (a table is
        never rescaled below its profiled base — underruns are normal)."""
        dq = self.samples.get(name)
        if not dq:
            raise RuntimeError(f"no measured completions recorded for slice {name!r}")
        n = n_samples if n_samples is not None else self.config.reprofile_samples
        recent = list(dq)[-n:]
        ratios = sorted(a / e for e, a in recent if e > 0)
        if not ratios:
            raise RuntimeError(f"no usable completion samples for slice {name!r}")
        idx = int(math.ceil(self.config.reprofile_quantile * len(ratios))) - 1
        return max(1.0, ratios[max(0, min(idx, len(ratios) - 1))])

    def reprofile(self, name: str, n_samples: Optional[int] = None) -> float:
        """Rescale the slice's WCET table from MEASURED completions.

        Replaces the operator-supplied stale scale of the old
        ``mark_slow``: admission on this slice now budgets what the
        hardware currently delivers, not what profiling once saw. Always
        rescales from the slice's base table, so repeated re-profiles
        never compound."""
        drift = self.measured_drift(name, n_samples)
        self.cluster._rescale(name, drift)
        self.reprofiles[name] = self.reprofiles.get(name, 0) + 1
        return drift

    # -- transitions -------------------------------------------------------
    def _late_signal(self, name: str, reason: str) -> None:
        self.clean_streak[name] = 0
        self.late_streak[name] = self.late_streak.get(name, 0) + 1
        streak = self.late_streak[name]
        health = self.cluster.slices[name].health
        if health == HEALTHY and streak >= self.config.suspect_after:
            self._set_state(name, SUSPECT, f"{reason}: {streak} consecutive late signals")
        elif health == SUSPECT and streak >= self.config.quarantine_after:
            self._quarantine(name, f"{reason}: drift persisted for {streak} late signals")

    def _quarantine(self, name: str, reason: str) -> None:
        self._set_state(name, QUARANTINED, reason)
        self.cluster.fail_slice(name)

    def _set_state(self, name: str, new: str, reason: str) -> None:
        sl = self.cluster.slices[name]
        old = sl.health
        if old == new:
            return
        sl.health = new
        self.late_streak[name] = 0
        self.clean_streak[name] = 0
        self.transitions.append((self.cluster.loop.now, name, old, new, reason))
        if self.tracer is not None:
            self.tracer.emit(
                T.HEALTH_TRANSITION, self.cluster.loop.now, where=name,
                meta={"old": old, "new": new, "reason": reason})
        # Couple into the paper's adaptation loop: a drifting device
        # tightens the gateway's shed budget for ALL its categories
        # (AdaptationModule.DEGRADED_BUDGET_TIGHTEN), not just penalized
        # ones.
        adaptation = getattr(sl.scheduler, "adaptation", None)
        if adaptation is not None:
            adaptation.note_device_health(new == HEALTHY)
        if new == SUSPECT:
            # Entering suspect: future admissions on this slice (none
            # while suspect, but its own running streams' re-placements)
            # must budget the drifted WCETs.
            try:
                self.reprofile(name)
            except RuntimeError:
                pass  # no completion samples yet (e.g. first submit hung)
        elif new == HEALTHY and old == SUSPECT:
            # Recovery: rescale from the clean completions that proved
            # it, restoring the table toward its profiled base.
            try:
                self.reprofile(name, n_samples=self.config.recover_after)
            except RuntimeError:
                pass
        for fn in list(self.listeners):
            fn(name, old, new)


class ClusterScheduler:
    def __init__(
        self,
        loop: Optional[EventLoop] = None,
        execution=None,
        watchdog: Optional[WatchdogConfig] = None,
        retry_backoff: float = 0.02,
        retry_max_backoff: float = 1.0,
    ):
        self.loop = loop if loop is not None else EventLoop()
        self.execution = execution
        self.slices: Dict[str, Slice] = {}
        # request -> slice name, for failure recovery:
        self.placement: Dict[int, str] = {}
        self.requests: Dict[int, Request] = {}
        self.dropped: List[Request] = []
        self.reroutes = 0
        # Placement audit trail: (request_id, ((slice, utilization), ...)
        # in try order, chosen slice or None). The spill-order tests (and
        # any postmortem of a mis-placed request) read this. Bounded: a
        # live cluster submits for the process lifetime, so an unbounded
        # per-submission log would be a slow leak.
        self.placement_attempts: Deque[
            Tuple[int, Tuple[Tuple[str, float], ...], Optional[str]]
        ] = deque(maxlen=4096)
        # Evictions from the bounded audit trail above — the overflow
        # count keeps the total submission volume reconstructible.
        self.placement_attempts_overflow = 0
        # Failover audit: displaced request -> re-admitted tail request id
        # (None = shed). Requests whose frames had all arrived when their
        # slice died have nothing to re-admit and land in
        # ``finished_with_slice`` instead — between the three records,
        # no request placed on a failed slice goes unaccounted.
        self.failover_map: Dict[int, Optional[int]] = {}
        self.finished_with_slice: List[int] = []
        # Health machinery. ``watchdog`` arms the full loop (device
        # watchdogs are built by the factories from the same config);
        # without it the monitor still exists so operator-driven
        # fail_slice keeps a single audit/notification path.
        self.watchdog = watchdog
        self.health = SliceHealthMonitor(self, watchdog)
        # Deadline-aware retry queue for displaced tails that no
        # surviving slice could accept at the failover instant:
        # origin request id -> ParkedTail. Every parked entry resolves to
        # exactly one of ``parked_admitted`` / ``parked_expired``.
        self.retry_backoff = retry_backoff
        self.retry_max_backoff = retry_max_backoff
        self.parked: Dict[int, ParkedTail] = {}
        self.parked_admitted: List[int] = []
        self.parked_expired: List[int] = []
        # Subset of parked_expired withdrawn by their rehome owner
        # (transport eviction) rather than by clock expiry.
        self.parked_cancelled: List[int] = []
        # Session re-homing hook (the transport server registers here):
        # an object with owns(rid) / rehomed(origin_rid, tail, slice) /
        # expired(origin_rid). Tails it owns are re-admitted as EXTERNAL
        # requests — the owner replays the real buffered bytes into them
        # instead of the cluster streaming synthetic frames.
        self.rehome_owner = None
        # Frame-lifecycle tracer (core/telemetry.py); attach_tracer wires
        # every slice's pipeline plus the health monitor.
        self.tracer = None
        # Extra snapshot sections: name -> zero-arg callable returning a
        # JSON-able dict. The live factory registers engine probes here
        # (arena occupancy, staging-ring reuse) so telemetry_snapshot
        # folds execution-substrate state in without core importing it.
        self.telemetry_probes: Dict[str, Callable[[], Dict]] = {}

    def set_rehome_owner(self, owner) -> None:
        self.rehome_owner = owner

    # -- elasticity ------------------------------------------------------
    def add_slice(self, spec: SliceSpec) -> Slice:
        return self.register(Slice(spec, self.loop, execution=self.execution))

    def register(self, sl: Slice) -> Slice:
        """Add a pre-built slice (the live factory's entry point)."""
        self.slices[sl.spec.name] = sl
        if self.tracer is not None:
            sl.scheduler.attach_tracer(self.tracer, tag=sl.spec.name)
        return sl

    def attach_tracer(self, tracer) -> None:
        """Enable frame-lifecycle tracing cluster-wide: every slice's
        pipeline (tagged with the slice name) plus the health monitor's
        watchdog/transition lane. Slices registered later inherit the
        tracer. ``tracer=None`` detaches everywhere."""
        self.tracer = tracer
        self.health.tracer = tracer
        for sl in self.slices.values():
            sl.scheduler.attach_tracer(tracer, tag=sl.spec.name)

    def mark_slow(self, name: str, factor: Optional[float] = None) -> float:
        """Straggler: scale the slice's WCET table for future admissions;
        running work is absorbed by the paper's adaptation machinery.

        ``factor=None`` re-profiles live: the scale is the MEASURED
        drift (quantile of actual/expected over recent completions,
        tracked by the health monitor) instead of an operator-supplied
        stale guess. An explicit factor is still accepted for tests and
        forced degradation."""
        if factor is None:
            return self.health.reprofile(name)
        self._rescale(name, factor)
        return factor

    def _rescale(self, name: str, factor: float) -> None:
        sl = self.slices[name]
        sl.slow_factor = factor
        sl.scheduler.table = sl.spec.table.scaled(factor)
        sl.scheduler.admission.table = sl.scheduler.table

    def fail_slice(self, name: str) -> List[Request]:
        """Fail-stop a slice; re-admit its unfinished requests elsewhere.

        Live slices are shut down first (device closed, engine frozen),
        so the dead slice's arena rows are never touched again; each
        displaced request's remaining tail is re-admitted through the
        normal placement + admission + lease path, which allocates rows
        on SURVIVING slices' resident arenas.

        Tails that no surviving slice can accept at the failover instant
        are PARKED in the deadline-aware retry queue (``parked``) and
        retried with backoff until admitted or provably past their last
        frame's arrival — they are returned for visibility, not shed.
        Frames already delivered to the dead slice that never completed
        are reconciled into its ``Metrics.lost_frames`` exactly once, so
        ``completed + dropped + lost == ingested`` holds across failure.

        Failing a slice twice (or an unknown name) raises instead of
        silently double-displacing requests and corrupting the failover
        accounting."""
        if name not in self.slices:
            raise KeyError(
                f"fail_slice: unknown slice {name!r} (have: {sorted(self.slices)})"
            )
        sl = self.slices[name]
        if not sl.alive:
            raise RuntimeError(
                f"fail_slice: slice {name!r} already failed; failing it again "
                f"would re-displace its requests and corrupt failover accounting"
            )
        if sl.health != QUARANTINED:
            # Operator-initiated failure takes the same audit +
            # notification path as a watchdog quarantine (listeners —
            # e.g. the ingest gateway aborting this slice's sessions —
            # must fire before deliveries are reconciled below).
            self.health._set_state(name, QUARANTINED, "fail_slice (operator)")
        sl.shutdown()
        displaced: List[Tuple[int, Request]] = []
        finished_now: List[int] = []
        now = self.loop.now
        for rid, placed_on in list(self.placement.items()):
            if placed_on != name:
                continue
            req = self.requests[rid]
            del self.placement[rid]
            if req.end_time <= now:
                # Already fully arrived; in-flight frames lost with the
                # slice, nothing left to re-admit.
                self.finished_with_slice.append(rid)
                finished_now.append(rid)
                continue
            # Frames with arrival <= now are lost with the slice. floor,
            # not int(): a request whose start is still in the future
            # (e.g. a tail re-admitted by an earlier failover) has a
            # negative elapsed fraction, and int()'s truncation toward
            # zero would count one phantom arrived frame.
            arrived = math.floor((now - req.start_time) / req.period) + 1
            remaining = req.n_frames - max(0, arrived)
            if remaining <= 0:
                self.finished_with_slice.append(rid)
                finished_now.append(rid)
                continue
            # Re-admit the remaining tail as a fresh request.
            tail = Request(
                category=req.category,
                period=req.period,
                relative_deadline=req.relative_deadline,
                n_frames=remaining,
                start_time=now + req.period,
            )
            displaced.append((rid, tail))
        # Reconcile frames that died in the dead slice's pipeline
        # (delivered but never completed: DisBatcher windows, the EDF
        # queue, and the in-flight job whose completion is swallowed).
        m = sl.scheduler.metrics
        in_pipeline = m.delivered_frames - m.completed_frames - m.lost_frames
        if in_pipeline > 0:
            m.record_lost(in_pipeline)
        parked_now: List[Request] = []
        owner = self.rehome_owner
        # Requests with no deliverable tail are OVER at the failover
        # instant: resolve their owner's session now (same callback as a
        # parked tail expiring), or a transport session aborted into
        # ``failover`` state would wait forever for a re-home that is
        # never coming. ``finished_with_slice`` stays their ledger —
        # they never enter ``failover_map``.
        for rid in finished_now:
            if owner is not None and owner.owns(rid):
                owner.expired(rid)
        for rid, tail in displaced:
            owned = owner is not None and owner.owns(rid)
            if self._try_place(tail, external_arrivals=owned):
                self.failover_map[rid] = tail.request_id
                self.reroutes += 1
                if owned:
                    owner.rehomed(rid, tail, self.placement[tail.request_id])
            else:
                self._park(rid, tail, external=owned)
                parked_now.append(tail)
        return parked_now

    # -- parked-tail retry queue ------------------------------------------
    def _park(self, origin_rid: int, tail: Request, external: bool = False) -> None:
        entry = ParkedTail(
            origin_rid=origin_rid, tail=tail, parked_at=self.loop.now,
            external=external,
        )
        self.parked[origin_rid] = entry
        self._schedule_retry(entry)

    def _schedule_retry(self, entry: ParkedTail) -> None:
        tail = entry.tail
        delay = min(
            max(self.retry_backoff, tail.period) * (2 ** entry.attempts),
            self.retry_max_backoff,
        )
        # Deadline-aware: never sleep past the instant the tail provably
        # expires (one period after its last frame's arrival) — the retry
        # landing there resolves the entry as expired, so every parked
        # tail terminates in bounded time.
        expiry = tail.start_time + (tail.n_frames - 1) * tail.period + tail.period
        when = max(min(self.loop.now + delay, expiry), self.loop.now)
        self.loop.schedule(
            when,
            partial(self._retry_parked, entry.origin_rid),
            priority=getattr(self.loop, "PRIO_ARRIVAL", 0),
        )

    def _retry_parked(self, origin_rid: int) -> None:
        entry = self.parked.get(origin_rid)
        if entry is None:
            return
        tail = entry.tail
        now = self.loop.now
        # Frames whose arrival passed while parked are gone (same floor
        # rule as fail_slice); what is still deliverable shrinks as time
        # passes because the tail keeps its original clock.
        arrived = math.floor((now - tail.start_time) / tail.period) + 1
        remaining = tail.n_frames - max(0, arrived)
        owner = self.rehome_owner if entry.external else None
        if remaining <= 0:
            del self.parked[origin_rid]
            self.parked_expired.append(origin_rid)
            self.failover_map[origin_rid] = None
            if owner is not None:
                owner.expired(origin_rid)
            return
        fresh = Request(
            category=tail.category,
            period=tail.period,
            relative_deadline=tail.relative_deadline,
            n_frames=remaining,
            start_time=now + tail.period,
        )
        if self._try_place(fresh, external_arrivals=entry.external):
            del self.parked[origin_rid]
            self.parked_admitted.append(origin_rid)
            self.failover_map[origin_rid] = fresh.request_id
            self.reroutes += 1
            if owner is not None:
                owner.rehomed(origin_rid, fresh, self.placement[fresh.request_id])
            return
        entry.attempts += 1
        self._schedule_retry(entry)

    def cancel_parked(self, origin_rid: int) -> bool:
        """Owner-initiated withdrawal of a parked tail (the transport
        evicted the session it belonged to): the entry resolves as
        expired-by-cancellation and can never be re-admitted. No
        ``rehome_owner.expired`` callback — the owner asked. The pending
        retry finds the entry gone and is a no-op."""
        entry = self.parked.pop(origin_rid, None)
        if entry is None:
            return False
        self.parked_expired.append(origin_rid)
        self.parked_cancelled.append(origin_rid)
        self.failover_map[origin_rid] = None
        return True

    # -- placement + admission --------------------------------------------
    def submit_request(
        self, request: Request, external_arrivals: bool = False
    ) -> bool:
        """``external_arrivals`` is forwarded to the chosen slice's
        scheduler: the ingest gateway registers streams through the
        SAME placement/admission/lease path but delivers the frames
        itself (``DeepRT.ingest_frame``)."""
        if self._try_place(request, external_arrivals=external_arrivals):
            return True
        self.dropped.append(request)
        return False

    def _try_place(
        self, request: Request, external_arrivals: bool = False
    ) -> bool:
        """Placement + admission without the drop bookkeeping: shared by
        fresh submissions (which record a drop on failure) and parked-
        tail retries (which park again instead). Only HEALTHY slices are
        candidates — a SUSPECT slice keeps serving what it has but takes
        no new placements until it recovers."""
        ranked = sorted(
            ((sl.utilization(), sl.spec.name, sl)
             for sl in self.slices.values()
             if sl.health == HEALTHY and sl.hosts(request)),
            key=lambda t: (t[0], t[1]),
        )
        chosen: Optional[str] = None
        for _u, _name, sl in ranked:
            if not sl.can_lease(request):
                continue  # no free arena row for a new decode stream: spill
            result = sl.scheduler.submit_request(
                request, external_arrivals=external_arrivals
            )
            if result.admitted:
                sl.lease(request)
                self.placement[request.request_id] = sl.spec.name
                self.requests[request.request_id] = request
                chosen = sl.spec.name
                break
        if len(self.placement_attempts) == self.placement_attempts.maxlen:
            self.placement_attempts_overflow += 1
        self.placement_attempts.append(
            (request.request_id,
             tuple((name, u) for u, name, _ in ranked), chosen)
        )
        return chosen is not None

    # -- metrics ----------------------------------------------------------
    def run(self, until: Optional[float] = None) -> None:
        self.loop.run(until)

    def aggregate_metrics(self) -> Dict[str, float]:
        total = missed = jobs = shed = lost = delivered = retries = 0
        e2e = LatencyHistogram()
        for sl in self.slices.values():
            m = sl.scheduler.metrics
            total += m.completed_frames
            missed += m.missed_frames
            jobs += m.job_count
            shed += m.dropped_frames
            lost += m.lost_frames
            delivered += m.delivered_frames
            retries += m.submit_retries
            # Streaming histograms, not raw sample lists: correct (and
            # O(1) memory) even with Metrics.record_samples off.
            e2e.merge(m.e2e_hist)
        return {
            "completed_frames": total,
            "missed_frames": missed,
            "miss_rate": missed / total if total else 0.0,
            "jobs": jobs,
            "dropped_requests": len(self.dropped),
            "dropped_frames": shed,
            "lost_frames": lost,
            "ingested_frames": delivered + shed,
            "submit_retries": retries,
            "mean_e2e_latency": e2e.mean,
            "e2e_p50": e2e.percentile(0.50),
            "e2e_p95": e2e.percentile(0.95),
            "e2e_p99": e2e.percentile(0.99),
            "max_e2e_latency": e2e.vmax,
            "reroutes": self.reroutes,
            "parked": len(self.parked),
            "parked_admitted": len(self.parked_admitted),
            "parked_expired": len(self.parked_expired),
            "parked_cancelled": len(self.parked_cancelled),
        }

    def telemetry_snapshot(self) -> Dict:
        """One JSON-able tree of everything observable about the
        cluster: aggregate + per-slice frame metrics, slice health and
        utilization, chunk-depth histograms and bounded-log overflow
        counters, watchdog statistics, registered execution-substrate
        probes (arena occupancy, staging-ring reuse — see
        ``telemetry_probes``), and — when a tracer is attached — the
        tracer's ring stats and full deadline-miss attribution. The
        transport server embeds this into its STATUS reply; never the
        other way around (no recursion)."""
        slices = {}
        for name, sl in self.slices.items():
            m = sl.scheduler.metrics
            w = sl.scheduler.worker
            slices[name] = {
                "health": sl.health,
                "alive": sl.alive,
                "utilization": sl.utilization() if sl.alive else 0.0,
                "slow_factor": sl.slow_factor,
                "completed_frames": m.completed_frames,
                "missed_frames": m.missed_frames,
                "dropped_frames": m.dropped_frames,
                "lost_frames": m.lost_frames,
                "delivered_frames": m.delivered_frames,
                "latency": m.latency_hist.to_dict(),
                "e2e": m.e2e_hist.to_dict(),
                "chunk_depths": {str(k): v for k, v in
                                 sorted(w.chunk_depth_counts.items())},
                "chunk_log_overflow": w.chunk_log_overflow,
                "leases": len(sl.leases),
                "admission": dict(sl.scheduler.admission.stats),
                "adaptation": sl.scheduler.adaptation.telemetry(),
            }
        h = self.health
        snap = {
            "aggregate": self.aggregate_metrics(),
            "slices": slices,
            "placement_attempts_overflow": self.placement_attempts_overflow,
            "watchdog": {
                "transitions": len(h.transitions),
                "reprofiles": dict(h.reprofiles),
                "submit_errors": dict(h.submit_errors),
            },
        }
        for name, probe in self.telemetry_probes.items():
            snap[name] = probe()
        if self.tracer is not None:
            snap["tracer"] = self.tracer.snapshot()
            snap["attribution"] = self.tracer.attribution()
        return snap

    def telemetry_text(self) -> str:
        """``/metrics``-style text exposition of the snapshot."""
        return render_text(self.telemetry_snapshot())


def build_sim_cluster(
    table_fn: Callable[[], ProfileTable],
    slice_names: Sequence[str],
    fault_plans: Optional[Dict[str, FaultPlan]] = None,
    watchdog: Optional[WatchdogConfig] = None,
    execution=None,
    utilization_bound: float = 1.0,
    loop: Optional[EventLoop] = None,
) -> ClusterScheduler:
    """Simulated cluster with fault injection and the health watchdog.

    Every slice's ``SequentialDevice`` is wrapped in a
    :class:`~repro.core.faults.FaultyDevice` (an empty plan for slices
    not named in ``fault_plans``), and when ``watchdog`` is given each
    wrapper carries a :class:`~repro.core.faults.CompletionWatchdog` plus
    measured-completion reporting wired to the cluster's
    ``SliceHealthMonitor`` — the exact topology the live factory
    (``serving.batcher_bridge.build_live_cluster``) builds around
    ``AsyncDevice``, but in virtual time, so fault scenarios that take
    wall-clock minutes replay in milliseconds.

    ``table_fn`` is called once per slice so re-profiling rescales stay
    per-slice.
    """
    cluster = ClusterScheduler(loop=loop, execution=execution, watchdog=watchdog)
    plans = dict(fault_plans or {})
    unknown = set(plans) - set(slice_names)
    if unknown:
        raise ValueError(f"fault plans for unknown slices: {sorted(unknown)}")
    for name in slice_names:
        spec = SliceSpec(
            name=name, table=table_fn(), utilization_bound=utilization_bound
        )
        wd = None
        if watchdog is not None:
            wd = CompletionWatchdog(
                cluster.loop, watchdog,
                on_overdue=partial(cluster.health.note_overdue, name),
            )
        device = FaultyDevice(
            SequentialDevice(cluster.loop),
            plans.get(name, FaultPlan()),
            watchdog=wd,
            on_measured=(
                partial(cluster.health.note_complete, name)
                if watchdog is not None else None
            ),
            on_submit_error=partial(cluster.health.note_submit_error, name),
        )
        sched = DeepRT(
            spec.table, loop=cluster.loop, execution=execution,
            utilization_bound=utilization_bound, device=device,
        )
        cluster.register(Slice(spec, cluster.loop, scheduler=sched))
    return cluster
