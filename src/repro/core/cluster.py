"""Cluster scheduler: DeepRT at pod scale (beyond-paper layer).

The paper schedules one GPU. At pod scale a deployment runs many *slices*
(a pod, or a sub-mesh hosting one model's SPMD program). Each slice runs
its own DeepRT instance (DisBatcher + EDF + admission) — the paper's
design is per-accelerator, so it shards naturally. This layer adds what a
1000-node deployment needs on top:

- placement: route a new request to the slice with the lowest Phase-1
  utilization that can host its category (capability = profiled model)
  AND has a free decode-arena row for it; admission on the chosen slice
  decides finally (spill to the next candidate on rejection);
- fault tolerance: on slice failure every in-flight request of that slice
  is *re-admitted* elsewhere — the paper's admission test doubles as the
  recovery policy, so recovery never overloads surviving slices;
- degraded capacity / stragglers: a slice may be marked slow with factor f;
  its WCET table is scaled by f (ProfileTable.scaled) and its *future*
  admissions see the degraded table, while the overrun/adaptation machinery
  (paper §4.4) absorbs the transient — the paper's penalty mechanism is
  precisely straggler mitigation at this level;
- elastic scale-up: adding a slice makes its capacity available to the
  placement loop immediately.

Two slice flavors behind one interface:

- ``Slice``: simulation — its DeepRT runs on the cluster's (virtual)
  event loop against a ``SequentialDevice`` with sampled exec times.
- ``LiveSlice``: real serving — its DeepRT owns a compiled
  ``InferenceEngine`` (per-slice resident KV arena, per-slice
  ``max_slots`` from ``bucketing.slice_arena_slots`` under the slice's
  Phase-1 utilization bound), an ``AsyncDevice``, and a per-slice
  profiled WCET table, all behind the shared device contract
  (ROADMAP architecture note). Decode requests LEASE an arena row on
  their slice at admission and release it when their last frame
  completes; ``fail_slice`` fail-stops the slice (device closed, engine
  frozen — its arena rows are never touched again) and re-admits the
  in-flight tails onto surviving slices' arenas by re-leasing rows
  there, never by re-creating arenas. ``serving.batcher_bridge.
  build_live_cluster`` is the factory.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.profiler import ProfileTable
from repro.core.request import Request
from repro.core.scheduler import DeepRT, ExecutionModel
from repro.core.simulator import EventLoop


@dataclass
class SliceSpec:
    name: str
    table: ProfileTable  # per-slice WCET table (mesh-dependent)
    models: Optional[Sequence[str]] = None  # None = hosts any profiled model
    # Phase-1 utilization ceiling this slice's admission enforces; the
    # live factory also sizes the slice's decode arena from it
    # (``bucketing.slice_arena_slots``).
    utilization_bound: float = 1.0


class Slice:
    def __init__(self, spec: SliceSpec, loop: EventLoop, execution=None,
                 adaptation_enabled: bool = True, scheduler: Optional[DeepRT] = None):
        """``scheduler=None`` (simulation) builds a DeepRT on the shared
        loop; ``LiveSlice`` passes a pre-wired live scheduler instead."""
        self.spec = spec
        if scheduler is None:
            scheduler = DeepRT(
                spec.table, loop=loop, execution=execution,
                adaptation_enabled=adaptation_enabled,
                utilization_bound=spec.utilization_bound,
            )
        self.scheduler = scheduler
        self.alive = True
        self.slow_factor = 1.0

    def hosts(self, request: Request) -> bool:
        if not self.alive:
            return False
        if self.spec.models is not None and request.category.model_id not in self.spec.models:
            return False
        return self.spec.table.has(
            request.category.model_id, request.category.shape_key
        )

    def utilization(self) -> float:
        return self.scheduler.utilization()

    # -- capacity leases (no-ops in simulation; LiveSlice overrides) ------
    def can_lease(self, request: Request) -> bool:
        return True

    def lease(self, request: Request) -> None:
        pass

    def release(self, request_id: int) -> None:
        pass

    def note_dropped(self, request_id: int) -> None:
        """Gateway shed one frame of this request: one fewer completion
        will ever arrive, so lease frame-countdowns must advance (no-op
        for sim slices, which hold no leases)."""

    def shutdown(self) -> None:
        """Fail-stop: stop hosting new requests and close the device
        (both contract implementations swallow any in-flight completion
        and report not-idle forever, so the dead scheduler's queued jobs
        never start — simulation and live fail identically). LiveSlice
        extends this to freeze its engine."""
        self.alive = False
        self.scheduler.device.close()


class LiveSlice(Slice):
    """A slice whose DeepRT executes real compiled programs.

    Owns the full live stack: ``engine`` (this slice's resident KV
    arenas + compiled steps), ``device`` (its AsyncDevice), and — via
    ``spec.table`` — its own profiled WCET table. ``kinds`` maps
    (model_id, shape_key) -> "prefill" | "decode" (the bridge's category
    list), so the slice knows which requests are decode streams that
    occupy an arena row for their lifetime.
    """

    def __init__(self, spec: SliceSpec, scheduler: DeepRT, engine,
                 kinds: Dict[Tuple[str, Tuple[int, ...]], str],
                 leases: Optional[Dict[int, Tuple[str, int, Tuple[int, ...]]]] = None):
        super().__init__(spec, loop=scheduler.loop, scheduler=scheduler)
        self.engine = engine
        # The slice's AsyncDevice IS the scheduler's device — derived,
        # not a second parameter, so shutdown can never close one object
        # while metrics readers watch another.
        self.device = scheduler.device
        self.kinds = dict(kinds)
        # request_id -> (model_id, seq, arena row ids) for decode streams.
        # The live factory passes the SAME dict it gave the dispatch
        # closure, so slot-aligned payload staging always sees current
        # leases (shared by reference, one source of truth).
        self.leases: Dict[int, Tuple[str, int, Tuple[int, ...]]] = (
            {} if leases is None else leases
        )
        self._frames_left: Dict[int, int] = {}
        # Release rows when a request's last frame completes, without
        # stealing the adaptation module's completion hook.
        prev = scheduler.worker.on_job_complete

        def _chained(job, actual, _prev=prev):
            if _prev is not None:
                _prev(job, actual)
            self._on_job_complete(job)

        scheduler.worker.on_job_complete = _chained

    def _decode_key(self, request: Request) -> Optional[Tuple[str, int]]:
        cat = request.category
        key = (cat.model_id, tuple(cat.shape_key))
        if self.kinds.get(key) != "decode":
            return None
        return cat.model_id, cat.shape_key[0]

    def can_lease(self, request: Request) -> bool:
        key = self._decode_key(request)
        if key is None:
            return True  # prefill / unknown: no resident row needed
        return len(self.engine.arena(*key).free) >= 1

    def lease(self, request: Request) -> None:
        """Pin one arena row for an admitted decode stream (one sequence
        = one resident KV row). Caller must have checked ``can_lease``;
        the allocator raises on exhaustion rather than reshaping."""
        key = self._decode_key(request)
        if key is None:
            return
        mid, seq = key
        slots = self.engine.alloc_slots(mid, seq, 1)
        self.leases[request.request_id] = (mid, seq, slots)
        self._frames_left[request.request_id] = request.n_frames

    def release(self, request_id: int) -> None:
        lease = self.leases.pop(request_id, None)
        self._frames_left.pop(request_id, None)
        if lease is None:
            return
        if not self.alive:
            # Dead slice: its engine is frozen and its arena rows must
            # never be touched again — the lease record is dropped, the
            # rows stay as the failure left them.
            return
        mid, seq, slots = lease
        self.engine.free_slots(mid, seq, slots)

    def _count_frame_done(self, rid: int) -> None:
        """One of ``rid``'s frames will never need the arena row again
        (completed OR shed upstream); release the lease on the last."""
        left = self._frames_left.get(rid)
        if left is None:
            return
        if left <= 1:
            self.release(rid)
        else:
            self._frames_left[rid] = left - 1

    def note_dropped(self, request_id: int) -> None:
        self._count_frame_done(request_id)

    def _on_job_complete(self, job) -> None:
        for frame in job.frames:
            self._count_frame_done(frame.request_id)

    def shutdown(self) -> None:
        """Fail-stop the live stack: the device is closed by the base
        shutdown; the engine freezes so any later touch of this slice's
        arenas raises."""
        super().shutdown()
        self.engine.freeze()


class ClusterScheduler:
    def __init__(self, loop: Optional[EventLoop] = None, execution=None):
        self.loop = loop if loop is not None else EventLoop()
        self.execution = execution
        self.slices: Dict[str, Slice] = {}
        # request -> slice name, for failure recovery:
        self.placement: Dict[int, str] = {}
        self.requests: Dict[int, Request] = {}
        self.dropped: List[Request] = []
        self.reroutes = 0
        # Placement audit trail: (request_id, ((slice, utilization), ...)
        # in try order, chosen slice or None). The spill-order tests (and
        # any postmortem of a mis-placed request) read this. Bounded: a
        # live cluster submits for the process lifetime, so an unbounded
        # per-submission log would be a slow leak.
        self.placement_attempts: Deque[
            Tuple[int, Tuple[Tuple[str, float], ...], Optional[str]]
        ] = deque(maxlen=4096)
        # Failover audit: displaced request -> re-admitted tail request id
        # (None = shed). Requests whose frames had all arrived when their
        # slice died have nothing to re-admit and land in
        # ``finished_with_slice`` instead — between the three records,
        # no request placed on a failed slice goes unaccounted.
        self.failover_map: Dict[int, Optional[int]] = {}
        self.finished_with_slice: List[int] = []

    # -- elasticity ------------------------------------------------------
    def add_slice(self, spec: SliceSpec) -> Slice:
        return self.register(Slice(spec, self.loop, execution=self.execution))

    def register(self, sl: Slice) -> Slice:
        """Add a pre-built slice (the live factory's entry point)."""
        self.slices[sl.spec.name] = sl
        return sl

    def mark_slow(self, name: str, factor: float) -> None:
        """Straggler: scale the slice's WCET table for future admissions;
        running work is absorbed by the paper's adaptation machinery."""
        sl = self.slices[name]
        sl.slow_factor = factor
        sl.scheduler.table = sl.spec.table.scaled(factor)
        sl.scheduler.admission.table = sl.scheduler.table

    def fail_slice(self, name: str) -> List[Request]:
        """Fail-stop a slice; re-admit its unfinished requests elsewhere.

        Live slices are shut down first (device closed, engine frozen),
        so the dead slice's arena rows are never touched again; each
        displaced request's remaining tail is re-admitted through the
        normal placement + admission + lease path, which allocates rows
        on SURVIVING slices' resident arenas. Returns requests that
        could not be re-placed (shed load — in a soft-RT system overload
        sheds rather than cascades)."""
        sl = self.slices[name]
        sl.shutdown()
        displaced: List[Tuple[int, Request]] = []
        now = self.loop.now
        for rid, placed_on in list(self.placement.items()):
            if placed_on != name:
                continue
            req = self.requests[rid]
            del self.placement[rid]
            if req.end_time <= now:
                # Already fully arrived; in-flight frames lost with the
                # slice, nothing left to re-admit.
                self.finished_with_slice.append(rid)
                continue
            # Frames with arrival <= now are lost with the slice. floor,
            # not int(): a request whose start is still in the future
            # (e.g. a tail re-admitted by an earlier failover) has a
            # negative elapsed fraction, and int()'s truncation toward
            # zero would count one phantom arrived frame.
            arrived = math.floor((now - req.start_time) / req.period) + 1
            remaining = req.n_frames - max(0, arrived)
            if remaining <= 0:
                self.finished_with_slice.append(rid)
                continue
            # Re-admit the remaining tail as a fresh request.
            tail = Request(
                category=req.category,
                period=req.period,
                relative_deadline=req.relative_deadline,
                n_frames=remaining,
                start_time=now + req.period,
            )
            displaced.append((rid, tail))
        lost = []
        for rid, tail in displaced:
            if self.submit_request(tail):
                self.failover_map[rid] = tail.request_id
                self.reroutes += 1
            else:
                self.failover_map[rid] = None
                lost.append(tail)
        return lost

    # -- placement + admission --------------------------------------------
    def submit_request(
        self, request: Request, external_arrivals: bool = False
    ) -> bool:
        """``external_arrivals`` is forwarded to the chosen slice's
        scheduler: the ingest gateway registers streams through the
        SAME placement/admission/lease path but delivers the frames
        itself (``DeepRT.ingest_frame``)."""
        ranked = sorted(
            ((sl.utilization(), sl.spec.name, sl)
             for sl in self.slices.values() if sl.hosts(request)),
            key=lambda t: (t[0], t[1]),
        )
        chosen: Optional[str] = None
        for _u, _name, sl in ranked:
            if not sl.can_lease(request):
                continue  # no free arena row for a new decode stream: spill
            result = sl.scheduler.submit_request(
                request, external_arrivals=external_arrivals
            )
            if result.admitted:
                sl.lease(request)
                self.placement[request.request_id] = sl.spec.name
                self.requests[request.request_id] = request
                chosen = sl.spec.name
                break
        self.placement_attempts.append(
            (request.request_id,
             tuple((name, u) for u, name, _ in ranked), chosen)
        )
        if chosen is not None:
            return True
        self.dropped.append(request)
        return False

    # -- metrics ----------------------------------------------------------
    def run(self, until: Optional[float] = None) -> None:
        self.loop.run(until)

    def aggregate_metrics(self) -> Dict[str, float]:
        total = missed = jobs = shed = 0
        e2e_sum = 0.0
        e2e_n = 0
        for sl in self.slices.values():
            m = sl.scheduler.metrics
            total += m.completed_frames
            missed += m.missed_frames
            jobs += m.job_count
            shed += m.dropped_frames
            e2e_sum += sum(m.e2e_latencies)
            e2e_n += len(m.e2e_latencies)
        return {
            "completed_frames": total,
            "missed_frames": missed,
            "miss_rate": missed / total if total else 0.0,
            "jobs": jobs,
            "dropped_requests": len(self.dropped),
            "dropped_frames": shed,
            "mean_e2e_latency": e2e_sum / e2e_n if e2e_n else 0.0,
            "reroutes": self.reroutes,
        }
