"""Cluster scheduler: DeepRT at pod scale (beyond-paper layer).

The paper schedules one GPU. At pod scale a deployment runs many *slices*
(a pod, or a sub-mesh hosting one model's SPMD program). Each slice runs
its own DeepRT instance (DisBatcher + EDF + admission) — the paper's
design is per-accelerator, so it shards naturally. This layer adds what a
1000-node deployment needs on top:

- placement: route a new request to the slice with the lowest Phase-1
  utilization that can host its category (capability = profiled model);
  admission on the chosen slice decides finally (spill to the next
  candidate on rejection);
- fault tolerance: on slice failure every in-flight request of that slice
  is *re-admitted* elsewhere — the paper's admission test doubles as the
  recovery policy, so recovery never overloads surviving slices;
- degraded capacity / stragglers: a slice may be marked slow with factor f;
  its WCET table is scaled by f (ProfileTable.scaled) and its *future*
  admissions see the degraded table, while the overrun/adaptation machinery
  (paper §4.4) absorbs the transient — the paper's penalty mechanism is
  precisely straggler mitigation at this level;
- elastic scale-up: adding a slice makes its capacity available to the
  placement loop immediately.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.profiler import ProfileTable
from repro.core.request import Request
from repro.core.scheduler import DeepRT, ExecutionModel
from repro.core.simulator import EventLoop


@dataclass
class SliceSpec:
    name: str
    table: ProfileTable  # per-slice WCET table (mesh-dependent)
    models: Optional[Sequence[str]] = None  # None = hosts any profiled model


class Slice:
    def __init__(self, spec: SliceSpec, loop: EventLoop, execution=None,
                 adaptation_enabled: bool = True):
        self.spec = spec
        self.scheduler = DeepRT(
            spec.table, loop=loop, execution=execution,
            adaptation_enabled=adaptation_enabled,
        )
        self.alive = True
        self.slow_factor = 1.0

    def hosts(self, request: Request) -> bool:
        if not self.alive:
            return False
        if self.spec.models is not None and request.category.model_id not in self.spec.models:
            return False
        return self.spec.table.has(
            request.category.model_id, request.category.shape_key
        )

    def utilization(self) -> float:
        sched = self.scheduler
        state_cats = []
        from repro.core.admission import snapshot_from_scheduler

        state = snapshot_from_scheduler(
            now=sched.loop.now,
            disbatcher=sched.disbatcher,
            queued_jobs=sched.worker.queue.snapshot(),
            device_free_at=sched.device.busy_until or sched.loop.now,
            table=sched.table,
        )
        return sched.admission.phase1_utilization(state.categories)


class ClusterScheduler:
    def __init__(self, loop: Optional[EventLoop] = None, execution=None):
        self.loop = loop if loop is not None else EventLoop()
        self.execution = execution
        self.slices: Dict[str, Slice] = {}
        # request -> slice name, for failure recovery:
        self.placement: Dict[int, str] = {}
        self.requests: Dict[int, Request] = {}
        self.dropped: List[Request] = []
        self.reroutes = 0

    # -- elasticity ------------------------------------------------------
    def add_slice(self, spec: SliceSpec) -> Slice:
        sl = Slice(spec, self.loop, execution=self.execution)
        self.slices[spec.name] = sl
        return sl

    def mark_slow(self, name: str, factor: float) -> None:
        """Straggler: scale the slice's WCET table for future admissions;
        running work is absorbed by the paper's adaptation machinery."""
        sl = self.slices[name]
        sl.slow_factor = factor
        sl.scheduler.table = sl.spec.table.scaled(factor)
        sl.scheduler.admission.table = sl.scheduler.table

    def fail_slice(self, name: str) -> List[Request]:
        """Kill a slice; re-admit its unfinished requests elsewhere.

        Returns requests that could not be re-placed (shed load — in a
        soft-RT system overload sheds rather than cascades)."""
        sl = self.slices[name]
        sl.alive = False
        displaced = []
        now = self.loop.now
        for rid, placed_on in list(self.placement.items()):
            if placed_on != name:
                continue
            req = self.requests[rid]
            if req.end_time <= now:
                continue  # already fully arrived; frames lost with the slice
            del self.placement[rid]
            remaining = req.n_frames - max(
                0, int((now - req.start_time) / req.period) + 1
            )
            if remaining <= 0:
                continue
            # Re-admit the remaining tail as a fresh request.
            tail = Request(
                category=req.category,
                period=req.period,
                relative_deadline=req.relative_deadline,
                n_frames=remaining,
                start_time=now + req.period,
            )
            displaced.append(tail)
        lost = []
        for req in displaced:
            if not self.submit_request(req):
                lost.append(req)
            else:
                self.reroutes += 1
        return lost

    # -- placement + admission --------------------------------------------
    def submit_request(self, request: Request) -> bool:
        candidates = [s for s in self.slices.values() if s.hosts(request)]
        candidates.sort(key=lambda s: s.utilization())
        for sl in candidates:
            result = sl.scheduler.submit_request(request)
            if result.admitted:
                self.placement[request.request_id] = sl.spec.name
                self.requests[request.request_id] = request
                return True
        self.dropped.append(request)
        return False

    # -- metrics ----------------------------------------------------------
    def run(self, until: Optional[float] = None) -> None:
        self.loop.run(until)

    def aggregate_metrics(self) -> Dict[str, float]:
        total = missed = jobs = 0
        for sl in self.slices.values():
            m = sl.scheduler.metrics
            total += m.completed_frames
            missed += m.missed_frames
            jobs += m.job_count
        return {
            "completed_frames": total,
            "missed_frames": missed,
            "miss_rate": missed / total if total else 0.0,
            "jobs": jobs,
            "dropped_requests": len(self.dropped),
            "reroutes": self.reroutes,
        }
